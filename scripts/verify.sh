#!/usr/bin/env bash
# Tier-1 verification gate — the exact command CI and ROADMAP.md use.
# Run from the repo root:  bash scripts/verify.sh  (or: make verify)
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
