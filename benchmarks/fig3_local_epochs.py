"""Paper Fig. 3: effect of the number of local epochs/steps K (E).

Claim reproduced: larger K gives faster per-round convergence early on, but
no significant final-accuracy advantage."""
from benchmarks.common import QUICK, csv_row, run_federated


def main(rounds: int = 0):
    rounds = rounds or (30 if QUICK else 100)
    rows = []
    early, final = {}, {}
    for K in (1, 3, 10):
        r = run_federated("fedams", rounds=rounds, K=K)
        early[K] = sum(r.losses[3:8]) / 5
        final[K] = r.accs[-1]
        rows.append(csv_row(f"fig3_K{K}", r.us_per_round,
                            f"early_loss={early[K]:.4f};final_acc={final[K]:.3f}"))
    ok = early[10] <= early[1] + 0.02
    rows.append(csv_row("fig3_claim", 0, f"larger_K_faster_early={ok}"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
