"""Wire-format benchmarks: encode/decode throughput per codec and measured
wire bytes vs the paper's analytic bit counts (Table 1 made concrete), plus
the end-to-end simulated round time for a FedSim wire-mode run."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, csv_row

from repro.comm import make_wire_codec, measured_vs_analytic


def _time(fn, *args, reps: int = 20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_codec(name: str, d: int, ratio: float = 1 / 64,
                pack_impl: str = "jnp"):
    codec = make_wire_codec(name, ratio, pack_impl=pack_impl)
    x = jnp.asarray(np.random.default_rng(0).normal(size=d), jnp.float32)
    enc = jax.jit(codec.encode)
    dec = jax.jit(lambda b: codec.decode(b, d))
    us_enc, buf = _time(enc, x)
    us_dec, out = _time(dec, buf)
    r = measured_vs_analytic(codec, d)
    exact = bool(jnp.all(out == codec.compressor.compress(x).reshape(-1)))
    mbps = d * 4 / (us_enc / 1e6) / 1e6
    tag = f"{name}_pallas" if pack_impl == "pallas" else name
    return csv_row(
        f"wire_{tag}_d{d}", us_enc + us_dec,
        f"encode_us={us_enc:.0f};decode_us={us_dec:.0f};"
        f"encode_MBps={mbps:.0f};wire_bytes={r['measured_bytes']};"
        f"analytic_bits={r['analytic_bits']};"
        f"overhead_bits={r['overhead_bits']};exact={exact}")


def main():
    d = 100_000 if QUICK else 11_200_000
    rows = [bench_codec(name, d)
            for name in ("dense32", "topk", "blocktopk", "sign")]
    rows.append(bench_codec("sign", d, pack_impl="pallas"))

    # end-to-end: a small FedCAMS run with wire=True through the simulated
    # network — cumulative measured bytes and simulated seconds per codec
    from repro.configs.base import FedConfig, TrainConfig
    from repro.core.api import FederatedTrainer
    from repro.data.synthetic import FederatedClassification
    from repro.models import params as pdefs
    from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss
    mc = MLPConfig(in_dim=32, hidden=64, depth=2, num_classes=10)
    rounds = 10 if QUICK else 60
    for comp in ("topk", "sign"):
        tr = FederatedTrainer(
            fed=FedConfig(algorithm="fedcams", num_clients=50,
                          participating=10, local_steps=3, compressor=comp,
                          compress_ratio=1 / 64, eta=0.1, eta_l=0.05,
                          wire=True),
            train=TrainConfig(rounds=rounds, log_every=10**6),
            loss_fn=lambda p, b: mlp_loss(p, b, mc),
            init_params=pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
        tr.data = FederatedClassification(num_clients=50, feature_dim=32,
                                          seed=0)
        t0 = time.time()
        hist = tr.run(log=None)
        rows.append(csv_row(
            f"wire_e2e_{comp}", (time.time() - t0) / rounds * 1e6,
            f"rounds={rounds};wire_MB={hist[-1]['wire_bytes']/1e6:.2f};"
            f"sim_time_s={hist[-1]['sim_time_s']:.2f};"
            f"loss={hist[-1]['loss']:.3f}"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
