"""Shared harness for the paper-faithful benchmarks.

Replicates the paper's protocol at container scale: m=100 clients, n=10
participating per round, K local steps, Dirichlet non-IID synthetic data
(DESIGN.md §7 records the dataset substitution). Each benchmark times its
round function and reports the paper's headline metric as ``derived``.
"""
from __future__ import annotations

import os
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.rounds import FedSim
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import (ConvMixerConfig, MLPConfig,
                                    convmixer_defs, convmixer_loss, mlp_defs,
                                    mlp_loss)

# quick preset unless BENCH_PRESET=full; QUICK=1 forces it (CI smoke job)
QUICK = (os.environ.get("QUICK") == "1"
         or os.environ.get("BENCH_PRESET", "quick") == "quick")

# machine-readable benchmark record, committed so the perf trajectory is
# tracked across PRs (benchmarks/run.py and bench_rounds.py both write it)
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_rounds.json")


def update_bench_json(fields: dict) -> None:
    """Merge ``fields`` into BENCH_rounds.json (read-modify-write).
    ``sections`` merges per-section so a partial run (e.g.
    ``python -m benchmarks.run wire``) never erases other sections'
    recorded rows."""
    import json
    data = {}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    if "sections" in fields:
        merged = dict(data.get("sections", {}))
        merged.update(fields["sections"])
        fields = dict(fields, sections=merged)
    data.update(fields)
    data["preset"] = "quick" if QUICK else "full"
    with open(BENCH_JSON, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


@dataclass
class RunResult:
    losses: List[float]
    accs: List[float]
    bits: List[float]
    gammas: List[float]
    us_per_round: float


def make_problem(model: str = "mlp", num_clients: int = 100,
                 alpha: float = 0.3, seed: int = 0):
    if model == "convmixer":
        cfgm = ConvMixerConfig(dim=32, depth=4, kernel=5, patch=2,
                               num_classes=10, image=16)
        data = FederatedClassification(num_clients=num_clients,
                                       image_shape=(16, 16, 3),
                                       alpha=alpha, seed=seed)
        defs = convmixer_defs(cfgm)
        loss_fn = lambda p, b: convmixer_loss(p, b, cfgm)
    else:
        cfgm = MLPConfig(in_dim=32, hidden=64, depth=2, num_classes=10)
        data = FederatedClassification(num_clients=num_clients,
                                       feature_dim=32, alpha=alpha, seed=seed)
        defs = mlp_defs(cfgm)
        loss_fn = lambda p, b: mlp_loss(p, b, cfgm)
    return defs, loss_fn, data


# Per-algorithm (eta, eps) tuned by grid search, mirroring the paper's
# Appendix E protocol ("we search for the best training hyper-parameters
# for each baseline, including ours"). See EXPERIMENTS.md §Paper.
TUNED = {
    "fedavg": (1.0, 1e-3),
    "fedadagrad": (0.03, 1e-3),
    "fedadam": (0.03, 1e-3),
    "fedyogi": (0.03, 1e-3),
    "fedamsgrad": (0.03, 1e-3),
    "fedams": (0.1, 1e-4),
    "fedcams": (0.1, 1e-4),
}


def run_federated(algorithm: str, *, model: str = "mlp", rounds: int = 60,
                  m: int = 100, n: int = 10, K: int = 3, batch: int = 20,
                  eta: Optional[float] = None, eps: Optional[float] = None,
                  eta_l: float = 0.05,
                  compressor: str = "topk", ratio: float = 1 / 64,
                  option: int = 1, two_way: bool = False, seed: int = 0,
                  eval_every: int = 5) -> RunResult:
    defs, loss_fn, data = make_problem(model, m, seed=seed)
    eta_d, eps_d = TUNED.get(algorithm, (0.1, 1e-3))
    eta = eta_d if eta is None else eta
    eps = eps_d if eps is None else eps
    fed = FedConfig(algorithm=algorithm, eta=eta, eta_l=eta_l, local_steps=K,
                    num_clients=m, participating=n, compressor=compressor,
                    compress_ratio=ratio, option=option, two_way=two_way,
                    eps=eps)
    sim = FedSim(loss_fn, fed)
    params = pdefs.init_params(defs, jax.random.PRNGKey(seed))
    st = sim.init(params)
    rng = jax.random.PRNGKey(seed + 1)
    eval_batch = data.round_batches([0], 10**6, 1, 256)
    eval_b = {"x": jnp.asarray(eval_batch["x"][0, 0]),
              "y": jnp.asarray(eval_batch["y"][0, 0])}

    losses, accs, bits, gammas = [], [], [], []
    t_round = []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, m, n))
        b = data.round_batches(idx, r, K, batch)
        t0 = time.time()
        st, met = sim.round(st, jax.tree.map(jnp.asarray, b),
                            jnp.asarray(idx), k2)
        met = jax.device_get(met)
        t_round.append(time.time() - t0)
        losses.append(float(met["loss"]))
        bits.append(float(met["bits"]))
        gammas.append(float(met.get("gamma", 0.0)))
        if r % eval_every == 0 or r == rounds - 1:
            _, em = loss_fn(st.params, eval_b)
            accs.append(float(em["acc"]))
    return RunResult(losses=losses, accs=accs, bits=bits, gammas=gammas,
                     us_per_round=float(np.median(t_round[1:]) * 1e6))


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.0f},{derived}"
