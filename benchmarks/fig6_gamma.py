"""Paper Fig. 6 / Appendix B.1: empirical justification of Assumption 4.17 —
the compression-dissimilarity constant gamma stays bounded during training."""
from benchmarks.common import QUICK, csv_row, run_federated


def main(rounds: int = 0):
    rounds = rounds or (30 if QUICK else 100)
    rows = []
    for comp in ("sign", "topk"):
        r = run_federated("fedcams", rounds=rounds, compressor=comp,
                          ratio=1 / 64)
        gmax = max(r.gammas)
        gmean = sum(r.gammas) / len(r.gammas)
        rows.append(csv_row(f"fig6_gamma_{comp}", r.us_per_round,
                            f"gamma_max={gmax:.3f};gamma_mean={gmean:.3f};"
                            f"bounded={gmax < 10.0}"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
