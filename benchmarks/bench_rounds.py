"""FedSim round throughput: pre-PR-style per-round driver vs the scan driver.

Three execution paths over identical inputs:

``legacy``
    The pre-PR harness, reconstructed: per-round batch staging
    (``round_batches`` + ``jnp.asarray`` every round), a non-donated jit of
    the round body, and a device→host metrics sync every round — what the
    fig1–fig7 benchmarks paid per round before this PR. (The pre-PR code
    also kept bits/round counters on device and synced ``int(state.round)``
    for transport; those are omitted here, which *favors* legacy.)
``loop``
    The post-PR per-round path: donated state, host-side counters,
    pre-staged inputs — one jitted dispatch + one metrics sync per round.
``scan``
    ``FedSim.run_rounds``: R rounds in one ``lax.scan`` dispatch with
    donated carry and a single host sync. Bit-identical to ``loop``.

Measured on two configs:

* ``bench_wire_e2e`` — the bench_wire end-to-end config (m=50, n=10, K=3,
  topk r=1/64, wire=True). On accelerator-class hosts the round body is
  dispatch-bound and the scan driver dominates; on small CPU containers the
  MLP's local-training matmuls are genuine compute (measured ~20 GFLOP/s on
  the batched per-client matmuls), which bounds the achievable
  scan-vs-loop ratio once the driver overhead is gone.
* ``overhead_bound`` — same m/n/wire with K=1 and a smaller model, the
  regime the ISSUE's motivation describes (driver overhead >> round math),
  where the scan driver's speedup is expected to clear 5×.

A third dimension sweeps the local-update rule (core/local.py): scan-driver
throughput for sgd / sgdm / prox plus the eta_l_decay and heterogeneous-K
scenario knobs on the overhead-bound config — the sgd number doubles as the
regression gate for the rounds-monolith → layered-engine split (the split
must cost no scan-driver throughput).

A fourth dimension measures the select-once sparse uplink (DESIGN.md §3)
on ``compression_bound``: d ≈ 1.15e6 (pad-free: 560 blocks of 2048),
blockwise top-1 (ratio 1/2048), K=1, batch=1, wire on, γ diagnostic off —
the regime where the round is dominated by the uplink's O(n·d) memory
passes rather than local training. Dense (``sparse_uplink=False``, the
reference pipeline: per-client encode→bytes→dense-scatter decode→(n, d)
hat block→dense mean→dense EF rebuild) is A/B'd against the sparse path
(one selection per client — an argmax reduce at k'=1, never a sort-based
``lax.top_k``; the (vals, idx) pair flows to an O(n·k + d) server scatter;
EF touches only selected coordinates), both end-to-end and as the isolated
uplink+aggregate stage.

A fifth dimension (``server_ingest``) gates the one-pass fused server
ingest (DESIGN.md §3): at the same compression-bound scale it compiles
the staged two-pass server step (``server_aggregate_sparse`` jit +
``server_update`` jit — the dense mean delta is materialized between
them) against the fused ``server_ingest`` jit per
``server_state_dtype`` and reports bytes-moved-per-round two ways:
an analytic stream model (materialized f32-equivalent output streams
over the d-sized domain) and the HLO measurement from
``launch.hlo_analysis`` (``bytes`` = materialized-buffer traffic, the
repo's §Perf convention and the gate metric; ``rw_bytes`` = the
read+write estimate, reported as a diagnostic). The fused path never
materializes the dense (d,) mean delta, so its ``bytes`` sits strictly
below the two-pass number at every state dtype; quantized v/v̂ storage
(bf16, int8) shrinks it further. CPU caveat for the ``rw_bytes``
diagnostic: XLA CPU recomputes fused elementwise moments inside every
consumer fusion instead of re-reading them, so at fp32 the fused
read+write estimate roughly ties the two-pass one — the win there is
the removed materialization, which is exactly what ``bytes`` counts.

Container caveat (mirrors PR-2's 5x note): the ISSUE's ≥3x target for
sparse-vs-dense presumes an accelerator-class host where the dense path's
(n, d) hat block + mean is HBM-traffic-bound and the compacted
(vals, idx) block (kernels.topk_ef_sparse emits it in a single HBM pass)
removes that traffic. On this 2-vCPU CPU container XLA fuses the dense
path's extra passes into the same bandwidth-bound streams the round
already pays (EF gather/update, local training), and CPU scatter costs
~100 ns/update, so the measured end-to-end win is ~1.2x at k'=1 (parity
at ratio 1/64, where the shared sort-based top-k dominates both paths)
with ~1.4x on the overhead-bound config. The CI gate asserts the sparse
path never regresses below the dense one on this config.

Writes everything to ``BENCH_rounds.json`` at the repo root (via
benchmarks.common) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, csv_row, update_bench_json

from repro.configs.base import FedConfig
from repro.core.rounds import FedSim, _CoreState
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

E2E = dict(name="bench_wire_e2e",
           mlp=dict(in_dim=32, hidden=64, depth=2, num_classes=10),
           local_steps=3, batch=20)
OVERHEAD = dict(name="overhead_bound",
                mlp=dict(in_dim=16, hidden=16, depth=1, num_classes=4),
                local_steps=1, batch=8, eta=0.03, eta_l=0.03)
FED_KW = dict(algorithm="fedcams", num_clients=50, participating=10,
              compressor="topk", compress_ratio=1 / 64, eta=0.1, eta_l=0.05,
              wire=True)
# d = 1058² + (16+2+8)·1058 + 8 = 1,146,880 = 560 · 2048 exactly (no padded
# tail anywhere in the pipeline); blockwise top-1 is the compression-bound
# point: selection is a reduce and the message is 560 (val, idx) pairs.
COMPRESSION = dict(name="compression_bound",
                   mlp=dict(in_dim=16, hidden=1058, depth=2, num_classes=8),
                   local_steps=1, batch=1)
COMPRESSION_FED_KW = dict(algorithm="fedcams", num_clients=10,
                          participating=10, compressor="blocktopk",
                          compress_ratio=1 / 2048, wire_block=2048,
                          eta=0.1, eta_l=0.05, wire=True, track_gamma=False)
# Sixth dimension (``scale_out``, DESIGN.md §scale-out): the EF shard
# store at m = 10^4 .. 10^6 clients with a FIXED participating cohort.
# d = 16·64+64 + 64·64+64 + 64·8+8 = 5768; the resident (m, d) EF buffer
# is 231 MB at m=10^4 and 23 GB at m=10^6 — the sharded path must hold
# device residency flat (cohort-sized) across the whole sweep. wire=True
# so the sweep also exercises the lazy per-client link draws.
SCALE = dict(name="scale_out",
             mlp=dict(in_dim=16, hidden=64, depth=2, num_classes=8),
             local_steps=2, batch=8)
SCALE_FED_KW = dict(algorithm="fedcams", compressor="blocktopk",
                    compress_ratio=1 / 64, participating=32, eta=0.1,
                    eta_l=0.05, wire=True, track_gamma=False)


def _make_sim(cfg):
    mc = MLPConfig(**cfg["mlp"])
    kw = dict(FED_KW, **{k: cfg[k] for k in ("eta", "eta_l") if k in cfg})
    fed = FedConfig(local_steps=cfg["local_steps"], **kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, mc), fed)
    st = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
    return sim, st


def _fresh_state(sim, cfg):
    mc = MLPConfig(**cfg["mlp"])
    sim.network = type(sim.network)(sim.network.cfg, FED_KW["num_clients"])
    sim.comm_log = type(sim.comm_log)()
    return sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))


def _stage(data, cfg, rounds: int):
    """Identical staged inputs for every path: (R, n, K, ...) batches,
    (R, n) indices, (R,) keys — plus the host-side per-round views the
    legacy path re-stages from."""
    rng = jax.random.PRNGKey(1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, FED_KW["num_clients"],
                                        FED_KW["participating"]))
        batches.append(data.round_batches(idx, r, cfg["local_steps"],
                                          cfg["batch"]))
        idxs.append(idx)
        keys.append(k2)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    return stacked, jnp.asarray(np.stack(idxs)), jnp.stack(keys), np.stack(idxs)


def _run_legacy(sim, fn, st, data, cfg, idx_host, keys, rounds: int):
    """Pre-PR driver semantics: stage + dispatch + sync every round.
    ``fn`` is the shared non-donated jit of the round body (like the seed's
    round jit; hoisted so compile stays out of the timing)."""
    bits = 0
    for r in range(rounds):
        raw = data.round_batches(idx_host[r], r, cfg["local_steps"],
                                 cfg["batch"])
        b = jax.tree.map(jnp.asarray, raw)
        core, met = fn(_CoreState(*st[:5]), b, jnp.asarray(idx_host[r]),
                       keys[r], jnp.int32(r))
        bits += sim._bits_per_round(idx_host.shape[1])
        met = dict(met)
        met["bits"] = bits
        met.update(sim._record_timing(sim._round_timing(idx_host[r], r),
                                      None))
        met = {k: float(v) for k, v in met.items()}  # per-round sync
        st = type(st)(*core, bits=bits, round=r + 1)
    return st, met


def _run_loop(sim, st, batches, idx, keys, rounds: int):
    """Post-PR per-round path, consumed the way FederatedTrainer consumes
    it (per-round float() conversion = one device sync per round)."""
    last = None
    for r in range(rounds):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st, met = sim.round(st, b_r, idx[r], keys[r])
        last = {k: float(v) for k, v in met.items()}
    return st, last


def _run_scan(sim, st, batches, idx, keys):
    st, mets = sim.run_rounds(st, batches, idx, keys)
    return st, {k: float(v) for k, v in mets[-1].items()}


def measure(cfg, rounds: int) -> dict:
    data = FederatedClassification(num_clients=FED_KW["num_clients"],
                                   num_classes=cfg["mlp"]["num_classes"],
                                   feature_dim=cfg["mlp"]["in_dim"], seed=0)
    batches, idx, keys, idx_host = _stage(data, cfg, rounds)

    sim, st = _make_sim(cfg)
    legacy_fn = jax.jit(sim._round_impl)  # NOT donated, like the seed jit
    _run_legacy(sim, legacy_fn, st, data, cfg, idx_host, keys, 2)  # warmup
    st = _fresh_state(sim, cfg)
    t0 = time.perf_counter()
    st_l, met_legacy = _run_legacy(sim, legacy_fn, st, data, cfg, idx_host,
                                   keys, rounds)
    jax.block_until_ready(st_l.params)
    t_legacy = time.perf_counter() - t0

    sim2, st2 = _make_sim(cfg)
    _run_loop(sim2, st2, batches, idx, keys, 2)  # warmup
    st2 = _fresh_state(sim2, cfg)
    t0 = time.perf_counter()
    st_loop, met_loop = _run_loop(sim2, st2, batches, idx, keys, rounds)
    jax.block_until_ready(st_loop.params)
    t_loop = time.perf_counter() - t0

    sim3, st3 = _make_sim(cfg)
    _run_scan(sim3, st3, batches, idx, keys)  # warmup
    st3 = _fresh_state(sim3, cfg)
    t0 = time.perf_counter()
    st_scan, met_scan = _run_scan(sim3, st3, batches, idx, keys)
    jax.block_until_ready(st_scan.params)
    t_scan = time.perf_counter() - t0

    # loop and scan consume identical staged inputs -> identical results
    assert met_loop["wire_bytes"] == met_scan["wire_bytes"]
    assert np.array_equal(met_loop["loss"], met_scan["loss"],
                          equal_nan=True), (met_loop, met_scan)
    wire_bytes = met_scan["wire_bytes"]
    return {
        "config": dict(FED_KW, rounds=rounds, d=int(sim._d), **{
            k: v for k, v in cfg.items() if k != "name"}),
        "legacy_rounds_per_s": rounds / t_legacy,
        "loop_rounds_per_s": rounds / t_loop,
        "scan_rounds_per_s": rounds / t_scan,
        "speedup_scan_vs_legacy": t_legacy / t_scan,
        "speedup_scan_vs_loop": t_loop / t_scan,
        "wire_bytes_total": int(wire_bytes),
        "scan_wire_bytes_per_s": wire_bytes / t_scan,
        "final_loss": met_scan["loss"],
    }


def _measure_sparse_ab(cfg, fed_kw, rounds: int, reps: int) -> dict:
    """Scan-driver rounds/s for sparse_uplink True vs False on identical
    staged inputs (median of ``reps`` runs — this container is noisy)."""
    data = FederatedClassification(num_clients=fed_kw["num_clients"],
                                   num_classes=cfg["mlp"]["num_classes"],
                                   feature_dim=cfg["mlp"]["in_dim"], seed=0)
    rng = jax.random.PRNGKey(1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, fed_kw["num_clients"],
                                        fed_kw["participating"]))
        batches.append(data.round_batches(idx, r, cfg["local_steps"],
                                          cfg["batch"]))
        idxs.append(idx)
        keys.append(k2)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    idx, keys = jnp.asarray(np.stack(idxs)), jnp.stack(keys)

    mc = MLPConfig(**cfg["mlp"])
    sims, ts = {}, {"dense": [], "sparse": []}
    out = {}
    for sparse in (False, True):
        fed = FedConfig(local_steps=cfg["local_steps"],
                        sparse_uplink=sparse, **fed_kw)
        sims["sparse" if sparse else "dense"] = FedSim(
            lambda p, b: mlp_loss(p, b, mc), fed)
    # interleaved A/B, fastest-of-N per path: this container's run-to-run
    # noise (±30-40%) would otherwise dominate the measured ratio
    for rep in range(reps + 1):         # first pair compiles
        for key, sim in sims.items():
            if sim.network is not None:
                sim.network = type(sim.network)(sim.network.cfg,
                                                fed_kw["num_clients"])
                sim.comm_log = type(sim.comm_log)()
            st = sim.init(pdefs.init_params(mlp_defs(mc),
                                            jax.random.PRNGKey(0)))
            t0 = time.perf_counter()
            st, mets = sim.run_rounds(st, stacked, idx, keys)
            jax.block_until_ready(st.params)
            ts[key].append(time.perf_counter() - t0)
            out[f"{key}_final_loss"] = float(mets[-1]["loss"])
    for key in sims:
        out[f"{key}_rounds_per_s"] = rounds / float(np.min(ts[key][1:]))
    out["speedup_sparse_vs_dense"] = (out["sparse_rounds_per_s"]
                                      / out["dense_rounds_per_s"])
    return out


def _measure_uplink_stage(d: int, n: int, fed_kw, rounds: int,
                          reps: int) -> dict:
    """The tentpole in isolation: EF→select/compress→aggregate over staged
    (rounds, n, d) deltas, scanned — no local training, no server update.
    Uses the same stage functions the round composes (core/stages.py)."""
    import functools

    from repro.comm import make_wire_codec
    from repro.core.compressors import make_compressor
    from repro.core.stages import (client_uplink, client_uplink_sparse,
                                   ef_update_sparse, server_aggregate_sparse)

    comp = make_compressor(fed_kw["compressor"], fed_kw["compress_ratio"],
                           fed_kw["wire_block"])
    codec = make_wire_codec(fed_kw["compressor"], fed_kw["compress_ratio"],
                            fed_kw["wire_block"])
    m = fed_kw["num_clients"]
    rng = jax.random.PRNGKey(0)
    deltas = 0.01 * jax.random.normal(rng, (rounds, n, d), jnp.float32)
    cidx = jnp.stack([jax.random.permutation(jax.random.fold_in(rng, r),
                                             m)[:n] for r in range(rounds)])
    pos = jnp.arange(n)

    def dense_body(errors, inp):
        delta, ci = inp
        errs = errors[ci]
        hats, nerrs = client_uplink(comp, codec, d, rng, delta, errs, pos)
        return errors.at[ci].set(nerrs), jnp.mean(hats, axis=0)

    def sparse_body(errors, inp):
        delta, ci = inp
        errors = errors.at[ci].add(delta)
        vals, sidx, rxv = client_uplink_sparse(comp, codec, d, rng,
                                               errors[ci], pos)
        errors = ef_update_sparse(errors, ci, sidx, vals, rxv)
        return errors, server_aggregate_sparse(rxv, sidx, d, n)

    def make_fn(body):
        @functools.partial(jax.jit, donate_argnums=(0,))
        def scan_fn(errors, dd, ii):
            return jax.lax.scan(body, errors, (dd, ii))
        return scan_fn

    fns = {"dense": make_fn(dense_body), "sparse": make_fn(sparse_body)}
    # interleaved A/B, fastest-of-N per path (see _measure_sparse_ab)
    ts = {"dense": [], "sparse": []}
    for rep in range(reps + 1):
        for key, fn in fns.items():
            errors = jnp.zeros((m, d), jnp.float32)
            t0 = time.perf_counter()
            errors, aggs = fn(errors, deltas, cidx)
            jax.block_until_ready(aggs)
            ts[key].append(time.perf_counter() - t0)
    out = {f"{k}_rounds_per_s": rounds / float(np.min(v[1:]))
           for k, v in ts.items()}
    out["speedup_sparse_vs_dense"] = (out["sparse_rounds_per_s"]
                                      / out["dense_rounds_per_s"])
    return out


def measure_compression_bound(rounds: int, reps: int = 3) -> dict:
    """The sparse-uplink dimension: end-to-end A/B plus the isolated
    uplink+aggregate stage on the compression-bound config (see module
    docstring for the container caveat on the ISSUE's 3x target)."""
    cfg = COMPRESSION
    mc = MLPConfig(**cfg["mlp"])
    d = sum(int(np.prod(s)) for s in
            [(mc.in_dim, mc.hidden), (mc.hidden,),
             (mc.hidden, mc.hidden), (mc.hidden,),
             (mc.hidden, mc.num_classes), (mc.num_classes,)])
    e2e = _measure_sparse_ab(cfg, COMPRESSION_FED_KW, rounds, reps)
    stage = _measure_uplink_stage(d, COMPRESSION_FED_KW["participating"],
                                  COMPRESSION_FED_KW,
                                  max(rounds, 4), reps)
    return {
        "config": dict(COMPRESSION_FED_KW, rounds=rounds, d=d,
                       **{k: v for k, v in cfg.items() if k != "name"}),
        "e2e": e2e,
        "uplink_stage": stage,
        "note": ("sparse = select-once (vals, idx) pipeline, DESIGN.md §3; "
                 "dense = reference encode->decode->dense-mean path. "
                 "See bench_rounds docstring: the ISSUE's >=3x presumes "
                 "accelerator-class HBM-bound aggregation; on this 2-vCPU "
                 "CPU container the dense path's extra passes fuse into "
                 "the round's shared bandwidth-bound streams."),
    }


def measure_server_ingest() -> dict:
    """The one-pass ingest dimension: bytes-moved-per-round for the fused
    ``server_ingest`` jit vs the staged two-pass (aggregate jit + update
    jit) at compression-bound scale, per ``server_state_dtype``. Static
    compile-time measurement (no timing loop): the gate metric is the HLO
    ``bytes`` estimate from launch.hlo_analysis — see module docstring."""
    import dataclasses

    from repro.core.compressors import block_layout
    from repro.core.server_opt import (init_server_state, server_ingest,
                                       server_update)
    from repro.core.stages import server_aggregate_sparse
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import backend_spec

    cfg = COMPRESSION
    mc = MLPConfig(**cfg["mlp"])
    d = sum(int(np.prod(s)) for s in
            [(mc.in_dim, mc.hidden), (mc.hidden,),
             (mc.hidden, mc.hidden), (mc.hidden,),
             (mc.hidden, mc.num_classes), (mc.num_classes,)])
    fed = FedConfig(local_steps=cfg["local_steps"], **COMPRESSION_FED_KW)
    n = fed.participating
    bs, nb = block_layout(d, fed.wire_block)
    k = max(1, int(round(bs * fed.compress_ratio)))

    # gathered (n, nb·k) client selections: one in-block offset per block,
    # global indices — the exact shape the uplink delivers to the server
    rngn = np.random.default_rng(0)
    off = rngn.integers(0, bs, size=(n, nb * k))
    idx = jnp.asarray((np.repeat(np.arange(nb), k)[None, :] * bs
                       + off).astype(np.int32))
    vals = jnp.asarray(rngn.standard_normal((n, nb * k)).astype(np.float32))
    x = jnp.zeros(d, jnp.float32)
    spec = backend_spec()
    f32_stream = 4.0 * d

    # two-pass: two jits with the dense (d,) mean delta materialized
    # between them (exactly how sim/mesh stage it on the unfused path)
    st = init_server_state(x)
    agg_c = jax.jit(
        lambda v, i: server_aggregate_sparse(v, i, d, n)
    ).lower(vals, idx).compile()
    upd_c = jax.jit(
        lambda s, p, dm: server_update(fed, s, p, dm)
    ).lower(st, x, x).compile()
    agg_hc, upd_hc = analyze(agg_c.as_text()), analyze(upd_c.as_text())
    two_bytes = agg_hc.bytes + upd_hc.bytes
    two = {
        # acc scatter target + dense mean delta + x2/m2/v2/vh2 outputs
        "analytic_streams": 6.0,
        "hlo_bytes": two_bytes,
        "hlo_streams": two_bytes / f32_stream,
        "hlo_rw_bytes": agg_hc.rw_bytes + upd_hc.rw_bytes,
        "memory_s": two_bytes / spec.hbm_bw,
    }

    # fused: one jit per state dtype, dense delta never materialized
    analytic = {
        "float32": 5.0,                      # acc + x2/m2/v2/vh2
        "bfloat16": 4.0,                     # v2/vh2 written at 2 B
        "int8": 3.5 + 2 * 4.0 * nb / f32_stream,   # q codes + block scales
    }
    fused = {}
    for dtype in ("float32", "bfloat16", "int8"):
        fed2 = dataclasses.replace(fed, server_state_dtype=dtype,
                                   fused_ingest="jnp")
        st2 = init_server_state(x, dtype, bs)
        c = jax.jit(
            lambda s, p, v, i, fed2=fed2: server_ingest(
                fed2, s, p, v, i, n, block=bs, impl="jnp")
        ).lower(st2, x, vals, idx).compile()
        hc = analyze(c.as_text())
        fused[dtype] = {
            "analytic_streams": analytic[dtype],
            "hlo_bytes": hc.bytes,
            "hlo_streams": hc.bytes / f32_stream,
            "hlo_rw_bytes": hc.rw_bytes,
            "memory_s": hc.bytes / spec.hbm_bw,
            "bytes_reduction_vs_two_pass": two_bytes / hc.bytes,
        }
    return {
        "config": dict(d=d, n=n, block=bs, nb=nb, k=k,
                       algorithm=fed.algorithm, option=fed.option,
                       backend=spec.name),
        "uplink_bytes": float(vals.nbytes + idx.nbytes),
        "two_pass": two,
        "fused": fused,
        "note": ("gate metric is hlo_bytes (materialized-buffer traffic, "
                 "the repo's §Perf convention): the fused path drops the "
                 "dense (d,) mean-delta stream at every state dtype. "
                 "analytic_streams counts materialized f32-equivalent "
                 "output streams over the d domain (uplink (vals, idx) "
                 "reads, 8·n·nb·k bytes, are identical on both paths and "
                 "excluded). int8 measures above its 3.5-stream analytic "
                 "model because XLA CPU materializes the fp32 v2/vh2 "
                 "intermediates before requantization. hlo_rw_bytes is "
                 "the read+write diagnostic — see module docstring for "
                 "the fp32 CPU recompute caveat."),
    }


def _live_device_bytes() -> int:
    """Total bytes of live jax arrays — the device-residency proxy this
    single-process CPU bench can measure (on CPU the 'device' is host RAM,
    but the accounting is the same buffers an accelerator would hold)."""
    return sum(int(x.nbytes) for x in jax.live_arrays())


def _compiled_mem(sim, st, batch, idx_like):
    """Best-effort XLA memory analysis of the compiled round executable
    (argument/output/temp sizes). None where the backend doesn't report."""
    try:
        core = _CoreState(*st[:5])
        args = (core, batch, jnp.asarray(idx_like), jax.random.PRNGKey(0),
                jnp.int32(0))
        avals = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
            args)
        ma = sim._round_fn.lower(*avals).compile().memory_analysis()
        if ma is None:
            return None
        return {k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes")
                if hasattr(ma, k)}
    except Exception:
        return None


def _run_scale(m: int, ef_store: bool, rounds: int) -> dict:
    """One scale-out arm: m clients, fixed n-cohort rounds through
    ``FedSim.round`` (per-round loop so live-buffer peaks are observable
    between rounds), prefetch overlapping when the shard store is on."""
    cfg = SCALE
    mc = MLPConfig(**cfg["mlp"])
    n = SCALE_FED_KW["participating"]
    fed = FedConfig(local_steps=cfg["local_steps"], num_clients=m,
                    ef_store=ef_store, **SCALE_FED_KW)
    sim = FedSim(lambda p, b: mlp_loss(p, b, mc), fed)
    data = FederatedClassification(num_clients=m, num_classes=mc.num_classes,
                                   feature_dim=mc.in_dim, seed=0)
    rng = jax.random.PRNGKey(1)
    staged = []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, m, n))
        b = data.round_batches(idx, r, cfg["local_steps"], cfg["batch"])
        staged.append((jax.tree.map(jnp.asarray, b), jnp.asarray(idx), k2))
    baseline = _live_device_bytes()          # staged inputs, no fed state yet
    st = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
    # warmup compiles round 0 (and, sharded, materializes warmup shards);
    # the timed run restarts from scratch
    w, _ = sim.round(st, staged[0][0], staged[0][1], staged[0][2])
    jax.block_until_ready(w.params)
    mem = _compiled_mem(sim, w, staged[0][0],
                        np.arange(n, dtype=np.int32) if ef_store
                        else staged[0][1])
    del w
    if ef_store:
        from repro.checkpoint.store import EFStore
        sim._efs = EFStore(m, sim._d)
    if sim.network is not None:
        sim.network = type(sim.network)(sim.network.cfg, m)
        sim.comm_log = type(sim.comm_log)()
    st = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
    losses, peak = [], 0
    t0 = time.perf_counter()
    for r in range(rounds):
        b, i, k = staged[r]
        nxt = staged[r + 1][1] if ef_store and r + 1 < rounds else None
        st, met = sim.round(st, b, i, k, prefetch_idx=nxt)
        losses.append(float(met["loss"]))    # per-round sync, loop semantics
        peak = max(peak, _live_device_bytes())
    dt = time.perf_counter() - t0
    return {
        "m": m, "ef_store": ef_store, "d": int(sim._d), "n": n,
        "rounds_per_s": rounds / dt,
        "losses": losses,
        "baseline_live_bytes": int(baseline),
        "peak_live_bytes": int(peak),
        "state_live_bytes": int(peak - baseline),
        "efstore_host_bytes": int(sim._efs.nbytes) if ef_store else 0,
        "compiled_memory": mem,
    }


def measure_scale_out(rounds: int) -> dict:
    """The scale-out dimension: resident-vs-sharded A/B at m=10^4 (must be
    loss-bit-identical), then the sharded m-sweep at fixed cohort size —
    device residency must stay flat while the resident baseline grows with
    m·d. Asserts the sharded peak under 1.25x the analytic device bound."""
    res = _run_scale(10_000, False, rounds)
    shd = _run_scale(10_000, True, rounds)
    assert res["losses"] == shd["losses"], (
        "ef_store must be bit-identical to the resident buffer",
        res["losses"], shd["losses"])
    sweep = {"10000": shd}
    for m in ((100_000,) if QUICK else (100_000, 1_000_000)):
        sweep[str(m)] = _run_scale(m, rounds=rounds, ef_store=True)
    d, n = shd["d"], shd["n"]
    # analytic device bound for the sharded path, independent of m:
    # fed state = params + m/v/v̂ moments + x_client + server_error (6 d
    # fp32 vectors) + the (n, d) cohort EF block; doubled for the
    # donate/update overlap (old + new state both live at the swap), plus
    # the measured pre-state baseline (staged batches, rng keys).
    bound = 2 * (4 * d * (n + 6)) + shd["baseline_live_bytes"]
    for r in sweep.values():
        assert r["peak_live_bytes"] <= 1.25 * bound, (
            "sharded device residency exceeded the analytic bound",
            r["m"], r["peak_live_bytes"], bound)
    return {
        "config": dict(SCALE_FED_KW, rounds=rounds, d=d,
                       **{k: v for k, v in SCALE.items() if k != "name"}),
        "resident_m10k": res,
        "sharded_m10k": shd,
        "loss_bitwise_identical": res["losses"] == shd["losses"],
        "resident_vs_sharded_peak_ratio": (res["peak_live_bytes"]
                                           / shd["peak_live_bytes"]),
        "sweep": sweep,
        "analytic_device_bound_bytes": int(bound),
        "note": ("resident (m, d) EF residency grows with m (231 MB at "
                 "m=10^4, 23 GB at 10^6 — unrunnable); the sharded path "
                 "holds the cohort block only, so peak live bytes stay "
                 "flat across the sweep while the EF state spills to "
                 "lazily materialized host shards (efstore_host_bytes). "
                 "rounds/s on this 1-vCPU CPU container includes host "
                 "gather/scatter + staging; the prefetch overlaps the "
                 "next round's gather with device compute."),
    }


# Seventh dimension (``robustness``, DESIGN.md §robustness): final loss
# and simulated time-to-loss under a (crash_prob × deadline × corruption)
# sweep on a straggler-heavy network (20% stragglers at 8x slowdown —
# wait-for-all pays the straggler max nearly every round, the deadline
# cutoff caps the round at ~2·p50 and drops the stragglers into the EF
# repayment path instead).
ROBUST = dict(name="robustness",
              mlp=dict(in_dim=16, hidden=32, depth=2, num_classes=8),
              local_steps=2, batch=8)
ROBUST_FED_KW = dict(algorithm="fedcams", num_clients=40, participating=16,
                     compressor="blocktopk", compress_ratio=1 / 16,
                     wire_block=256, eta=0.1, eta_l=0.05, wire=True,
                     track_gamma=False)


def _time_to_loss(losses, times, target: float) -> float:
    """Cumulative simulated seconds until the loss first reaches
    ``target`` (inf if it never does)."""
    cum = 0.0
    for l, t in zip(losses, times):
        cum += t
        if l <= target:
            return cum
    return float("inf")


def _stage_robust(rounds: int):
    """Shared staged inputs for every robustness / time-to-loss arm."""
    cfg = ROBUST
    m, n = ROBUST_FED_KW["num_clients"], ROBUST_FED_KW["participating"]
    data = FederatedClassification(num_clients=m,
                                   num_classes=cfg["mlp"]["num_classes"],
                                   feature_dim=cfg["mlp"]["in_dim"], seed=0)
    rng = jax.random.PRNGKey(1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, m, n))
        batches.append(data.round_batches(idx, r, cfg["local_steps"],
                                          cfg["batch"]))
        idxs.append(idx)
        keys.append(k2)
    return (jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches),
            jnp.asarray(np.stack(idxs)), jnp.stack(keys))


def _run_robust_arm(staged, fault, deadline_s: float, *,
                    straggler: float = 0.2, async_buffer: int = 0,
                    staleness: str = "inv_sqrt") -> dict:
    """One robustness / time-to-loss arm: scan-driven FedSim run on the
    shared staged inputs and a fresh (deterministic) straggler network.
    With ``async_buffer > 0`` the run goes through the event-driven
    buffered engine, so the per-entry metrics are per FLUSH, not per
    staged cohort. Uplink bytes are reported delivered/attempted
    separately (the CommLog split) — a deadline or async arm's wire bill
    counts only payloads the server saw."""
    from repro.comm import NetworkConfig, SimulatedNetwork
    from repro.comm.faults import FaultConfig  # noqa: F401 (callers build)
    batches, idx, keys = staged
    cfg = ROBUST
    mc = MLPConfig(**cfg["mlp"])
    fed = FedConfig(local_steps=cfg["local_steps"], fault=fault,
                    deadline_s=deadline_s, async_buffer=async_buffer,
                    staleness_weight=staleness, **ROBUST_FED_KW)
    net = SimulatedNetwork(
        NetworkConfig(straggler_prob=straggler, straggler_slowdown=8.0,
                      seed=0),
        ROBUST_FED_KW["num_clients"])
    sim = FedSim(lambda p, b: mlp_loss(p, b, mc), fed, network=net)
    st = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
    st, mets = sim.run_rounds(st, batches, idx, keys)
    n = ROBUST_FED_KW["participating"]
    losses = [float(m["loss"]) for m in mets]
    times = [float(m["round_time_s"]) for m in mets]
    return {
        "losses": losses,
        "final_loss": losses[-1],
        "round_times_s": times,
        "sim_time_s": float(np.sum(times)),
        "entries": len(mets),   # cohorts (sync) or flushes (async)
        "uplink_bytes_delivered": int(sim.comm_log.uplink_bytes),
        "uplink_bytes_attempted": int(sim.comm_log.uplink_bytes_attempted),
        "mean_survivors": float(np.mean(
            [float(m.get("survivors", n)) for m in mets])),
        "rejected_total": float(np.sum(
            [float(m.get("rejected", 0.0)) for m in mets])),
        "staleness_max": float(np.max(
            [float(m.get("staleness_max", 0.0)) for m in mets])),
    }


def _probe_dstar(staged, straggler: float) -> float:
    """Deadline for the cutoff arm: ~2·p50 of the round-0 cohort's
    per-client times passes every clean client (jitter included) and
    cuts the 8x stragglers. The probe network is a fresh instance with
    the arm's seed — draws are keyed by (seed, id)/(seed, round, id), so
    the arms see identical links and straggler fates."""
    from repro.comm import NetworkConfig, SimulatedNetwork
    cfg = ROBUST
    net = SimulatedNetwork(
        NetworkConfig(straggler_prob=straggler, straggler_slowdown=8.0,
                      seed=0),
        ROBUST_FED_KW["num_clients"])
    mc = MLPConfig(**cfg["mlp"])
    fed0 = FedConfig(local_steps=cfg["local_steps"], **ROBUST_FED_KW)
    sim0 = FedSim(lambda p, b: mlp_loss(p, b, mc), fed0, network=net)
    sim0.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
    timing0 = sim0._round_timing(np.asarray(staged[1][0]), 0)
    return 2.0 * timing0.p50_client_time_s


def measure_robustness(rounds: int) -> dict:
    """The robustness dimension: fault-free baseline, the all-ones-mask
    bitwise-parity arm, the (crash_prob × deadline) grid, and the
    corruption arms. Asserts the acceptance invariants inline — parity is
    bitwise, NaN injection keeps the loss finite and within 2x of
    fault-free, deadline-cutoff time-to-loss beats wait-for-all on the
    straggler-heavy network."""
    from repro.comm.faults import FaultConfig
    cfg = ROBUST
    m, n = ROBUST_FED_KW["num_clients"], ROBUST_FED_KW["participating"]
    staged = _stage_robust(rounds)

    base = _run_robust_arm(staged, None, 0.0)
    parity = _run_robust_arm(staged, FaultConfig(), 0.0)
    assert parity["losses"] == base["losses"], (
        "all-ones fault mask must be bitwise-identical to fault-free",
        base["losses"][:3], parity["losses"][:3])

    dstar = _probe_dstar(staged, 0.2)

    grid = {}
    for crash in (0.0, 0.1, 0.3):
        for dl, dname in ((0.0, "wait_all"), (dstar, "deadline")):
            fault = FaultConfig(crash_prob=crash, seed=1)
            grid[f"crash{crash}_{dname}"] = _run_robust_arm(staged, fault,
                                                            dl)
    corrupt = {}
    for mode in ("nan", "bitflip"):
        corrupt[mode] = _run_robust_arm(
            staged, FaultConfig(corrupt_prob=0.1, corrupt_mode=mode,
                                seed=2), 0.0)
        assert np.isfinite(corrupt[mode]["final_loss"]), mode
        assert corrupt[mode]["rejected_total"] > 0, (
            f"{mode}@0.1 produced no rejections over {rounds} rounds")
    assert corrupt["nan"]["final_loss"] <= 2.0 * base["final_loss"], (
        "NaN injection at 0.1 must stay within 2x of fault-free",
        corrupt["nan"]["final_loss"], base["final_loss"])

    # billing fix regression: a deadline arm bills fewer delivered than
    # attempted uplink bytes (cut stragglers' sends never arrive); the
    # fault-free wait-for-all arm bills everything it attempted
    assert grid["crash0.0_deadline"]["uplink_bytes_delivered"] < \
        grid["crash0.0_deadline"]["uplink_bytes_attempted"]
    assert base["uplink_bytes_delivered"] == base["uplink_bytes_attempted"]

    # acceptance: at straggler_prob >= 0.05 the deadline cutoff reaches
    # the shared loss target in no more simulated time than wait-for-all
    wait, cut = grid["crash0.0_wait_all"], grid["crash0.0_deadline"]
    target = 1.02 * max(wait["final_loss"], cut["final_loss"])
    ttl_wait = _time_to_loss(wait["losses"], wait["round_times_s"], target)
    ttl_cut = _time_to_loss(cut["losses"], cut["round_times_s"], target)
    assert ttl_cut <= ttl_wait, (
        "deadline cutoff must not lose to wait-for-all on the "
        "straggler-heavy network", ttl_cut, ttl_wait)
    return {
        "config": dict(ROBUST_FED_KW, rounds=rounds, deadline_s=dstar,
                       network=dict(straggler_prob=0.2,
                                    straggler_slowdown=8.0),
                       **{k: v for k, v in cfg.items() if k != "name"}),
        "baseline": base,
        "parity_bitwise_identical": parity["losses"] == base["losses"],
        "grid": grid,
        "corruption": corrupt,
        "time_to_loss": {"target": target, "wait_all_s": ttl_wait,
                         "deadline_s": ttl_cut,
                         "speedup": ttl_wait / ttl_cut},
        "note": ("grid arms share staged batches/cohorts and the network "
                 "seed, so loss deltas isolate the fault model; dropped "
                 "clients keep stale EF residuals and repay on rejoin "
                 "(DESIGN.md §robustness)."),
    }


def measure_time_to_loss(rounds: int) -> dict:
    """The time-to-loss dimension (DESIGN.md §11): sync wait-for-all vs
    deadline cutoff vs the event-driven async buffered engine, all on the
    shared staged inputs, swept over straggler probability. The metric is
    cumulative simulated seconds until the loss trajectory first reaches
    a shared target (1.02x the worst arm's final loss, so every arm gets
    there by construction). Asserts the ISSUE acceptance ordering
    async <= deadline <= wait_all at straggler_prob >= 0.2."""
    staged = _stage_robust(rounds)
    buffer = ROBUST_FED_KW["participating"] // 2
    probs = (0.2,) if QUICK else (0.0, 0.2, 0.4)
    sweep = {}
    for sp in probs:
        dstar = _probe_dstar(staged, sp)
        arms = {
            "wait_all": _run_robust_arm(staged, None, 0.0, straggler=sp),
            "deadline": _run_robust_arm(staged, None, dstar, straggler=sp),
            "async": _run_robust_arm(staged, None, 0.0, straggler=sp,
                                     async_buffer=buffer),
        }
        target = 1.02 * max(a["final_loss"] for a in arms.values())
        ttl = {k: _time_to_loss(a["losses"], a["round_times_s"], target)
               for k, a in arms.items()}
        assert all(np.isfinite(t) for t in ttl.values()), (sp, ttl)
        if sp >= 0.2:
            assert ttl["async"] <= ttl["deadline"] <= ttl["wait_all"], (
                "async buffered rounds must reach the target loss no "
                "later than deadline-cutoff and wait-for-all on a "
                "straggler-heavy network", sp, ttl)
        sweep[f"straggler{sp}"] = {
            "deadline_s": dstar,
            "target": target,
            "time_to_loss_s": ttl,
            "speedup_async_vs_wait_all": ttl["wait_all"] / ttl["async"],
            "speedup_async_vs_deadline": ttl["deadline"] / ttl["async"],
            "async_flushes": arms["async"]["entries"],
            "async_staleness_max": arms["async"]["staleness_max"],
            "arms": arms,
        }
    head = sweep["straggler0.2"]
    return {
        "config": dict(ROBUST_FED_KW, rounds=rounds, async_buffer=buffer,
                       staleness_weight="inv_sqrt",
                       straggler_grid=list(probs),
                       network=dict(straggler_slowdown=8.0, seed=0)),
        "sweep": sweep,
        "headline": {
            "straggler_prob": 0.2,
            "speedup_async_vs_wait_all": head["speedup_async_vs_wait_all"],
            "speedup_async_vs_deadline": head["speedup_async_vs_deadline"],
        },
        "note": ("arms share staged batches/cohorts and the network seed "
                 "(draws keyed by (seed, round, client)), so time-to-loss "
                 "deltas isolate the round policy; the async arm's bytes "
                 "bill only delivered payloads (DESIGN.md §11)."),
    }


_MESH_AB_CODE = '''
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs.base import ModelConfig, FedConfig, TrainConfig
from repro.core.mesh import (build_fed_round, build_fed_rounds_scan,
                             fed_batch_defs, fed_state_defs, init_fed_state,
                             scan_batch_specs)
from repro.launch.mesh import make_mesh
from repro.models import params as pdefs
from repro.models.model import Model
from repro.sharding.rules import ParallelContext

ROUNDS, REPS = {rounds}, {reps}
cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")
mesh = make_mesh((8, 1), ("data", "model"))
model = Model(cfg, tp=1)
ctx = ParallelContext(client_axes=("data",), num_clients=8)
K, GB, S = 1, 8, 16
rngn = np.random.default_rng(0)
toks = rngn.integers(0, 64, size=(ROUNDS, K, GB, S)).astype(np.int32)
batch = {{"tokens": jnp.asarray(toks),
          "labels": jnp.asarray(np.roll(toks, -1, -1))}}
seeds = jnp.arange(ROUNDS, dtype=jnp.int32)
steps, out = {{}}, {{}}
for agg in ("dense", "sparse"):
    fed = FedConfig(algorithm="fedcams", num_clients=8, local_steps=K,
                    compressor="blocktopk", compress_ratio=1 / 64,
                    aggregation=agg, client_axes=("data",),
                    eta=0.3, eta_l=0.05, track_gamma=False)
    train = TrainConfig(global_batch=GB, seq_len=S, remat_policy="none")
    rnd = build_fed_round(model, fed, train, ctx)
    sdefs = fed_state_defs(model, fed)
    ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
    bsp = jax.tree.map(lambda d: d.spec, fed_batch_defs(model, fed, train),
                       is_leaf=pdefs.is_def)
    steps[agg] = (jax.jit(compat.shard_map(
        build_fed_rounds_scan(rnd), mesh=mesh,
        in_specs=(ssp, scan_batch_specs(bsp), P(None)),
        out_specs=(ssp, {{"loss": P(None), "wire_up_bytes": P(None)}})),
        donate_argnums=(0,)), fed)
ts = {{"dense": [], "sparse": []}}
for rep in range(REPS + 1):          # first pair compiles
    for name, (fn, fed) in steps.items():
        state = init_fed_state(model, fed, jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        state, met = fn(state, batch, seeds)
        jax.block_until_ready(state.params)
        ts[name].append(time.perf_counter() - t0)
        out[name + "_final_loss"] = float(np.asarray(met["loss"])[-1])
        out[name + "_wire_up_bytes"] = float(
            np.asarray(met["wire_up_bytes"])[-1])
for name in ts:
    out[name + "_rounds_per_s"] = ROUNDS / float(np.min(ts[name][1:]))
out["speedup_sparse_vs_dense"] = (out["sparse_rounds_per_s"]
                                  / out["dense_rounds_per_s"])
out["wire_reduction"] = (out["dense_wire_up_bytes"]
                         / out["sparse_wire_up_bytes"])
print(json.dumps(out))
'''


def measure_mesh_sparse_ab(rounds: int, reps: int = 3) -> dict:
    """Mesh-backend sparse-vs-dense aggregation A/B: the scan-driven mesh
    round on a forced-8-device subprocess (the bench process itself must
    keep seeing one device, like the tests — tests/conftest.py note), tiny
    transformer, blocktopk 1/64, compacted-Selection gather vs dense psum.
    On this CPU host ``mesh_sparse_impl`` auto-resolves to the jnp
    ``Compressor.select`` provider (interpret-mode Pallas would measure
    the interpreter, not the kernel); both providers emit the
    bit-identical Selection (tests/test_mesh_parity.py), so the payload
    numbers are provider-independent. CPU-mesh collectives are
    shared-memory copies, so the rounds/s ratio is NOT the accelerator
    story — the load-bearing numbers are ``wire_reduction`` (the
    collective payload ratio, measured by ``mesh_wire_bytes`` == the
    traced gather operands) and the loss parity between the paths."""
    import json as _json
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _MESH_AB_CODE.format(rounds=rounds, reps=reps)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1800)
    assert r.returncode == 0, r.stderr[-4000:]
    out = _json.loads(r.stdout.strip().splitlines()[-1])
    out["config"] = dict(compressor="blocktopk", ratio=1 / 64, clients=8,
                         rounds=rounds, reps=reps, scan=True)
    return out


def measure_local_rules(rounds: int) -> dict:
    """The local-rule dimension (core/local.py): scan-driver throughput per
    rule on the overhead-bound config. sgd is the pre-split round — its
    number is the stage-split regression gate; sgdm/prox show what the
    extra local state/ops cost inside the same scanned pipeline."""
    cfg = OVERHEAD
    data = FederatedClassification(num_clients=FED_KW["num_clients"],
                                   num_classes=cfg["mlp"]["num_classes"],
                                   feature_dim=cfg["mlp"]["in_dim"], seed=0)
    batches, idx, keys, _ = _stage(data, cfg, rounds)
    out = {}
    for rule_kw in ({"local_opt": "sgd"},
                    {"local_opt": "sgdm"},
                    {"local_opt": "prox"},
                    {"local_opt": "sgd", "eta_l_decay": 0.99},
                    {"local_opt": "sgd", "local_steps_min": 1}):
        name = rule_kw["local_opt"]
        if "eta_l_decay" in rule_kw:
            name = "sgd+decay"
        elif "local_steps_min" in rule_kw:
            name = "sgd+heteroK"
        mc = MLPConfig(**cfg["mlp"])
        kw = dict(FED_KW, **{k: cfg[k] for k in ("eta", "eta_l") if k in cfg})
        kw.update(rule_kw)
        fed = FedConfig(local_steps=cfg["local_steps"], **kw)
        sim = FedSim(lambda p, b, mc=mc: mlp_loss(p, b, mc), fed)
        st = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
        _run_scan(sim, st, batches, idx, keys)  # warmup
        st = _fresh_state(sim, cfg)
        t0 = time.perf_counter()
        st, met = _run_scan(sim, st, batches, idx, keys)
        jax.block_until_ready(st.params)
        out[name] = {"scan_rounds_per_s": rounds / (time.perf_counter() - t0),
                     "final_loss": met["loss"]}
    return out


def main():
    rounds = 30 if QUICK else 120
    payload = {
        "suite": "fedsim_rounds",
        # The ISSUE's >=5x target presumes driver-overhead-dominated
        # rounds. On this container the bench_wire_e2e round body is
        # compute-bound (per-client matmuls at ~20 GFLOP/s on 2 vCPUs), so
        # the measured e2e ratio is bounded near 2x; overhead_bound shows
        # the driver itself clears 5x once round math stops dominating.
        "note": ("speedups are vs the reconstructed pre-PR per-round "
                 "driver ('legacy'); see module docstring for the "
                 "compute-bound vs overhead-bound regimes"),
    }
    rows = []
    for cfg in (E2E, OVERHEAD):
        p = measure(cfg, rounds)
        payload[cfg["name"]] = p
        rows.append(csv_row(
            f"rounds_{cfg['name']}_legacy", 1e6 * (1 / p["legacy_rounds_per_s"]),
            f"rounds_per_s={p['legacy_rounds_per_s']:.1f}"))
        rows.append(csv_row(
            f"rounds_{cfg['name']}_scan", 1e6 * (1 / p["scan_rounds_per_s"]),
            f"rounds_per_s={p['scan_rounds_per_s']:.1f};"
            f"speedup_vs_legacy={p['speedup_scan_vs_legacy']:.1f}x;"
            f"speedup_vs_loop={p['speedup_scan_vs_loop']:.1f}x;"
            f"wire_MBps={p['scan_wire_bytes_per_s']/1e6:.1f}"))
    lr = measure_local_rules(rounds)
    payload["local_rules"] = lr
    for name, p in lr.items():
        rows.append(csv_row(
            f"rounds_local_{name}", 1e6 * (1 / p["scan_rounds_per_s"]),
            f"rounds_per_s={p['scan_rounds_per_s']:.1f}"))
    cb = measure_compression_bound(4 if QUICK else 8, reps=3 if QUICK else 5)
    payload["compression_bound"] = cb
    rows.append(csv_row(
        "rounds_compression_bound_sparse",
        1e6 * (1 / cb["e2e"]["sparse_rounds_per_s"]),
        f"rounds_per_s={cb['e2e']['sparse_rounds_per_s']:.2f};"
        f"e2e_speedup_vs_dense={cb['e2e']['speedup_sparse_vs_dense']:.2f}x;"
        f"uplink_stage_speedup="
        f"{cb['uplink_stage']['speedup_sparse_vs_dense']:.2f}x"))
    si = measure_server_ingest()
    payload["server_ingest"] = si
    rows.append(csv_row(
        "rounds_server_ingest_fused_f32",
        1e6 * si["fused"]["float32"]["memory_s"],
        f"hlo_streams={si['fused']['float32']['hlo_streams']:.2f};"
        f"two_pass_streams={si['two_pass']['hlo_streams']:.2f};"
        "bytes_reduction="
        f"{si['fused']['float32']['bytes_reduction_vs_two_pass']:.2f}x;"
        "bf16_reduction="
        f"{si['fused']['bfloat16']['bytes_reduction_vs_two_pass']:.2f}x;"
        "int8_reduction="
        f"{si['fused']['int8']['bytes_reduction_vs_two_pass']:.2f}x"))
    ab = measure_mesh_sparse_ab(8 if QUICK else 24, reps=2 if QUICK else 4)
    payload["mesh_sparse_ab"] = ab
    rows.append(csv_row(
        "rounds_mesh_sparse_ab",
        1e6 * (1 / ab["sparse_rounds_per_s"]),
        f"rounds_per_s={ab['sparse_rounds_per_s']:.1f};"
        f"speedup_vs_dense={ab['speedup_sparse_vs_dense']:.2f}x;"
        f"wire_reduction={ab['wire_reduction']:.1f}x"))
    rb = measure_robustness(20 if QUICK else 60)
    payload["robustness"] = rb
    rows.append(csv_row(
        "rounds_robustness_deadline",
        1e6 * rb["time_to_loss"]["deadline_s"],
        f"ttl_speedup_vs_wait_all={rb['time_to_loss']['speedup']:.2f}x;"
        f"parity_bitwise={rb['parity_bitwise_identical']};"
        f"nan_final_loss={rb['corruption']['nan']['final_loss']:.3f};"
        f"base_final_loss={rb['baseline']['final_loss']:.3f};"
        f"crash0.3_deadline_loss="
        f"{rb['grid']['crash0.3_deadline']['final_loss']:.3f}"))
    ttl = measure_time_to_loss(20 if QUICK else 60)
    payload["time_to_loss"] = ttl
    hd = ttl["sweep"]["straggler0.2"]
    rows.append(csv_row(
        "rounds_async_time_to_loss",
        1e6 * hd["time_to_loss_s"]["async"],
        f"speedup_vs_wait_all={hd['speedup_async_vs_wait_all']:.2f}x;"
        f"speedup_vs_deadline={hd['speedup_async_vs_deadline']:.2f}x;"
        f"async_flushes={hd['async_flushes']};"
        f"staleness_max={hd['async_staleness_max']:.0f}"))
    so = measure_scale_out(4 if QUICK else 6)
    payload["scale_out"] = so
    for m, r in so["sweep"].items():
        rows.append(csv_row(
            f"rounds_scale_out_m{m}", 1e6 * (1 / r["rounds_per_s"]),
            f"rounds_per_s={r['rounds_per_s']:.2f};"
            f"peak_live_MB={r['peak_live_bytes']/1e6:.1f};"
            f"efstore_host_MB={r['efstore_host_bytes']/1e6:.1f}"))
    rows.append(csv_row(
        "rounds_scale_out_resident_m10000",
        1e6 * (1 / so["resident_m10k"]["rounds_per_s"]),
        f"rounds_per_s={so['resident_m10k']['rounds_per_s']:.2f};"
        f"peak_live_MB={so['resident_m10k']['peak_live_bytes']/1e6:.1f};"
        f"peak_ratio_vs_sharded="
        f"{so['resident_vs_sharded_peak_ratio']:.1f}x;"
        f"loss_bitwise={so['loss_bitwise_identical']}"))
    update_bench_json(payload)
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
