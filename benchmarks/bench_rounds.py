"""FedSim round throughput: pre-PR-style per-round driver vs the scan driver.

Three execution paths over identical inputs:

``legacy``
    The pre-PR harness, reconstructed: per-round batch staging
    (``round_batches`` + ``jnp.asarray`` every round), a non-donated jit of
    the round body, and a device→host metrics sync every round — what the
    fig1–fig7 benchmarks paid per round before this PR. (The pre-PR code
    also kept bits/round counters on device and synced ``int(state.round)``
    for transport; those are omitted here, which *favors* legacy.)
``loop``
    The post-PR per-round path: donated state, host-side counters,
    pre-staged inputs — one jitted dispatch + one metrics sync per round.
``scan``
    ``FedSim.run_rounds``: R rounds in one ``lax.scan`` dispatch with
    donated carry and a single host sync. Bit-identical to ``loop``.

Measured on two configs:

* ``bench_wire_e2e`` — the bench_wire end-to-end config (m=50, n=10, K=3,
  topk r=1/64, wire=True). On accelerator-class hosts the round body is
  dispatch-bound and the scan driver dominates; on small CPU containers the
  MLP's local-training matmuls are genuine compute (measured ~20 GFLOP/s on
  the batched per-client matmuls), which bounds the achievable
  scan-vs-loop ratio once the driver overhead is gone.
* ``overhead_bound`` — same m/n/wire with K=1 and a smaller model, the
  regime the ISSUE's motivation describes (driver overhead >> round math),
  where the scan driver's speedup is expected to clear 5×.

A third dimension sweeps the local-update rule (core/local.py): scan-driver
throughput for sgd / sgdm / prox plus the eta_l_decay and heterogeneous-K
scenario knobs on the overhead-bound config — the sgd number doubles as the
regression gate for the rounds-monolith → layered-engine split (the split
must cost no scan-driver throughput).

Writes everything to ``BENCH_rounds.json`` at the repo root (via
benchmarks.common) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, csv_row, update_bench_json

from repro.configs.base import FedConfig
from repro.core.rounds import FedSim, _CoreState
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

E2E = dict(name="bench_wire_e2e",
           mlp=dict(in_dim=32, hidden=64, depth=2, num_classes=10),
           local_steps=3, batch=20)
OVERHEAD = dict(name="overhead_bound",
                mlp=dict(in_dim=16, hidden=16, depth=1, num_classes=4),
                local_steps=1, batch=8, eta=0.03, eta_l=0.03)
FED_KW = dict(algorithm="fedcams", num_clients=50, participating=10,
              compressor="topk", compress_ratio=1 / 64, eta=0.1, eta_l=0.05,
              wire=True)


def _make_sim(cfg):
    mc = MLPConfig(**cfg["mlp"])
    kw = dict(FED_KW, **{k: cfg[k] for k in ("eta", "eta_l") if k in cfg})
    fed = FedConfig(local_steps=cfg["local_steps"], **kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, mc), fed)
    st = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
    return sim, st


def _fresh_state(sim, cfg):
    mc = MLPConfig(**cfg["mlp"])
    sim.network = type(sim.network)(sim.network.cfg, FED_KW["num_clients"])
    sim.comm_log = type(sim.comm_log)()
    return sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))


def _stage(data, cfg, rounds: int):
    """Identical staged inputs for every path: (R, n, K, ...) batches,
    (R, n) indices, (R,) keys — plus the host-side per-round views the
    legacy path re-stages from."""
    rng = jax.random.PRNGKey(1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, FED_KW["num_clients"],
                                        FED_KW["participating"]))
        batches.append(data.round_batches(idx, r, cfg["local_steps"],
                                          cfg["batch"]))
        idxs.append(idx)
        keys.append(k2)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    return stacked, jnp.asarray(np.stack(idxs)), jnp.stack(keys), np.stack(idxs)


def _run_legacy(sim, fn, st, data, cfg, idx_host, keys, rounds: int):
    """Pre-PR driver semantics: stage + dispatch + sync every round.
    ``fn`` is the shared non-donated jit of the round body (like the seed's
    round jit; hoisted so compile stays out of the timing)."""
    bits = 0
    for r in range(rounds):
        raw = data.round_batches(idx_host[r], r, cfg["local_steps"],
                                 cfg["batch"])
        b = jax.tree.map(jnp.asarray, raw)
        core, met = fn(_CoreState(*st[:5]), b, jnp.asarray(idx_host[r]),
                       keys[r], jnp.int32(r))
        bits += sim._bits_per_round(idx_host.shape[1])
        met = dict(met)
        met["bits"] = bits
        met.update(sim._transport_met(idx_host[r], r))
        met = {k: float(v) for k, v in met.items()}  # per-round sync
        st = type(st)(*core, bits=bits, round=r + 1)
    return st, met


def _run_loop(sim, st, batches, idx, keys, rounds: int):
    """Post-PR per-round path, consumed the way FederatedTrainer consumes
    it (per-round float() conversion = one device sync per round)."""
    last = None
    for r in range(rounds):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st, met = sim.round(st, b_r, idx[r], keys[r])
        last = {k: float(v) for k, v in met.items()}
    return st, last


def _run_scan(sim, st, batches, idx, keys):
    st, mets = sim.run_rounds(st, batches, idx, keys)
    return st, {k: float(v) for k, v in mets[-1].items()}


def measure(cfg, rounds: int) -> dict:
    data = FederatedClassification(num_clients=FED_KW["num_clients"],
                                   num_classes=cfg["mlp"]["num_classes"],
                                   feature_dim=cfg["mlp"]["in_dim"], seed=0)
    batches, idx, keys, idx_host = _stage(data, cfg, rounds)

    sim, st = _make_sim(cfg)
    legacy_fn = jax.jit(sim._round_impl)  # NOT donated, like the seed jit
    _run_legacy(sim, legacy_fn, st, data, cfg, idx_host, keys, 2)  # warmup
    st = _fresh_state(sim, cfg)
    t0 = time.perf_counter()
    st_l, met_legacy = _run_legacy(sim, legacy_fn, st, data, cfg, idx_host,
                                   keys, rounds)
    jax.block_until_ready(st_l.params)
    t_legacy = time.perf_counter() - t0

    sim2, st2 = _make_sim(cfg)
    _run_loop(sim2, st2, batches, idx, keys, 2)  # warmup
    st2 = _fresh_state(sim2, cfg)
    t0 = time.perf_counter()
    st_loop, met_loop = _run_loop(sim2, st2, batches, idx, keys, rounds)
    jax.block_until_ready(st_loop.params)
    t_loop = time.perf_counter() - t0

    sim3, st3 = _make_sim(cfg)
    _run_scan(sim3, st3, batches, idx, keys)  # warmup
    st3 = _fresh_state(sim3, cfg)
    t0 = time.perf_counter()
    st_scan, met_scan = _run_scan(sim3, st3, batches, idx, keys)
    jax.block_until_ready(st_scan.params)
    t_scan = time.perf_counter() - t0

    # loop and scan consume identical staged inputs -> identical results
    assert met_loop["wire_bytes"] == met_scan["wire_bytes"]
    assert np.array_equal(met_loop["loss"], met_scan["loss"],
                          equal_nan=True), (met_loop, met_scan)
    wire_bytes = met_scan["wire_bytes"]
    return {
        "config": dict(FED_KW, rounds=rounds, d=int(sim._d), **{
            k: v for k, v in cfg.items() if k != "name"}),
        "legacy_rounds_per_s": rounds / t_legacy,
        "loop_rounds_per_s": rounds / t_loop,
        "scan_rounds_per_s": rounds / t_scan,
        "speedup_scan_vs_legacy": t_legacy / t_scan,
        "speedup_scan_vs_loop": t_loop / t_scan,
        "wire_bytes_total": int(wire_bytes),
        "scan_wire_bytes_per_s": wire_bytes / t_scan,
        "final_loss": met_scan["loss"],
    }


def measure_local_rules(rounds: int) -> dict:
    """The local-rule dimension (core/local.py): scan-driver throughput per
    rule on the overhead-bound config. sgd is the pre-split round — its
    number is the stage-split regression gate; sgdm/prox show what the
    extra local state/ops cost inside the same scanned pipeline."""
    cfg = OVERHEAD
    data = FederatedClassification(num_clients=FED_KW["num_clients"],
                                   num_classes=cfg["mlp"]["num_classes"],
                                   feature_dim=cfg["mlp"]["in_dim"], seed=0)
    batches, idx, keys, _ = _stage(data, cfg, rounds)
    out = {}
    for rule_kw in ({"local_opt": "sgd"},
                    {"local_opt": "sgdm"},
                    {"local_opt": "prox"},
                    {"local_opt": "sgd", "eta_l_decay": 0.99},
                    {"local_opt": "sgd", "local_steps_min": 1}):
        name = rule_kw["local_opt"]
        if "eta_l_decay" in rule_kw:
            name = "sgd+decay"
        elif "local_steps_min" in rule_kw:
            name = "sgd+heteroK"
        mc = MLPConfig(**cfg["mlp"])
        kw = dict(FED_KW, **{k: cfg[k] for k in ("eta", "eta_l") if k in cfg})
        kw.update(rule_kw)
        fed = FedConfig(local_steps=cfg["local_steps"], **kw)
        sim = FedSim(lambda p, b, mc=mc: mlp_loss(p, b, mc), fed)
        st = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
        _run_scan(sim, st, batches, idx, keys)  # warmup
        st = _fresh_state(sim, cfg)
        t0 = time.perf_counter()
        st, met = _run_scan(sim, st, batches, idx, keys)
        jax.block_until_ready(st.params)
        out[name] = {"scan_rounds_per_s": rounds / (time.perf_counter() - t0),
                     "final_loss": met["loss"]}
    return out


def main():
    rounds = 30 if QUICK else 120
    payload = {
        "suite": "fedsim_rounds",
        # The ISSUE's >=5x target presumes driver-overhead-dominated
        # rounds. On this container the bench_wire_e2e round body is
        # compute-bound (per-client matmuls at ~20 GFLOP/s on 2 vCPUs), so
        # the measured e2e ratio is bounded near 2x; overhead_bound shows
        # the driver itself clears 5x once round math stops dominating.
        "note": ("speedups are vs the reconstructed pre-PR per-round "
                 "driver ('legacy'); see module docstring for the "
                 "compute-bound vs overhead-bound regimes"),
    }
    rows = []
    for cfg in (E2E, OVERHEAD):
        p = measure(cfg, rounds)
        payload[cfg["name"]] = p
        rows.append(csv_row(
            f"rounds_{cfg['name']}_legacy", 1e6 * (1 / p["legacy_rounds_per_s"]),
            f"rounds_per_s={p['legacy_rounds_per_s']:.1f}"))
        rows.append(csv_row(
            f"rounds_{cfg['name']}_scan", 1e6 * (1 / p["scan_rounds_per_s"]),
            f"rounds_per_s={p['scan_rounds_per_s']:.1f};"
            f"speedup_vs_legacy={p['speedup_scan_vs_legacy']:.1f}x;"
            f"speedup_vs_loop={p['speedup_scan_vs_loop']:.1f}x;"
            f"wire_MBps={p['scan_wire_bytes_per_s']/1e6:.1f}"))
    lr = measure_local_rules(rounds)
    payload["local_rules"] = lr
    for name, p in lr.items():
        rows.append(csv_row(
            f"rounds_local_{name}", 1e6 * (1 / p["scan_rounds_per_s"]),
            f"rounds_per_s={p['scan_rounds_per_s']:.1f}"))
    update_bench_json(payload)
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
