"""Paper Fig. 1 (and Fig. 8): FedAMS vs FedAvg/FedAdam/FedYogi/FedAMSGrad.

Claim reproduced: the adaptive methods beat FedAvg on the adaptive-friendly
model, and FedAMS (Option 1 max stabilization) is at least as good as the
other adaptive baselines on final training loss."""
from benchmarks.common import QUICK, csv_row, run_federated

ALGOS = ["fedavg", "fedadam", "fedyogi", "fedamsgrad", "fedams"]


def main(model: str = "mlp", rounds: int = 0):
    rounds = rounds or (80 if QUICK else 200)
    rows = []
    results = {}
    for algo in ALGOS:
        r = run_federated(algo, model=model, rounds=rounds)
        results[algo] = r
        rows.append(csv_row(
            f"fig1_{model}_{algo}", r.us_per_round,
            f"final_loss={r.losses[-1]:.4f};final_acc={r.accs[-1]:.3f}"))
    # headline check (paper Fig.1): FedAMS achieves the best test accuracy
    best_other = max(r.accs[-1] for a, r in results.items() if a != "fedams")
    ok = results["fedams"].accs[-1] >= best_other - 0.02
    ok_avg = results["fedams"].accs[-1] >= results["fedavg"].accs[-1]
    rows.append(csv_row(f"fig1_{model}_claim", 0,
                        f"fedams_top_acc={ok};fedams_beats_fedavg={ok_avg}"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
