"""Paper Fig. 2 (and Fig. 7): effect of the participating-client count n.

Claim reproduced (Corollary 4.11): larger n converges faster."""
from benchmarks.common import QUICK, csv_row, run_federated


def main(rounds: int = 0):
    rounds = rounds or (40 if QUICK else 120)
    rows = []
    finals = {}
    for n in (2, 5, 10, 20):
        r = run_federated("fedams", rounds=rounds, n=n)
        finals[n] = sum(r.losses[-5:]) / 5
        rows.append(csv_row(f"fig2_n{n}", r.us_per_round,
                            f"final_loss={finals[n]:.4f}"))
    ok = finals[20] <= finals[2] + 0.02
    rows.append(csv_row("fig2_claim", 0, f"larger_n_faster={ok}"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
