"""Paper Figs. 4/5: FedCAMS (sign / top-k r in {1/64,1/128,1/256}) vs
uncompressed FedAMS — loss vs rounds AND vs communicated bits.

Claims reproduced:
  * FedCAMS reaches comparable loss to FedAMS at 1-2 orders of magnitude
    fewer client->server bits;
  * heavier top-k compression (smaller r) = fewer bits but slower rounds.
"""
from benchmarks.common import QUICK, csv_row, run_federated

CASES = [
    ("fedams", dict()),
    ("fedcams_sign", dict(compressor="sign")),
    ("fedcams_top64", dict(compressor="topk", ratio=1 / 64)),
    ("fedcams_top128", dict(compressor="topk", ratio=1 / 128)),
    ("fedcams_top256", dict(compressor="topk", ratio=1 / 256)),
]


def main(rounds: int = 0):
    rounds = rounds or (40 if QUICK else 150)
    rows = []
    res = {}
    for name, kw in CASES:
        algo = "fedams" if name == "fedams" else "fedcams"
        r = run_federated(algo, rounds=rounds, **kw)
        res[name] = r
        rows.append(csv_row(
            f"fig4_{name}", r.us_per_round,
            f"final_loss={r.losses[-1]:.4f};bits={r.bits[-1]:.3g};"
            f"final_acc={r.accs[-1]:.3f}"))
    ratio64 = res["fedams"].bits[-1] / res["fedcams_top64"].bits[-1]
    ratio256 = res["fedams"].bits[-1] / res["fedcams_top256"].bits[-1]
    gap = res["fedcams_sign"].losses[-1] - res["fedams"].losses[-1]
    rows.append(csv_row("fig4_claim", 0,
                        f"bits_saving_top64={ratio64:.0f}x;"
                        f"bits_saving_top256={ratio256:.0f}x;"
                        f"sign_loss_gap={gap:+.3f}"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
