"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set BENCH_PRESET=full for the
long (paper-protocol-length) runs; the default quick preset keeps the whole
suite CPU-friendly.

    PYTHONPATH=src python -m benchmarks.run [section ...]
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (bench_rounds, bench_wire, fig1_fedams_vs_baselines,
                        fig2_num_clients, fig3_local_epochs, fig4_compression,
                        fig6_gamma, fig7_fedcams_clients, roofline,
                        table1_bits)

SECTIONS = {
    "rounds": bench_rounds.main,
    "wire": bench_wire.main,
    "fig1": lambda: fig1_fedams_vs_baselines.main("mlp"),
    "fig1_convmixer": lambda: fig1_fedams_vs_baselines.main("convmixer",
                                                            rounds=15),
    "fig2": fig2_num_clients.main,
    "fig3": fig3_local_epochs.main,
    "fig4": fig4_compression.main,
    "fig6": fig6_gamma.main,
    "fig7": fig7_fedcams_clients.main,
    "table1": table1_bits.main,
    "roofline": roofline.main,
}


def _parse_row(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main() -> None:
    from benchmarks.common import update_bench_json
    wanted = sys.argv[1:] or list(SECTIONS)
    print("name,us_per_call,derived")
    sections = {}
    for name in wanted:
        if name not in SECTIONS:
            print(f"{name},0,ERROR=unknown section", flush=True)
            continue
        rows = []
        try:
            for row in SECTIONS[name]():
                print(row, flush=True)
                rows.append(_parse_row(row))
        except Exception as e:  # keep the suite going (and partial rows)
            print(f"{name},0,ERROR={type(e).__name__}:{e}", flush=True)
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"ERROR={type(e).__name__}:{e}"})
        sections[name] = rows
    # machine-readable record next to the CSV, so the perf trajectory is
    # tracked across PRs (bench_rounds merges its own structured numbers)
    update_bench_json({"sections": sections})


if __name__ == "__main__":
    main()
