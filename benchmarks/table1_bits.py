"""Paper Tables 1/2: communication-bit accounting for scaled-sign and top-k,
one-way and two-way, plus the concrete ResNet-18-sized (d=11.2M) 500-round
costs from Table 2."""
from benchmarks.common import csv_row

from repro.core.compressors import make_compressor


def bits_table(d: int, T: int, n: int):
    unc = 32 * d * 2 * T * n
    rows = {}
    for name, ratio in (("sign", None), ("topk64", 1 / 64),
                        ("topk128", 1 / 128), ("topk256", 1 / 256)):
        comp = make_compressor("sign" if name == "sign" else "topk",
                               ratio or 1 / 64)
        one_way = (comp.bits_per_message(d) + 32 * d) * T * n
        two_way = comp.bits_per_message(d) * 2 * T * n
        rows[name] = (unc, one_way, two_way)
    return rows


def main():
    d, T, n = 11_200_000, 500, 10   # ResNet-18-sized, Table 2 protocol
    rows = []
    for name, (unc, ow, tw) in bits_table(d, T, n).items():
        rows.append(csv_row(
            f"table1_{name}", 0,
            f"uncompressed={unc:.3g};one_way={ow:.3g};two_way={tw:.3g};"
            f"saving_two_way={unc/tw:.0f}x"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
