"""Roofline report: renders results/dryrun.json (produced by
``python -m repro.launch.dryrun``) as the per-(arch x shape x mesh) table
used in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def load(path: str = DEFAULT):
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def rows(path: str = DEFAULT, tag: str = "baseline"):
    out = []
    for key, rec in sorted(load(path).items()):
        if not key.startswith(tag + "/"):
            continue
        _, mesh, arch, shape = key.split("/")
        if rec.get("status") == "skipped":
            out.append((mesh, arch, shape, "SKIP", rec["reason"], "", "", ""))
            continue
        if rec.get("status") != "ok":
            out.append((mesh, arch, shape, "ERROR",
                        rec.get("error", "")[:60], "", "", ""))
            continue
        rl = rec["roofline"]
        out.append((mesh, arch, shape, rl["dominant"],
                    f"{rl['compute_s']:.3e}", f"{rl['memory_s']:.3e}",
                    f"{rl['collective_s']:.3e}", f"{rl['useful_ratio']:.3f}"))
    return out


def main(path: str = DEFAULT, tag: str = "baseline"):
    lines = []
    for mesh, arch, shape, dom, c, m, coll, useful in rows(path, tag):
        lines.append(
            f"roofline_{mesh}_{arch}_{shape},0,"
            f"dominant={dom};compute_s={c};memory_s={m};"
            f"collective_s={coll};useful={useful}")
    if not lines:
        lines.append("roofline,0,missing=run python -m repro.launch.dryrun")
    return lines


if __name__ == "__main__":
    for line in main(*sys.argv[1:]):
        print(line)
