"""Paper Fig. 7: FedCAMS with different participating-client counts n —
the compressed algorithm keeps the n-scaling of Corollary 4.11 / B.2
(partial-participation analysis, Appendix B.4)."""
from benchmarks.common import QUICK, csv_row, run_federated


def main(rounds: int = 0):
    rounds = rounds or (40 if QUICK else 120)
    rows = []
    finals = {}
    for n in (2, 5, 10, 20):
        r = run_federated("fedcams", rounds=rounds, n=n, compressor="topk",
                          ratio=1 / 64)
        finals[n] = sum(r.losses[-5:]) / 5
        rows.append(csv_row(f"fig7_fedcams_n{n}", r.us_per_round,
                            f"final_loss={finals[n]:.4f}"))
    ok = finals[20] <= finals[2] + 0.02
    rows.append(csv_row("fig7_claim", 0,
                        f"larger_n_faster_under_compression={ok}"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
