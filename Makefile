.PHONY: verify test bench

# Tier-1 gate (ROADMAP.md): same command contributors and CI run.
verify:
	bash scripts/verify.sh

# Full suite without -x (see every failure).
test:
	PYTHONPATH=src python -m pytest -q

bench:
	PYTHONPATH=src python -m benchmarks.run
