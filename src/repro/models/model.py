"""Top-level model: embeddings -> stack -> final norm -> (tied) unembed.

One ``Model`` class serves all 10 assigned architectures; family-specific
behaviour is driven entirely by ``ModelConfig``. Modality frontends for
[audio]/[vlm] archs are stubs per the assignment: training batches carry
precomputed frame/patch *embeddings* of shape (B, S, d_model) instead of
token ids (the transformer backbone is what we implement).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import params as pdefs
from repro.models import stack as stack_mod
from repro.models.layers import (embed_defs, embed_lookup, rms_norm,
                                 sharded_xent, softcap, unembed_logits)
from repro.sharding.rules import ParallelContext, attn_dims, pad_to


class Model:
    def __init__(self, cfg: ModelConfig, tp: int = 1):
        self.cfg = cfg
        self.tp = tp
        self.vocab_padded = pad_to(cfg.vocab_size, tp)
        self.dims = attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, tp)

    # ------------------------------------------------------------------
    # Parameter definitions
    # ------------------------------------------------------------------
    def defs(self):
        cfg = self.cfg
        d = {
            "embed": embed_defs(self.vocab_padded, cfg.d_model),
            "stack": stack_mod.stack_defs(cfg, self.tp),
            "final_norm": pdefs.norm_scale(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            d["unembed"] = embed_defs(self.vocab_padded, cfg.d_model)
        if cfg.mtp is not None:
            desc = stack_mod.LayerDesc("attn", 0)
            d["mtp"] = {
                "proj": pdefs.linear(2 * cfg.d_model, cfg.d_model),
                "block": stack_mod.layer_defs(cfg, desc, self.dims, self.tp),
                "norm": pdefs.norm_scale(cfg.d_model),
            }
        return d

    def param_specs(self):
        return pdefs.param_specs(self.defs())

    def abstract_params(self, mesh=None):
        return pdefs.abstract_params(self.defs(), mesh)

    def init(self, rng):
        return pdefs.init_params(self.defs(), rng)

    # ------------------------------------------------------------------
    # Input specs (dry-run stand-ins and real-batch shapes)
    # ------------------------------------------------------------------
    def train_batch_defs(self, batch: int, seq: int):
        cfg = self.cfg
        if cfg.frontend is not None:
            return {
                "embeddings": pdefs.ParamDef((batch, seq, cfg.d_model),
                                             P("data", None, None), dtype=cfg.dtype),
                "labels": pdefs.ParamDef((batch, seq), P("data", None),
                                         dtype="int32"),
            }
        return {
            "tokens": pdefs.ParamDef((batch, seq), P("data", None), dtype="int32"),
            "labels": pdefs.ParamDef((batch, seq), P("data", None), dtype="int32"),
        }

    # ------------------------------------------------------------------
    # Forward / loss
    # ------------------------------------------------------------------
    def _embed_in(self, params, batch, ctx: ParallelContext):
        if "embeddings" in batch:
            return batch["embeddings"].astype(jnp.dtype(self.cfg.dtype))
        return embed_lookup(params["embed"], batch["tokens"], ctx, self.cfg.dtype)

    def _unembed(self, params, h, ctx: ParallelContext):
        table = params.get("unembed", params["embed"])
        logits = unembed_logits(table, ctx.tp_copy(h), self.cfg.dtype)
        return softcap(logits.astype(jnp.float32), self.cfg.logit_softcap)

    def loss(self, params, batch, ctx: ParallelContext, *,
             remat_policy: str = "full", chunk: int = 2048):
        """Next-token (or masked-target) CE. Returns (loss, metrics)."""
        cfg = self.cfg
        x = self._embed_in(params, batch, ctx)
        h, aux = stack_mod.stack_train(params["stack"], x, cfg, ctx,
                                       remat_policy=remat_policy, chunk=chunk)
        h = rms_norm(params["final_norm"], h, cfg.norm_eps)
        logits = self._unembed(params, h, ctx)
        labels = batch["labels"]
        ce = sharded_xent(logits, labels, ctx, true_vocab=cfg.vocab_size)
        loss = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp is not None and "tokens" in batch:
            mtp_ce = self._mtp_loss(params, h, batch, ctx)
            loss = loss + cfg.mtp.loss_weight * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        return loss, metrics

    def _mtp_loss(self, params, h, batch, ctx: ParallelContext):
        """DeepSeek-V3 multi-token prediction: predict t+2 from [h_t; emb_{t+1}]."""
        cfg = self.cfg
        mp = params["mtp"]
        emb_next = embed_lookup(params["embed"],
                                jnp.roll(batch["tokens"], -1, axis=1), ctx,
                                cfg.dtype)
        z = jnp.concatenate([h, emb_next], axis=-1) @ mp["proj"].astype(h.dtype)
        desc = stack_mod.LayerDesc("attn", 0)
        z, _ = stack_mod.layer_train(mp["block"], z, cfg, desc, self.dims, ctx)
        z = rms_norm(mp["norm"], z, cfg.norm_eps)
        logits = self._unembed(params, z, ctx)
        labels2 = jnp.roll(batch["labels"], -1, axis=1)
        return sharded_xent(logits, labels2, ctx, true_vocab=cfg.vocab_size)

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def cache_defs(self, batch: int, max_len: int, *, seq_sharded: bool = False):
        return stack_mod.stack_cache_defs(self.cfg, self.tp, batch, max_len,
                                          seq_sharded=seq_sharded)

    def init_cache(self, batch: int, max_len: int, *, seq_sharded: bool = False):
        return stack_mod.init_cache_value(
            self.cache_defs(batch, max_len, seq_sharded=seq_sharded))

    def prefill(self, params, tokens, ctx: ParallelContext, *, max_len: int,
                chunk: int = 2048):
        """tokens (B,S) -> (last-position logits (B, V/tp), caches)."""
        if self.cfg.is_encoder:
            raise ValueError(f"{self.cfg.name} is encoder-only: no decode path")
        x = embed_lookup(params["embed"], tokens, ctx, self.cfg.dtype)
        h, caches = stack_mod.stack_prefill(params["stack"], x, self.cfg, ctx,
                                            max_len=max_len, chunk=chunk)
        h = rms_norm(params["final_norm"], h[:, -1:], self.cfg.norm_eps)
        return self._unembed(params, h, ctx)[:, 0], caches

    def decode_step(self, params, token, caches, pos, ctx: ParallelContext, *,
                    max_len: int):
        """token (B,1) int32, pos scalar -> (logits (B, V/tp), new caches)."""
        if self.cfg.is_encoder:
            raise ValueError(f"{self.cfg.name} is encoder-only: no decode path")
        x = embed_lookup(params["embed"], token, ctx, self.cfg.dtype)
        h, caches = stack_mod.stack_decode(params["stack"], x, caches, pos,
                                           self.cfg, ctx, max_len)
        h = rms_norm(params["final_norm"], h, self.cfg.norm_eps)
        return self._unembed(params, h, ctx)[:, 0], caches

    def encode(self, params, batch, ctx: ParallelContext, *, chunk: int = 2048):
        """Encoder-only forward (hubert prefill_32k): returns frame logits."""
        x = self._embed_in(params, batch, ctx)
        h, _ = stack_mod.stack_train(params["stack"], x, self.cfg, ctx,
                                     remat_policy="none", chunk=chunk)
        h = rms_norm(params["final_norm"], h, self.cfg.norm_eps)
        return self._unembed(params, h, ctx)


def greedy_sample(logits_local, ctx: ParallelContext):
    """Argmax over a vocab-sharded logits row. logits_local: (B, V/tp)."""
    vloc = logits_local.shape[-1]
    lo = ctx.model_index() * vloc
    lmax = jnp.max(logits_local, axis=-1)
    larg = jnp.argmax(logits_local, axis=-1).astype(jnp.int32) + lo
    gmax = ctx.pmax_model(lmax)
    cand = jnp.where(lmax >= gmax, larg, jnp.int32(2**30))
    return -ctx.pmax_model(-cand)
