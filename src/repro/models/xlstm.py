"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

mLSTM runs in two equivalent forms (tested against each other):
  * parallel/quadratic for train & prefill — decay matrix D_ij built from
    cumulative log-forget-gates, q-chunked like attention;
  * recurrent for decode — stabilized (C, n, m) state per head.

Sharding (DESIGN.md): mLSTM value/output dim is column-sharded over "model"
(C and n shard on the value axis); q/k and the gate projections are
replicated. The sLSTM core (4-head block-diagonal recurrence, d=1024) is
replicated over "model" — it does not shard 16 ways — while its FFN shards
normally; the cost is documented in the roofline notes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import XLSTMConfig
from repro.models import params as pdefs
from repro.models.layers import cast
from repro.sharding.rules import ParallelContext, pad_to

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(d_model: int, num_heads: int, x: XLSTMConfig):
    di = pad_to(int(d_model * x.mlstm_proj_factor), 128)
    dh = di // num_heads
    # v / o / down are sharded on the PER-HEAD value dim (so every shard
    # keeps all heads — the decay matrix is per-head and must align with
    # the unsharded q/k head layout).
    return {
        "w_q": pdefs.linear(d_model, di),
        "w_k": pdefs.linear(d_model, di),
        "w_v": pdefs.ParamDef((d_model, num_heads, dh),
                              pdefs.P(None, None, "model"),
                              scale=d_model ** -0.5),
        "w_i": pdefs.linear(d_model, num_heads),
        "w_f": pdefs.linear(d_model, num_heads),
        "w_o": pdefs.ParamDef((d_model, num_heads, dh),
                              pdefs.P(None, None, "model"),
                              scale=d_model ** -0.5),
        "w_down": pdefs.ParamDef((num_heads, dh, d_model),
                                 pdefs.P(None, "model", None),
                                 scale=di ** -0.5),
    }


def _mlstm_qkvgates(p, x, num_heads, dtype):
    B, S, _ = x.shape
    di = p["w_q"].shape[1]
    dh = di // num_heads
    q = (x @ cast(p["w_q"], dtype)).reshape(B, S, num_heads, dh)
    k = (x @ cast(p["w_k"], dtype)).reshape(B, S, num_heads, dh)
    v = jnp.einsum("bsd,dhv->bshv", x, cast(p["w_v"], dtype))
    log_i = (x @ cast(p["w_i"], dtype)).astype(jnp.float32)           # (B,S,nh)
    log_f = jax.nn.log_sigmoid((x @ cast(p["w_f"], dtype)).astype(jnp.float32))
    return q, k, v, log_i, log_f, dh


def mlstm_train(p, x, num_heads: int, ctx: ParallelContext,
                dtype="bfloat16", chunk: int = 2048,
                return_state: bool = False):
    """Parallel (quadratic) stabilized mLSTM. x: (B,S,d)."""
    B, S, d = x.shape
    q, k, v, log_i, log_f, dh = _mlstm_qkvgates(p, x, num_heads, dtype)
    F = jnp.cumsum(log_f, axis=1)                                     # (B,S,nh)
    scale = dh ** -0.5

    n_chunks = max(S // chunk, 1)
    cs = S // n_chunks

    def body(_, ci):
        qi = lax.dynamic_slice_in_dim(q, ci * cs, cs, axis=1)
        Fi = lax.dynamic_slice_in_dim(F, ci * cs, cs, axis=1)
        ipos = ci * cs + jnp.arange(cs)
        # D_ij = F_i - F_j + log_i_j   (j <= i)
        D = Fi[:, :, None, :] - F[:, None, :, :] + log_i[:, None, :, :]
        mask = ipos[:, None] >= jnp.arange(S)[None, :]
        D = jnp.where(mask[None, :, :, None], D, NEG_INF)
        m = jnp.max(D, axis=2)                                        # (B,cs,nh)
        w = jnp.exp(D - m[:, :, None, :])
        s = jnp.einsum("bihd,bjhd->bijh", qi, k).astype(jnp.float32) * scale
        sw = s * w
        eta = jnp.sum(sw, axis=2)                                     # (B,cs,nh)
        denom = jnp.maximum(jnp.abs(eta), jnp.exp(-m))
        h = jnp.einsum("bijh,bjhv->bihv", sw.astype(v.dtype), v)
        return None, h / denom[..., None].astype(v.dtype)

    _, hs = lax.scan(body, None, jnp.arange(n_chunks))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(
        B, S, num_heads, -1)                           # (B,S,nh,dhv_local)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhv->bshv", x, cast(p["w_o"], dtype)))
    out = jnp.einsum("bshv,hvd->bsd", h.astype(jnp.dtype(dtype)) * o,
                     cast(p["w_down"], dtype))
    out = ctx.psum_model(out)
    if return_state:
        # final recurrent state: weights w_j = exp(F_S - F_j + log_i_j - m_S)
        rel = F[:, -1:, :] - F + log_i                  # (B,S,nh)
        m_fin = jnp.max(rel, axis=1)                    # (B,nh)
        wgt = jnp.exp(rel - m_fin[:, None, :])
        C = jnp.einsum("bsh,bshk,bshv->bhkv", wgt, k.astype(jnp.float32),
                       v.astype(jnp.float32))
        n = jnp.einsum("bsh,bshk->bhk", wgt, k.astype(jnp.float32))
        return out, MLSTMState(C=C, n=n, m=m_fin)
    return out


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, nh, dh_k, dh_v_local)
    n: jax.Array   # (B, nh, dh_k)
    m: jax.Array   # (B, nh)


def mlstm_train_chunkwise(p, x, num_heads: int, ctx: ParallelContext,
                          dtype="bfloat16", chunk: int = 256,
                          return_state: bool = False):
    """Chunkwise-recurrent mLSTM (xLSTM paper §A parallel-chunkwise form).

    O(S·c) score matrices instead of O(S²): within a chunk the quadratic
    stabilized form runs locally; across chunks a stabilized (C, n, m) state
    carries the prefix — identical numerics to ``mlstm_train`` (tested).
    """
    B, S, d = x.shape
    q, k, v, log_i, log_f, dh = _mlstm_qkvgates(p, x, num_heads, dtype)
    dhv = v.shape[-1]
    scale = dh ** -0.5
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c

    def resh(a):
        return a.reshape(B, nc, c, *a.shape[2:]).transpose(1, 0, 2,
                                                           *range(3, a.ndim + 1))

    qc, kc, vc = resh(q), resh(k), resh(v)                # (nc,B,c,nh,*)
    lic, lfc = resh(log_i), resh(log_f)                   # (nc,B,c,nh)

    # carry vma must match the body outputs: C mixes in the model-sharded v
    st0 = MLSTMState(
        C=jnp.zeros_like(v, shape=(B, num_heads, dh, dhv), dtype=jnp.float32),
        n=jnp.zeros_like(log_i, shape=(B, num_heads, dh)),
        m=jnp.full_like(log_i, -1e30, shape=(B, num_heads)))

    def body(st, inp):
        qi, ki, vi, li, lf = inp                          # (B,c,nh,*)
        Fl = jnp.cumsum(lf, axis=1)                       # (B,c,nh)
        Ftot = Fl[:, -1]                                  # (B,nh)
        # intra-chunk decay D_ij = Fl_i - Fl_j + li_j (j<=i)
        D = Fl[:, :, None, :] - Fl[:, None, :, :] + li[:, None, :, :]
        mask = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
        D = jnp.where(mask[None, :, :, None], D, NEG_INF)
        m_intra = jnp.max(D, axis=2)                      # (B,c,nh)
        m_inter = Fl + st.m[:, None, :]                   # (B,c,nh)
        m_row = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(D - m_row[:, :, None, :])
        s = jnp.einsum("bihd,bjhd->bijh", qi, ki).astype(jnp.float32) * scale
        sw = s * w
        num = jnp.einsum("bijh,bjhv->bihv", sw.astype(vi.dtype), vi)
        eta = jnp.sum(sw, axis=2)                         # (B,c,nh)
        # inter-chunk (prefix) contribution
        wi = jnp.exp(m_inter - m_row)                     # (B,c,nh)
        q32 = qi.astype(jnp.float32) * scale
        num = num.astype(jnp.float32) + \
            wi[..., None] * jnp.einsum("bihd,bhdv->bihv", q32, st.C)
        eta = eta + wi * jnp.einsum("bihd,bhd->bih", q32, st.n)
        denom = jnp.maximum(jnp.abs(eta), jnp.exp(-m_row))
        h = (num / denom[..., None]).astype(vi.dtype)     # (B,c,nh,dhv)
        # state update (stabilized)
        rel = Ftot[:, None, :] - Fl + li                  # (B,c,nh)
        m_new = jnp.maximum(Ftot + st.m, jnp.max(rel, axis=1))
        wk = jnp.exp(rel - m_new[:, None, :])
        carry_scale = jnp.exp(Ftot + st.m - m_new)
        C2 = carry_scale[..., None, None] * st.C + jnp.einsum(
            "bjh,bjhd,bjhv->bhdv", wk, ki.astype(jnp.float32),
            vi.astype(jnp.float32))
        n2 = carry_scale[..., None] * st.n + jnp.einsum(
            "bjh,bjhd->bhd", wk, ki.astype(jnp.float32))
        return MLSTMState(C=C2, n=n2, m=m_new), h

    st_fin, hs = lax.scan(body, st0, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, num_heads, -1)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhv->bshv", x, cast(p["w_o"], dtype)))
    out = jnp.einsum("bshv,hvd->bsd", h.astype(jnp.dtype(dtype)) * o,
                     cast(p["w_down"], dtype))
    out = ctx.psum_model(out)
    if return_state:
        return out, st_fin
    return out


def mlstm_decode(p, x, state: MLSTMState, num_heads: int,
                 ctx: ParallelContext, dtype="bfloat16"):
    """Stabilized recurrent step. x: (B,1,d)."""
    B = x.shape[0]
    q, k, v, log_i, log_f, dh = _mlstm_qkvgates(p, x, num_heads, dtype)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                # (B,nh,dh)
    log_i, log_f = log_i[:, 0], log_f[:, 0]            # (B,nh)
    m_new = jnp.maximum(log_f + state.m, log_i)
    fprime = jnp.exp(log_f + state.m - m_new)
    iprime = jnp.exp(log_i - m_new)
    C = fprime[..., None, None] * state.C + \
        iprime[..., None, None] * (k[..., :, None] * v[..., None, :]).astype(state.C.dtype)
    n = fprime[..., None] * state.n + iprime[..., None] * k.astype(state.n.dtype)
    scale = dh ** -0.5
    hnum = jnp.einsum("bhkv,bhk->bhv", C, q.astype(C.dtype) * scale)
    eta = jnp.einsum("bhk,bhk->bh", n, q.astype(n.dtype) * scale)
    denom = jnp.maximum(jnp.abs(eta), jnp.exp(-m_new))
    h = (hnum / denom[..., None])[:, None]             # (B,1,nh,dhv_local)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhv->bshv", x, cast(p["w_o"], dtype)))
    out = jnp.einsum("bshv,hvd->bsd", h.astype(jnp.dtype(dtype)) * o,
                     cast(p["w_down"], dtype))
    return ctx.psum_model(out), MLSTMState(C=C, n=n, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(d_model: int, num_heads: int, x: XLSTMConfig):
    dh = d_model // num_heads
    dff = pad_to(int(d_model * x.slstm_proj_factor), 128)
    from repro.models.layers import ffn_defs
    return {
        "w_in": pdefs.linear(d_model, 4 * d_model),                  # i,f,z,o
        "r": pdefs.ParamDef((4, num_heads, dh, dh), pdefs.P(), scale=dh ** -0.5),
        "b": pdefs.bias(4 * d_model),
        "norm": pdefs.norm_scale(d_model),
        "ffn": ffn_defs(d_model, dff),
    }


class SLSTMState(NamedTuple):
    h: jax.Array   # (B, d)
    c: jax.Array
    n: jax.Array
    m: jax.Array


def _slstm_step(p4r, pre, st: SLSTMState, num_heads: int):
    """pre: (B,4d) input preactivations; p4r: (4,nh,dh,dh) recurrent mats."""
    B, d4 = pre.shape
    d = d4 // 4
    dh = d // num_heads
    hh = st.h.reshape(B, num_heads, dh)
    rec = jnp.einsum("bhk,ghkl->bghl", hh, p4r).reshape(B, 4, d)
    z = pre.reshape(B, 4, d) + rec
    it, ft, zt, ot = z[:, 0], z[:, 1], z[:, 2], z[:, 3]
    m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + st.m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(jax.nn.log_sigmoid(ft) + st.m - m_new)
    c = fp * st.c + ip * jnp.tanh(zt)
    n = fp * st.n + ip
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(h=h, c=c, n=n, m=m_new)


def slstm_train(p, x, num_heads: int, ctx: ParallelContext, dtype="bfloat16",
                return_state: bool = False):
    """Sequential sLSTM over the sequence. x: (B,S,d). Core replicated."""
    from repro.models.layers import ffn_apply, rms_norm
    B, S, d = x.shape
    pre = (x @ cast(p["w_in"], dtype) + cast(p["b"], dtype)).astype(jnp.float32)
    # zeros_like(pre, ...) keeps the vma (client/data-varying) tag so the
    # scan carry types match the body outputs under shard_map.
    st0 = SLSTMState(h=jnp.zeros_like(pre, shape=(B, d)),
                     c=jnp.zeros_like(pre, shape=(B, d)),
                     n=jnp.zeros_like(pre, shape=(B, d)),
                     m=jnp.full_like(pre, -1e30, shape=(B, d)))
    r = p["r"].astype(jnp.float32)

    def body(st, pre_t):
        st2 = _slstm_step(r, pre_t, st, num_heads)
        return st2, st2.h

    st_fin, hs = lax.scan(body, st0, pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(jnp.dtype(dtype))
    h = rms_norm(p["norm"], h)
    out = ffn_apply(p["ffn"], h, ctx, dtype=dtype)
    if return_state:
        return out, st_fin
    return out


def slstm_decode(p, x, state: SLSTMState, num_heads: int,
                 ctx: ParallelContext, dtype="bfloat16"):
    from repro.models.layers import ffn_apply, rms_norm
    pre = (x[:, 0] @ cast(p["w_in"], dtype) + cast(p["b"], dtype)).astype(jnp.float32)
    st = _slstm_step(p["r"].astype(jnp.float32), pre, state, num_heads)
    h = rms_norm(p["norm"], st.h.astype(jnp.dtype(dtype)))[:, None, :]
    return ffn_apply(p["ffn"], h, ctx, dtype=dtype), st
