"""Mixture-of-Experts FFN with expert parallelism over the "model" axis.

TPU-native dispatch: instead of a (tokens × experts × capacity) one-hot
einsum (VMEM-hostile at DeepSeek scale) we use *per-expert top-C gather*:

  1. router logits -> global top-k gates per token (replicated math),
  2. each model shard owns E/tp experts; for each local expert, take the
     top-C tokens by gate (C = capacity_factor · T·k/E),
  3. gather those tokens, run the expert FFN batched over local experts,
  4. scatter-add weighted outputs and psum over "model" to combine shards.

Tokens beyond capacity are dropped (standard capacity-style MoE); the smoke
tests check the dispatch against a dense reference modulo drops.

Experts are padded up to a multiple of tp (qwen2-moe: 60 -> 64) with their
router logits pinned to -inf.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MoEConfig
from repro.models import params as pdefs
from repro.models.layers import activation, cast, softcap
from repro.sharding.rules import ParallelContext, pad_to


def moe_defs(d_model: int, mo: MoEConfig, tp: int, act: str = "silu"):
    E = pad_to(mo.num_experts, tp)
    dfe = mo.d_ff_expert
    defs = {
        "router": pdefs.linear(d_model, E),
        # stacked expert weights, expert dim sharded over "model"
        "w_up": pdefs.ParamDef((E, d_model, dfe), pdefs.P("model", None, None),
                               scale=d_model ** -0.5),
        "w_gate": pdefs.ParamDef((E, d_model, dfe), pdefs.P("model", None, None),
                                 scale=d_model ** -0.5),
        "w_down": pdefs.ParamDef((E, dfe, d_model), pdefs.P("model", None, None),
                                 scale=dfe ** -0.5),
    }
    if mo.num_shared_experts:
        dfs = mo.d_ff_shared or mo.num_shared_experts * dfe
        from repro.models.layers import ffn_defs
        defs["shared"] = ffn_defs(d_model, dfs, act)
    return defs


def router_probs(p, x, mo: MoEConfig, dtype):
    """(T,d) -> (T,E) softmax probs with padded experts masked out."""
    logits = (x @ cast(p["router"], dtype)).astype(jnp.float32)
    logits = softcap(logits, mo.router_softcap)
    E = logits.shape[-1]
    if E > mo.num_experts:
        pad_mask = jnp.arange(E) >= mo.num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    return jax.nn.softmax(logits, axis=-1)


def moe_ffn(p, x, mo: MoEConfig, ctx: ParallelContext, *,
            act: str = "silu", dtype="bfloat16") -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    probs = router_probs(p, xf, mo, dtype)                    # (T,E)
    E = probs.shape[-1]
    gates, top_idx = lax.top_k(probs, mo.top_k)               # (T,k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # dense (T,E) gate matrix restricted to the top-k choices
    sel = jnp.zeros((T, E), jnp.float32)
    sel = jax.vmap(lambda s, i, g: s.at[i].set(g))(sel, top_idx, gates)

    E_loc = p["w_up"].shape[0]                                # E / tp locally
    e0 = ctx.model_index() * E_loc
    sel_loc = lax.dynamic_slice_in_dim(sel, e0, E_loc, axis=1)
    # per local expert: top-C tokens by gate
    C = max(1, min(T, int(mo.capacity_factor * mo.top_k * T / E + 0.999)))
    w_tok, tok_idx = lax.top_k(sel_loc.T, C)                  # (E_loc, C)
    valid = w_tok > 0.0

    xg = jnp.take(xf, tok_idx.reshape(-1), axis=0).reshape(E_loc, C, d)
    up = jnp.einsum("ecd,edf->ecf", xg, cast(p["w_up"], dtype))
    gt = jnp.einsum("ecd,edf->ecf", xg, cast(p["w_gate"], dtype))
    h = activation(gt, act) * up
    ye = jnp.einsum("ecf,efd->ecd", h, cast(p["w_down"], dtype))
    ye = ye * (w_tok * valid)[..., None].astype(ye.dtype)

    out = jnp.zeros((T, d), ye.dtype).at[tok_idx.reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    out = ctx.psum_model(out)

    if "shared" in p:
        from repro.models.layers import ffn_apply
        out = out + ffn_apply(p["shared"], xf, ctx, act=act, dtype=dtype).astype(out.dtype)

    # switch-style load balance aux loss (over true experts only)
    Et = mo.num_experts
    frac = jnp.mean(sel[:, :Et] > 0, axis=0)                  # fraction routed
    imp = jnp.mean(probs[:, :Et], axis=0)                     # mean router prob
    aux = mo.aux_loss_weight * Et * jnp.sum(frac * imp)
    return out.reshape(B, S, d).astype(x.dtype), aux


def moe_ffn_dense_ref(p, x, mo: MoEConfig, ctx: ParallelContext, *,
                      act: str = "silu", dtype="float32"):
    """Dense reference (every expert on every token) for tests. tp=1 only."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    probs = router_probs(p, xf, mo, dtype)
    gates, top_idx = lax.top_k(probs, mo.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    E = probs.shape[-1]
    sel = jnp.zeros((xf.shape[0], E), jnp.float32)
    sel = jax.vmap(lambda s, i, g: s.at[i].set(g))(sel, top_idx, gates)
    up = jnp.einsum("td,edf->etf", xf, cast(p["w_up"], dtype))
    gt = jnp.einsum("td,edf->etf", xf, cast(p["w_gate"], dtype))
    h = activation(gt, act) * up
    ye = jnp.einsum("etf,efd->etd", h, cast(p["w_down"], dtype))
    out = jnp.einsum("etd,te->td", ye, sel.astype(ye.dtype))
    if "shared" in p:
        from repro.models.layers import ffn_apply
        out = out + ffn_apply(p["shared"], xf, ctx, act=act, dtype=dtype).astype(out.dtype)
    return out.reshape(B, S, d).astype(x.dtype)
