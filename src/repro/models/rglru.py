"""Griffin recurrent block: gated temporal conv + RG-LRU (arXiv:2402.19427).

The RG-LRU recurrence  h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t)  is
elementwise over the width dimension, so it shards perfectly over the
"model" axis and parallelizes over sequence with ``lax.associative_scan``.

Deviation (DESIGN.md): the input/recurrence gates use diagonal (per-channel)
weights rather than Griffin's block-diagonal ones — the recurrence structure,
sqrt(1-a²) input normalization and the c=8 decay constant are as published.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import RGLRUConfig
from repro.models import params as pdefs
from repro.models.layers import cast
from repro.sharding.rules import ParallelContext


def rglru_defs(d_model: int, r: RGLRUConfig):
    w = r.lru_width or d_model
    return {
        "w_gate": pdefs.linear(d_model, w, shard="model"),
        "w_x": pdefs.linear(d_model, w, shard="model"),
        "conv_k": pdefs.ParamDef((r.conv_width, w), pdefs.P(None, "model"),
                                 scale=r.conv_width ** -0.5),
        "lam": pdefs.ParamDef((w,), pdefs.P("model"), scale=1.0),
        "w_rg": pdefs.ParamDef((w,), pdefs.P("model"), scale=1.0),
        "b_rg": pdefs.bias(w, shard="model"),
        "w_ig": pdefs.ParamDef((w,), pdefs.P("model"), scale=1.0),
        "b_ig": pdefs.bias(w, shard="model"),
        "w_out": pdefs.linear(w, d_model, shard="model", shard_dim=0),
    }


def _causal_conv(x, kern, state=None):
    """Depthwise causal conv over seq. x:(B,S,w), kern:(cw,w).
    state: (B,cw-1,w) previous inputs for decode; returns (y, new_state)."""
    cw = kern.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * kern[cw - 1 - i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad
    return y, new_state


def _gates(p, xc, r: RGLRUConfig):
    rg = jax.nn.sigmoid(xc * p["w_rg"] + p["b_rg"])
    ig = jax.nn.sigmoid(xc * p["w_ig"] + p["b_ig"])
    log_a = -r.c_constant * jax.nn.softplus(p["lam"]) * rg   # (B,S,w) fp32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (ig * xc)
    return a, b


class RGLRUState(NamedTuple):
    h: jax.Array           # (B, w_local)
    conv: jax.Array        # (B, cw-1, w_local)


def rglru_train(p, x, r: RGLRUConfig, ctx: ParallelContext, dtype="bfloat16",
                return_state: bool = False):
    """x: (B,S,d) -> (B,S,d) [, final RGLRUState]."""
    gate = jax.nn.gelu(x @ cast(p["w_gate"], dtype))
    xb = x @ cast(p["w_x"], dtype)
    xc, conv_state = _causal_conv(xb, cast(p["conv_k"], dtype))
    xc32 = xc.astype(jnp.float32)
    a, b = _gates(p, xc32, r)

    def combine(l, rr):
        a1, b1 = l
        a2, b2 = rr
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    out = (gate * h.astype(dtype)) @ cast(p["w_out"], dtype)
    out = ctx.psum_model(out)
    if return_state:
        return out, RGLRUState(h=h[:, -1], conv=conv_state)
    return out


def rglru_decode(p, x, state: RGLRUState, r: RGLRUConfig,
                 ctx: ParallelContext, dtype="bfloat16"):
    """One-step decode. x: (B,1,d)."""
    gate = jax.nn.gelu(x @ cast(p["w_gate"], dtype))
    xb = x @ cast(p["w_x"], dtype)
    xc, conv_state = _causal_conv(xb, cast(p["conv_k"], dtype), state.conv)
    a, b = _gates(p, xc.astype(jnp.float32), r)
    h = a[:, 0] * state.h + b[:, 0]
    out = (gate[:, 0] * h.astype(dtype)) @ cast(p["w_out"], dtype)
    out = ctx.psum_model(out)[:, None, :]
    return out, RGLRUState(h=h, conv=conv_state.astype(state.conv.dtype))
