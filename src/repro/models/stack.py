"""Layer-stack assembly: periodic layer groups scanned with ``lax.scan``.

Architectures mix block kinds (attention windows alternate in gemma-2,
RG-LRU/attention alternate 2:1 in recurrentgemma, mLSTM/sLSTM in xLSTM). We
find the minimal period of the per-layer (kind, window) descriptor list and
scan over whole periods — every branch inside the scan body is *static*, so
the compiled HLO contains each distinct layer kind exactly once regardless
of depth. Leftover layers (26 = 8x(rec,rec,attn)+2) run unscanned as a tail.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import params as pdefs
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import ffn_apply, ffn_defs, rms_norm
from repro.sharding.rules import AttnDims, ParallelContext, attn_dims


@dataclass(frozen=True)
class LayerDesc:
    kind: str      # attn | rglru | mlstm | slstm
    window: int    # 0 = global (attn only)


def plan_stack(cfg: ModelConfig) -> Tuple[Tuple[LayerDesc, ...], int, Tuple[LayerDesc, ...]]:
    """-> (group_pattern, n_groups, tail_layers)."""
    descs = [LayerDesc(k, w) for k, w in zip(cfg.layer_kinds, cfg.layer_windows)]
    L = len(descs)
    for p in range(1, L + 1):
        n = L // p
        if n == 0:
            continue
        if all(descs[i] == descs[i % p] for i in range(n * p)):
            return tuple(descs[:p]), n, tuple(descs[n * p:])
    return tuple(descs), 1, ()


# ---------------------------------------------------------------------------
# Per-layer defs / apply
# ---------------------------------------------------------------------------


def layer_defs(cfg: ModelConfig, desc: LayerDesc, dims: AttnDims, tp: int):
    d = cfg.d_model
    defs = {"norm1": pdefs.norm_scale(d)}
    if desc.kind == "attn":
        if cfg.mla is not None:
            defs["mix"] = mla_mod.mla_defs(d, cfg.num_heads, cfg.mla, tp)
        else:
            defs["mix"] = attn.attn_defs(d, dims, qkv_bias=cfg.qkv_bias)
        defs["norm2"] = pdefs.norm_scale(d)
        if cfg.moe is not None:
            defs["mlp"] = moe_mod.moe_defs(d, cfg.moe, tp, cfg.act)
        elif cfg.d_ff > 0:
            defs["mlp"] = ffn_defs(d, cfg.d_ff, cfg.act, cfg.gated_ffn)
    elif desc.kind == "rglru":
        defs["mix"] = rglru_mod.rglru_defs(d, cfg.rglru)
        if cfg.d_ff > 0:
            defs["norm2"] = pdefs.norm_scale(d)
            defs["mlp"] = ffn_defs(d, cfg.d_ff, cfg.act, cfg.gated_ffn)
    elif desc.kind == "mlstm":
        defs["mix"] = xlstm_mod.mlstm_defs(d, cfg.num_heads, cfg.xlstm)
    elif desc.kind == "slstm":
        defs["mix"] = xlstm_mod.slstm_defs(d, cfg.num_heads, cfg.xlstm)
    else:
        raise ValueError(desc.kind)
    return defs


def layer_train(p, x, cfg: ModelConfig, desc: LayerDesc, dims: AttnDims,
                ctx: ParallelContext, chunk: int = 2048):
    """One layer, full sequence. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = ctx.tp_copy(rms_norm(p["norm1"], x, cfg.norm_eps))
    if desc.kind == "attn":
        if cfg.mla is not None:
            out = mla_mod.mla_train(p["mix"], h, cfg.mla, ctx,
                                    rope_theta=cfg.rope_theta, cap=cfg.attn_softcap,
                                    dtype=cfg.dtype, chunk=chunk)
        else:
            out, _ = attn.attn_train(p["mix"], h, dims, ctx,
                                     causal=not cfg.is_encoder, window=desc.window,
                                     cap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
                                     dtype=cfg.dtype, chunk=chunk)
        x = x + out
        h2 = ctx.tp_copy(rms_norm(p["norm2"], x, cfg.norm_eps))
        if cfg.moe is not None:
            out2, aux = moe_mod.moe_ffn(p["mlp"], h2, cfg.moe, ctx,
                                        act=cfg.act, dtype=cfg.dtype)
        else:
            out2 = ffn_apply(p["mlp"], h2, ctx, act=cfg.act, dtype=cfg.dtype)
        return x + out2, aux
    if desc.kind == "rglru":
        x = x + rglru_mod.rglru_train(p["mix"], h, cfg.rglru, ctx, cfg.dtype)
        if "mlp" in p:
            h2 = ctx.tp_copy(rms_norm(p["norm2"], x, cfg.norm_eps))
            x = x + ffn_apply(p["mlp"], h2, ctx, act=cfg.act, dtype=cfg.dtype)
        return x, aux
    if desc.kind == "mlstm":
        if cfg.xlstm.chunkwise:
            return x + xlstm_mod.mlstm_train_chunkwise(
                p["mix"], h, cfg.num_heads, ctx, cfg.dtype,
                chunk=cfg.xlstm.chunk_size), aux
        return x + xlstm_mod.mlstm_train(p["mix"], h, cfg.num_heads, ctx,
                                         cfg.dtype, chunk=chunk), aux
    if desc.kind == "slstm":
        return x + xlstm_mod.slstm_train(p["mix"], h, cfg.num_heads, ctx,
                                         cfg.dtype), aux
    raise ValueError(desc.kind)


def layer_prefill(p, x, cfg: ModelConfig, desc: LayerDesc, dims: AttnDims,
                  ctx: ParallelContext, max_len: int, chunk: int = 2048):
    """Full-sequence forward that also emits the layer's decode cache."""
    h = ctx.tp_copy(rms_norm(p["norm1"], x, cfg.norm_eps))
    if desc.kind == "attn":
        C = min(desc.window, max_len) if desc.window > 0 else max_len
        if cfg.mla is not None:
            out, cache = mla_mod.mla_train(
                p["mix"], h, cfg.mla, ctx, rope_theta=cfg.rope_theta,
                cap=cfg.attn_softcap, dtype=cfg.dtype, chunk=chunk,
                return_cache_len=C)
            cache = {"c_kv": cache.c_kv, "k_rope": cache.k_rope}
        else:
            out, kv = attn.attn_train(
                p["mix"], h, dims, ctx, causal=not cfg.is_encoder,
                window=desc.window, cap=cfg.attn_softcap,
                rope_theta=cfg.rope_theta, dtype=cfg.dtype, chunk=chunk,
                return_cache_len=C)
            cache = {"k": kv[0], "v": kv[1]}
        x = x + out
        h2 = ctx.tp_copy(rms_norm(p["norm2"], x, cfg.norm_eps))
        if cfg.moe is not None:
            out2, _ = moe_mod.moe_ffn(p["mlp"], h2, cfg.moe, ctx,
                                      act=cfg.act, dtype=cfg.dtype)
        else:
            out2 = ffn_apply(p["mlp"], h2, ctx, act=cfg.act, dtype=cfg.dtype)
        return x + out2, cache
    if desc.kind == "rglru":
        out, st = rglru_mod.rglru_train(p["mix"], h, cfg.rglru, ctx, cfg.dtype,
                                        return_state=True)
        x = x + out
        if "mlp" in p:
            h2 = ctx.tp_copy(rms_norm(p["norm2"], x, cfg.norm_eps))
            x = x + ffn_apply(p["mlp"], h2, ctx, act=cfg.act, dtype=cfg.dtype)
        return x, {"h": st.h, "conv": st.conv}
    if desc.kind == "mlstm":
        if cfg.xlstm.chunkwise:
            out, st = xlstm_mod.mlstm_train_chunkwise(
                p["mix"], h, cfg.num_heads, ctx, cfg.dtype,
                chunk=cfg.xlstm.chunk_size, return_state=True)
        else:
            out, st = xlstm_mod.mlstm_train(p["mix"], h, cfg.num_heads, ctx,
                                            cfg.dtype, chunk=chunk,
                                            return_state=True)
        return x + out, {"C": st.C, "n": st.n, "m": st.m}
    if desc.kind == "slstm":
        out, st = xlstm_mod.slstm_train(p["mix"], h, cfg.num_heads, ctx,
                                        cfg.dtype, return_state=True)
        return x + out, {"h": st.h, "c": st.c, "n": st.n, "m": st.m}
    raise ValueError(desc.kind)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def layer_cache_defs(cfg: ModelConfig, desc: LayerDesc, dims: AttnDims,
                     batch: int, max_len: int, *, seq_sharded: bool):
    """ParamDef tree describing one layer's decode state (GLOBAL shapes)."""
    d = cfg.d_model
    bspec = None if seq_sharded else "data"  # batch sharded unless seq-sharded
    if desc.kind == "attn":
        C = min(desc.window, max_len) if desc.window > 0 else max_len
        sspec = "data" if (seq_sharded and C == max_len) else None
        if cfg.mla is not None:
            m = cfg.mla
            return {
                "c_kv": pdefs.ParamDef((batch, C, m.kv_lora_rank),
                                       P(bspec, sspec, None), dtype=cfg.dtype),
                "k_rope": pdefs.ParamDef((batch, C, m.rope_head_dim),
                                         P(bspec, sspec, None), dtype=cfg.dtype),
            }
        kvspec = "model" if dims.kv_sharded else None
        kvh = dims.kv_heads if dims.kv_sharded else dims.kv_local
        return {
            "k": pdefs.ParamDef((batch, C, kvh, dims.head_dim),
                                P(bspec, sspec, kvspec, None), dtype=cfg.dtype),
            "v": pdefs.ParamDef((batch, C, kvh, dims.head_dim),
                                P(bspec, sspec, kvspec, None), dtype=cfg.dtype),
        }
    if desc.kind == "rglru":
        w = cfg.rglru.lru_width or d
        cw = cfg.rglru.conv_width
        return {
            "h": pdefs.ParamDef((batch, w), P(bspec, "model"), dtype="float32"),
            "conv": pdefs.ParamDef((batch, cw - 1, w), P(bspec, None, "model"),
                                   dtype=cfg.dtype),
        }
    if desc.kind == "mlstm":
        from repro.sharding.rules import pad_to
        di = pad_to(int(d * cfg.xlstm.mlstm_proj_factor), 128)
        nh = cfg.num_heads
        dh = di // nh
        return {
            "C": pdefs.ParamDef((batch, nh, dh, di // nh),
                                P(bspec, None, None, "model"), dtype="float32"),
            "n": pdefs.ParamDef((batch, nh, dh), P(bspec, None, None),
                                dtype="float32"),
            "m": pdefs.ParamDef((batch, nh), P(bspec, None), dtype="float32"),
        }
    if desc.kind == "slstm":
        return {k: pdefs.ParamDef((batch, d), P(bspec, None), dtype="float32")
                for k in ("h", "c", "n", "m")}
    raise ValueError(desc.kind)


def init_cache_value(defs):
    """Zero-initialized concrete cache (m-states get -1e30)."""

    def mk(path, dx):
        name = jax.tree_util.keystr(path)
        if name.endswith("'m']"):
            return jnp.full(dx.shape, -1e30, jnp.dtype(dx.dtype))
        return jnp.zeros(dx.shape, jnp.dtype(dx.dtype))

    flat, td = jax.tree_util.tree_flatten_with_path(defs, is_leaf=pdefs.is_def)
    return jax.tree_util.tree_unflatten(td, [mk(p, d) for p, d in flat])


def layer_decode(p, x, cache, pos, cfg: ModelConfig, desc: LayerDesc,
                 dims: AttnDims, ctx: ParallelContext, max_len: int):
    """One-token decode through one layer. Returns (x, new_cache)."""
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    if desc.kind == "attn":
        C = min(desc.window, max_len) if desc.window > 0 else max_len
        lctx = ctx if C == max_len else ctx.with_(seq_axis=None, seq_shards=1)
        if cfg.mla is not None:
            out, nc = mla_mod.mla_decode(
                p["mix"], h, mla_mod.MLACache(cache["c_kv"], cache["k_rope"]),
                pos, cfg.mla, lctx, rope_theta=cfg.rope_theta, total_len=C,
                cap=cfg.attn_softcap, dtype=cfg.dtype)
            new_cache = {"c_kv": nc.c_kv, "k_rope": nc.k_rope}
        else:
            out, nc = attn.attn_decode(
                p["mix"], h, attn.KVCache(cache["k"], cache["v"]), pos, dims,
                lctx, window=desc.window, cap=cfg.attn_softcap,
                rope_theta=cfg.rope_theta, total_len=C, dtype=cfg.dtype)
            new_cache = {"k": nc.k, "v": nc.v}
        x = x + out
        h2 = ctx.tp_copy(rms_norm(p["norm2"], x, cfg.norm_eps))
        if cfg.moe is not None:
            out2, _ = moe_mod.moe_ffn(p["mlp"], h2, cfg.moe, ctx,
                                      act=cfg.act, dtype=cfg.dtype)
        else:
            out2 = ffn_apply(p["mlp"], h2, ctx, act=cfg.act, dtype=cfg.dtype)
        return x + out2, new_cache
    if desc.kind == "rglru":
        out, st = rglru_mod.rglru_decode(
            p["mix"], h, rglru_mod.RGLRUState(cache["h"], cache["conv"]),
            cfg.rglru, ctx, cfg.dtype)
        x = x + out
        if "mlp" in p:
            h2 = ctx.tp_copy(rms_norm(p["norm2"], x, cfg.norm_eps))
            x = x + ffn_apply(p["mlp"], h2, ctx, act=cfg.act, dtype=cfg.dtype)
        return x, {"h": st.h, "conv": st.conv}
    if desc.kind == "mlstm":
        out, st = xlstm_mod.mlstm_decode(
            p["mix"], h, xlstm_mod.MLSTMState(cache["C"], cache["n"], cache["m"]),
            cfg.num_heads, ctx, cfg.dtype)
        return x + out, {"C": st.C, "n": st.n, "m": st.m}
    if desc.kind == "slstm":
        out, st = xlstm_mod.slstm_decode(
            p["mix"], h, xlstm_mod.SLSTMState(cache["h"], cache["c"],
                                              cache["n"], cache["m"]),
            cfg.num_heads, ctx, cfg.dtype)
        return x + out, {"h": st.h, "c": st.c, "n": st.n, "m": st.m}
    raise ValueError(desc.kind)


# ---------------------------------------------------------------------------
# Stack defs / apply
# ---------------------------------------------------------------------------


def stack_defs(cfg: ModelConfig, tp: int):
    group, n_groups, tail = plan_stack(cfg)
    dims = attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, tp)
    gdefs = {f"l{j}": layer_defs(cfg, desc, dims, tp) for j, desc in enumerate(group)}
    out = {"groups": pdefs.stack_defs(gdefs, n_groups)}
    if tail:
        out["tail"] = {f"t{j}": layer_defs(cfg, desc, dims, tp)
                       for j, desc in enumerate(tail)}
    return out


def stack_cache_defs(cfg: ModelConfig, tp: int, batch: int, max_len: int,
                     *, seq_sharded: bool):
    group, n_groups, tail = plan_stack(cfg)
    dims = attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, tp)
    gdefs = {f"l{j}": layer_cache_defs(cfg, desc, dims, batch, max_len,
                                       seq_sharded=seq_sharded)
             for j, desc in enumerate(group)}
    out = {"groups": pdefs.stack_defs(gdefs, n_groups)}
    if tail:
        out["tail"] = {f"t{j}": layer_cache_defs(cfg, desc, dims, batch, max_len,
                                                 seq_sharded=seq_sharded)
                       for j, desc in enumerate(tail)}
    return out


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def stack_train(p, x, cfg: ModelConfig, ctx: ParallelContext, *,
                remat_policy: str = "full", chunk: int = 2048):
    """Run all layers over a full sequence. Returns (x, total_aux_loss)."""
    group, n_groups, tail = plan_stack(cfg)
    dims = attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                     max(ctx.tp, 1))

    def group_fn(x, gp):
        aux = jnp.zeros((), jnp.float32)
        for j, desc in enumerate(group):
            x, a = layer_train(gp[f"l{j}"], x, cfg, desc, dims, ctx, chunk)
            aux = aux + a
        return x, aux

    gfn = _remat(group_fn, remat_policy)

    def body(carry, gp):
        x, aux = carry
        x, a = gfn(x, gp)
        return (x, aux + a), None

    # aux carry must match the body's varying-manual-axes type (vma):
    # derive it from x so it inherits the client/data-varying tag.
    aux0 = jnp.zeros_like(x, shape=(), dtype=jnp.float32)
    (x, aux), _ = lax.scan(body, (x, aux0), p["groups"])
    for j, desc in enumerate(tail):
        x, a = layer_train(p["tail"][f"t{j}"], x, cfg, desc, dims, ctx, chunk)
        aux = aux + a
    return x, aux


def stack_prefill(p, x, cfg: ModelConfig, ctx: ParallelContext, *,
                  max_len: int, chunk: int = 2048):
    """Full-sequence forward emitting decode caches. Returns (x, caches)."""
    group, n_groups, tail = plan_stack(cfg)
    dims = attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                     max(ctx.tp, 1))

    def body(x, gp):
        cs = {}
        for j, desc in enumerate(group):
            x, c = layer_prefill(gp[f"l{j}"], x, cfg, desc, dims, ctx,
                                 max_len, chunk)
            cs[f"l{j}"] = c
        return x, cs

    x, group_caches = lax.scan(body, x, p["groups"])
    caches = {"groups": group_caches}
    if tail:
        ct = {}
        for j, desc in enumerate(tail):
            x, c = layer_prefill(p["tail"][f"t{j}"], x, cfg, desc, dims, ctx,
                                 max_len, chunk)
            ct[f"t{j}"] = c
        caches["tail"] = ct
    return x, caches


def stack_decode(p, x, caches, pos, cfg: ModelConfig, ctx: ParallelContext,
                 max_len: int):
    """One-token decode through the whole stack. Returns (x, new_caches)."""
    group, n_groups, tail = plan_stack(cfg)
    dims = attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                     max(ctx.tp, 1))

    def body(x, inp):
        gp, gc = inp
        ncs = {}
        for j, desc in enumerate(group):
            x, nc = layer_decode(gp[f"l{j}"], x, gc[f"l{j}"], pos, cfg, desc,
                                 dims, ctx, max_len)
            ncs[f"l{j}"] = nc
        return x, ncs

    x, new_group_caches = lax.scan(body, x, (p["groups"], caches["groups"]))
    new_caches = {"groups": new_group_caches}
    if tail:
        nt = {}
        for j, desc in enumerate(tail):
            x, nc = layer_decode(p["tail"][f"t{j}"], x, caches["tail"][f"t{j}"],
                                 pos, cfg, desc, dims, ctx, max_len)
            nt[f"t{j}"] = nc
        new_caches["tail"] = nt
    return x, new_caches
