"""ConvMixer (Trockman & Kolter 2022) — the paper's second evaluation model —
plus a small MLP classifier. These run the *paper-faithful* federated
benchmarks (CIFAR-shaped synthetic data) on CPU; they use the simulation path
of the FL core (no tensor parallelism), so ``ctx`` is unused here.

Simplification vs the reference ConvMixer: BatchNorm is replaced by a
per-channel scale+bias (no cross-client batch statistics — BN is known to be
problematic in FL anyway, see e.g. FedBN)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import params as pdefs


@dataclass(frozen=True)
class ConvMixerConfig:
    dim: int = 256
    depth: int = 8
    kernel: int = 9
    patch: int = 2
    num_classes: int = 10
    image: int = 32
    channels: int = 3


def convmixer_defs(c: ConvMixerConfig):
    d = {
        "patch_w": pdefs.ParamDef((c.patch, c.patch, c.channels, c.dim),
                                  scale=(c.patch * c.patch * c.channels) ** -0.5),
        "patch_b": pdefs.bias(c.dim),
        "head": pdefs.linear(c.dim, c.num_classes),
        "head_b": pdefs.bias(c.num_classes),
    }
    for i in range(c.depth):
        d[f"block{i}"] = {
            "dw": pdefs.ParamDef((c.kernel, c.kernel, 1, c.dim),
                                 scale=(c.kernel * c.kernel) ** -0.5),
            "dw_s": pdefs.norm_scale(c.dim), "dw_b": pdefs.bias(c.dim),
            "pw": pdefs.linear(c.dim, c.dim),
            "pw_s": pdefs.norm_scale(c.dim), "pw_b": pdefs.bias(c.dim),
        }
    return d


def _depthwise(x, w):
    # x: (B,H,W,C), w: (k,k,1,C)
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", feature_group_count=x.shape[-1],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def convmixer_apply(p, images, c: ConvMixerConfig):
    x = jax.lax.conv_general_dilated(
        images, p["patch_w"], (c.patch, c.patch), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["patch_b"]
    x = jax.nn.gelu(x)
    for i in range(c.depth):
        b = p[f"block{i}"]
        h = jax.nn.gelu(_depthwise(x, b["dw"])) * b["dw_s"] + b["dw_b"]
        x = x + h
        x = jax.nn.gelu(x @ b["pw"]) * b["pw_s"] + b["pw_b"]
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["head"] + p["head_b"]


def convmixer_loss(p, batch, c: ConvMixerConfig):
    logits = convmixer_apply(p, batch["x"], c)
    ce = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits),
                                       batch["y"][:, None], axis=-1))
    acc = jnp.mean(jnp.argmax(logits, -1) == batch["y"])
    return ce, {"acc": acc}


# -- tiny MLP for fast optimizer-level benchmarks ---------------------------


@dataclass(frozen=True)
class MLPConfig:
    in_dim: int = 64
    hidden: int = 128
    depth: int = 2
    num_classes: int = 10


def mlp_defs(c: MLPConfig):
    d = {}
    prev = c.in_dim
    for i in range(c.depth):
        d[f"w{i}"] = pdefs.linear(prev, c.hidden)
        d[f"b{i}"] = pdefs.bias(c.hidden)
        prev = c.hidden
    d["w_out"] = pdefs.linear(prev, c.num_classes)
    d["b_out"] = pdefs.bias(c.num_classes)
    return d


def mlp_apply(p, x, c: MLPConfig):
    for i in range(c.depth):
        x = jax.nn.relu(x @ p[f"w{i}"] + p[f"b{i}"])
    return x @ p["w_out"] + p["b_out"]


def mlp_loss(p, batch, c: MLPConfig):
    logits = mlp_apply(p, batch["x"], c)
    ce = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits),
                                       batch["y"][:, None], axis=-1))
    acc = jnp.mean(jnp.argmax(logits, -1) == batch["y"])
    return ce, {"acc": acc}
