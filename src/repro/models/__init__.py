from repro.models.model import Model, greedy_sample  # noqa: F401
