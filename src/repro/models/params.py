"""Single-source-of-truth parameter definitions.

Each model module exposes ``*_defs(cfg, ...) -> pytree[ParamDef]`` describing
GLOBAL parameter shapes together with their mesh ``PartitionSpec``. From one
defs tree we derive:

  * concrete params        (``init_params`` — tests, examples, real training)
  * abstract params         (``abstract_params`` — dry-run ShapeDtypeStructs)
  * the in/out sharding specs for pjit / shard_map (``param_specs``)

so concrete init, dry-run and distribution can never drift apart.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P = P()
    scale: float = 1.0
    dtype: str = "float32"
    init: str = "normal"      # normal | zeros | ones

    def stacked(self, n: int) -> "ParamDef":
        """Prepend a scan (layer-stack) dimension."""
        return dataclasses.replace(
            self, shape=(n,) + tuple(self.shape), spec=P(None, *self.spec)
        )


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _leaves(defs):
    return jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)


def stack_defs(defs, n: int):
    return jax.tree.map(lambda d: d.stacked(n), defs, is_leaf=is_def)


def param_specs(defs):
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_def)


def abstract_params(defs, mesh=None):
    """ShapeDtypeStructs (with NamedSharding when a mesh is given)."""

    def mk(d: ParamDef):
        if mesh is not None:
            sh = jax.sharding.NamedSharding(mesh, d.spec)
            return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype), sharding=sh)
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype))

    return jax.tree.map(mk, defs, is_leaf=is_def)


def init_params(defs, rng):
    """Concretely initialize a defs tree. Per-leaf keys are derived from the
    flattened path so inits are order-independent."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)
    leaves = []
    for path, d in flat:
        key = jax.random.fold_in(rng, hash(jax.tree_util.keystr(path)) % (2**31))
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, jnp.dtype(d.dtype))
        elif d.init == "ones":
            arr = jnp.ones(d.shape, jnp.dtype(d.dtype))
        else:
            arr = jax.random.normal(key, d.shape, jnp.dtype(d.dtype)) * d.scale
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def count_params(defs) -> int:
    flat, _ = _leaves(defs)
    total = 0
    for _, d in flat:
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


# -- convenience constructors ------------------------------------------------


def linear(in_dim: int, out_dim: int, *, shard: Optional[str] = None,
           shard_dim: int = 1, dtype="float32") -> ParamDef:
    """A (in, out) weight. ``shard``: mesh axis name for ``shard_dim``."""
    spec = [None, None]
    if shard is not None:
        spec[shard_dim] = shard
    return ParamDef((in_dim, out_dim), P(*spec), scale=in_dim ** -0.5, dtype=dtype)


def bias(dim: int, *, shard: Optional[str] = None, dtype="float32") -> ParamDef:
    return ParamDef((dim,), P(shard), scale=0.0, dtype=dtype, init="zeros")


def norm_scale(dim: int, *, shard: Optional[str] = None) -> ParamDef:
    return ParamDef((dim,), P(shard), init="ones")


def embedding(vocab: int, dim: int, *, shard: Optional[str] = None) -> ParamDef:
    # vocab-sharded embedding table
    return ParamDef((vocab, dim), P(shard, None), scale=1.0)
