"""Shared neural-net building blocks (TP-aware, shard_map style).

Conventions:
  * Activations are replicated over the "model" axis; only weights are sharded.
  * Column-parallel linears produce sharded features (no collective);
    row-parallel linears consume sharded features and finish with psum.
  * All matmuls run in ``cfg.dtype`` (bf16 by default); params live in fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import params as pdefs
from repro.sharding.rules import ParallelContext


def cast(x, dtype: str):
    return x.astype(jnp.dtype(dtype))


def rms_norm(scale, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {kind!r}")


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10000.0):
    """Apply rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated FFN (column/row parallel)
# ---------------------------------------------------------------------------


def ffn_defs(d_model: int, d_ff: int, act: str = "silu", gated: bool = True):
    defs = {
        "up": pdefs.linear(d_model, d_ff, shard="model"),
        "down": pdefs.linear(d_ff, d_model, shard="model", shard_dim=0),
    }
    if gated:
        defs["gate"] = pdefs.linear(d_model, d_ff, shard="model")
    return defs


def ffn_apply(p, x, ctx: ParallelContext, act: str = "silu", dtype="bfloat16",
              psum: bool = True):
    up = x @ cast(p["up"], dtype)
    if "gate" in p:
        h = activation(x @ cast(p["gate"], dtype), act) * up
    else:
        h = activation(up, act)
    out = h @ cast(p["down"], dtype)
    return ctx.psum_model(out) if psum else out


# ---------------------------------------------------------------------------
# Vocab-sharded embedding + cross entropy
# ---------------------------------------------------------------------------


def embed_defs(vocab_padded: int, d_model: int):
    return {"table": pdefs.embedding(vocab_padded, d_model, shard="model")}


def embed_lookup(p, tokens, ctx: ParallelContext, dtype="bfloat16"):
    """Gather rows of a vocab-sharded table: local gather + psum over model."""
    table = p["table"]
    vloc = table.shape[0]
    lo = ctx.model_index() * vloc
    local_ids = tokens - lo
    in_range = (local_ids >= 0) & (local_ids < vloc)
    safe = jnp.clip(local_ids, 0, vloc - 1)
    out = jnp.take(table, safe, axis=0)
    out = jnp.where(in_range[..., None], out, 0.0)
    return ctx.psum_model(out).astype(jnp.dtype(dtype))


def unembed_logits(p, x, dtype="bfloat16"):
    """x @ table.T — logits sharded over vocab (no collective)."""
    return x @ cast(p["table"], dtype).T


def sharded_xent(logits_local, labels, ctx: ParallelContext,
                 true_vocab: Optional[int] = None, mask=None):
    """Cross entropy with vocab-sharded logits.

    logits_local: (..., V/tp) fp32/bf16, labels: (...) int32.
    Padded vocab entries (>= true_vocab) are excluded from the partition sum.
    Returns mean loss (scalar, replicated).
    """
    logits_local = logits_local.astype(jnp.float32)
    vloc = logits_local.shape[-1]
    lo = ctx.model_index() * vloc
    if true_vocab is not None:
        col = lo + jnp.arange(vloc)
        logits_local = jnp.where(col < true_vocab, logits_local, -1e30)
    local_max = jax.lax.stop_gradient(jnp.max(logits_local, axis=-1))
    gmax = ctx.pmax_model(local_max)
    sumexp = jnp.sum(jnp.exp(logits_local - gmax[..., None]), axis=-1)
    lse = jnp.log(ctx.psum_model(sumexp)) + gmax
    local_ids = labels - lo
    in_range = (local_ids >= 0) & (local_ids < vloc)
    safe = jnp.clip(local_ids, 0, vloc - 1)
    lab = jnp.take_along_axis(logits_local, safe[..., None], axis=-1)[..., 0]
    lab = ctx.psum_model(jnp.where(in_range, lab, 0.0))
    nll = lse - lab
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
