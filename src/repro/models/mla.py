"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill: decompress the latent kv for all positions and run standard
multi-head attention (heads sharded over "model").

Decode: the *absorbed* formulation — W_uk is folded into the query and W_uv
into the output so attention runs directly against the compressed latent
cache ``c_kv`` (kv_lora_rank + rope_head_dim per position, shared across
heads). This makes decode cost linear in context with a tiny cache, which is
why deepseek-v3 is allowed to run the long_500k shape (see DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MLAConfig
from repro.models import params as pdefs
from repro.models.layers import cast, rope, softcap
from repro.sharding.rules import ParallelContext, pad_to

NEG_INF = -1e30


def mla_defs(d_model: int, num_heads: int, m: MLAConfig, tp: int):
    H = pad_to(num_heads, tp)
    qh = m.nope_head_dim + m.rope_head_dim
    return {
        "w_dq": pdefs.linear(d_model, m.q_lora_rank),
        "q_norm": pdefs.norm_scale(m.q_lora_rank),
        "w_uq": pdefs.linear(m.q_lora_rank, H * qh, shard="model"),
        "w_dkv": pdefs.linear(d_model, m.kv_lora_rank),
        "kv_norm": pdefs.norm_scale(m.kv_lora_rank),
        "w_kr": pdefs.linear(d_model, m.rope_head_dim),
        "w_uk": pdefs.linear(m.kv_lora_rank, H * m.nope_head_dim, shard="model"),
        "w_uv": pdefs.linear(m.kv_lora_rank, H * m.v_head_dim, shard="model"),
        "wo": pdefs.linear(H * m.v_head_dim, d_model, shard="model", shard_dim=0),
    }


def _queries(p, x, m: MLAConfig, Hl: int, positions, theta, dtype):
    from repro.models.layers import rms_norm
    B, S, _ = x.shape
    cq = rms_norm(p["q_norm"], x @ cast(p["w_dq"], dtype))
    q = (cq @ cast(p["w_uq"], dtype)).reshape(B, S, Hl, -1)
    q_nope = q[..., : m.nope_head_dim]
    q_rope = rope(q[..., m.nope_head_dim:], positions, theta)
    return q_nope, q_rope


def _latents(p, x, m: MLAConfig, positions, theta, dtype):
    from repro.models.layers import rms_norm
    c_kv = rms_norm(p["kv_norm"], x @ cast(p["w_dkv"], dtype))
    k_rope = rope((x @ cast(p["w_kr"], dtype))[:, :, None, :], positions, theta)
    return c_kv, k_rope[:, :, 0, :]


def _pack_cache(arr, C):
    """(B,S,...) -> (B,C,...) rolling layout: slot j holds position p, p%C==j."""
    B, S = arr.shape[0], arr.shape[1]
    if S >= C:
        out = arr[:, S - C:]
        return jnp.roll(out, shift=(S - C) % C, axis=1)
    pad = [(0, 0), (0, C - S)] + [(0, 0)] * (arr.ndim - 2)
    return jnp.pad(arr, pad)


def mla_train(p, x, m: MLAConfig, ctx: ParallelContext, *,
              rope_theta: float, cap: Optional[float] = None,
              dtype="bfloat16", chunk: int = 2048, return_cache_len: int = 0):
    """Full-sequence causal MLA. x: (B,S,d)."""
    B, S, _ = x.shape
    Hl = p["w_uq"].shape[1] // (m.nope_head_dim + m.rope_head_dim)
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q_nope, q_rope = _queries(p, x, m, Hl, positions, rope_theta, dtype)
    c_kv, k_rope = _latents(p, x, m, positions, rope_theta, dtype)
    k_nope = (c_kv @ cast(p["w_uk"], dtype)).reshape(B, S, Hl, m.nope_head_dim)
    v = (c_kv @ cast(p["w_uv"], dtype)).reshape(B, S, Hl, m.v_head_dim)

    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    n_chunks = max(S // chunk, 1)
    cs = S // n_chunks

    def body(_, i):
        qn = lax.dynamic_slice_in_dim(q_nope, i * cs, cs, axis=1)
        qr = lax.dynamic_slice_in_dim(q_rope, i * cs, cs, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope).astype(jnp.float32)
        s = s + jnp.einsum("bqhd,bkd->bhqk", qr, k_rope).astype(jnp.float32)
        s = softcap(s * scale, cap)
        qpos = i * cs + jnp.arange(cs)
        mask = qpos[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        return None, jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)

    _, out = lax.scan(body, None, jnp.arange(n_chunks))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, Hl * m.v_head_dim)
    out = ctx.psum_model(out @ cast(p["wo"], dtype))
    if return_cache_len:
        C = return_cache_len
        cache = MLACache(_pack_cache(c_kv, C), _pack_cache(k_rope, C))
        return out, cache
    return out


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, C, kv_lora_rank)
    k_rope: jax.Array  # (B, C, rope_head_dim)


def mla_decode(p, x, cache: MLACache, pos, m: MLAConfig,
               ctx: ParallelContext, *, rope_theta: float, total_len: int,
               cap: Optional[float] = None, dtype="bfloat16"):
    """Absorbed one-token decode against the latent cache."""
    B = x.shape[0]
    Hl = p["w_uq"].shape[1] // (m.nope_head_dim + m.rope_head_dim)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _queries(p, x, m, Hl, posv, rope_theta, dtype)
    c_new, kr_new = _latents(p, x, m, posv, rope_theta, dtype)

    gslot = pos % total_len
    if ctx.seq_axis:
        Cl = cache.c_kv.shape[1]
        lo = ctx.seq_index() * Cl
        here = (gslot >= lo) & (gslot < lo + Cl)
        sl = jnp.clip(gslot - lo, 0, Cl - 1)
        cu = lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, sl, axis=1)
        ku = lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, sl, axis=1)
        new_cache = MLACache(jnp.where(here, cu, cache.c_kv),
                             jnp.where(here, ku, cache.k_rope))
        slot_ids = lo + jnp.arange(Cl)
    else:
        new_cache = MLACache(
            lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, gslot, axis=1),
            lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, gslot, axis=1))
        slot_ids = jnp.arange(total_len)

    # absorb W_uk into q:  q_lat[h] = q_nope[h] @ W_uk[:, h].T
    w_uk = cast(p["w_uk"], dtype).reshape(m.kv_lora_rank, Hl, m.nope_head_dim)
    q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)  # (B,1,Hl,kv_lora)

    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s = jnp.einsum("bqhc,bkc->bhqk", q_lat, new_cache.c_kv).astype(jnp.float32)
    s = s + jnp.einsum("bqhd,bkd->bhqk", q_rope, new_cache.k_rope).astype(jnp.float32)
    s = softcap(s * scale, cap)
    filled = (slot_ids <= pos) | (pos >= total_len)
    s = jnp.where(filled[None, None, None, :], s, NEG_INF)
    if ctx.seq_axis:
        mx = ctx.pmax_seq(jnp.max(s, axis=-1))
        w = jnp.exp(s - mx[..., None])
        denom = ctx.psum_seq(jnp.sum(w, axis=-1))
        lat = ctx.psum_seq(
            jnp.einsum("bhqk,bkc->bqhc", w.astype(new_cache.c_kv.dtype), new_cache.c_kv))
        lat = lat / denom.transpose(0, 2, 1)[..., None]
    else:
        w = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhqk,bkc->bqhc", w.astype(new_cache.c_kv.dtype), new_cache.c_kv)
    # absorb W_uv on the way out
    w_uv = cast(p["w_uv"], dtype).reshape(m.kv_lora_rank, Hl, m.v_head_dim)
    lat = lat.astype(jnp.dtype(dtype))
    out = jnp.einsum("bqhc,chd->bqhd", lat, w_uv).reshape(B, 1, Hl * m.v_head_dim)
    return ctx.psum_model(out @ cast(p["wo"], dtype)), new_cache
