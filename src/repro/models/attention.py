"""Softmax attention: GQA/MQA/MHA, sliding windows, softcaps, KV caches.

Three execution paths share one scoring core:
  * ``attn_train``   — full-sequence training/prefill, q-chunked to bound the
                       score-matrix working set (32k prefill stays compilable).
  * ``attn_decode``  — one new token against a (possibly rolling) KV cache.
  * sequence-sharded decode — the cache is sharded over the "data" axis
    (long_500k); partial attention per shard is combined with a
    log-sum-exp psum (2-pass-free online softmax merge).

Head layout is the padded layout from ``sharding.attn_dims``; kv heads are
expanded to q-head alignment with a gather so GQA/MQA/dense all run the same
einsums.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import params as pdefs
from repro.models.layers import cast, rope, softcap
from repro.sharding.rules import AttnDims, ParallelContext

NEG_INF = -1e30


def attn_defs(d_model: int, dims: AttnDims, *, qkv_bias: bool = False):
    hd = dims.head_dim
    kv_shard = "model" if dims.kv_sharded else None
    defs = {
        "wq": pdefs.linear(d_model, dims.q_heads * hd, shard="model"),
        "wk": pdefs.linear(d_model, dims.kv_heads * hd, shard=kv_shard),
        "wv": pdefs.linear(d_model, dims.kv_heads * hd, shard=kv_shard),
        "wo": pdefs.linear(dims.q_heads * hd, d_model, shard="model", shard_dim=0),
    }
    if qkv_bias:
        defs["bq"] = pdefs.bias(dims.q_heads * hd, shard="model")
        defs["bk"] = pdefs.bias(dims.kv_heads * hd, shard=kv_shard)
        defs["bv"] = pdefs.bias(dims.kv_heads * hd, shard=kv_shard)
    return defs


def _project_qkv(p, x, dims: AttnDims, ctx: ParallelContext, dtype):
    """Project to q,k,v and expand kv to the q-head-aligned layout."""
    B, S, _ = x.shape
    hd = dims.head_dim
    q = x @ cast(p["wq"], dtype)
    k = x @ cast(p["wk"], dtype)
    v = x @ cast(p["wv"], dtype)
    if "bq" in p:
        q = q + cast(p["bq"], dtype)
        k = k + cast(p["bk"], dtype)
        v = v + cast(p["bv"], dtype)
    q = q.reshape(B, S, dims.q_local, hd)
    k = k.reshape(B, S, dims.kv_local, hd)
    v = v.reshape(B, S, dims.kv_local, hd)
    return q, k, v


def _kv_head_map(dims: AttnDims, ctx: ParallelContext):
    """For each local q head, the LOCAL kv-head index holding its group."""
    gq = ctx.model_index() * dims.q_local + jnp.arange(dims.q_local)
    kv_global = gq // dims.group
    if dims.kv_sharded:
        return kv_global - ctx.model_index() * dims.kv_local
    return kv_global  # replicated: local index == global index


def expand_kv(k, dims: AttnDims, ctx: ParallelContext):
    """(B,S,KVl,hd) -> (B,S,Hl,hd) by gathering each q head's kv head."""
    if dims.kv_local == dims.q_local:
        return k
    return jnp.take(k, _kv_head_map(dims, ctx), axis=2)


def _scores_block(q, k, v, *, scale, cap, mask):
    """q:(B,Sq,H,hd) k,v:(B,Sk,H,hd) mask:(Sq,Sk) or (B,Sq,Sk) bool."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = softcap(s, cap)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]
        else:
            mask = mask[:, None]
        s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def _mask(q_pos, k_pos, *, causal: bool, window: int):
    """q_pos:(Sq,) k_pos:(Sk,) -> (Sq,Sk) bool of allowed pairs."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def attention_core(q, k, v, *, causal: bool, window: int,
                   cap: Optional[float], chunk: int = 2048,
                   q_offset: int = 0):
    """Full-sequence attention, q-chunked when long. All heads local."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    if Sq <= 2 * chunk:
        mask = _mask(q_offset + jnp.arange(Sq), jnp.arange(Sk),
                     causal=causal, window=window)
        return _scores_block(q, k, v, scale=scale, cap=cap, mask=mask)

    assert Sq % chunk == 0, (Sq, chunk)
    n_chunks = Sq // chunk
    qc = q.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    if window > 0:
        # local attention: slice a static-size kv band per q chunk
        band = ((window + chunk - 1) // chunk) * chunk + chunk

        def body(carry, inp):
            i, qi = inp
            start = jnp.maximum(i * chunk - (band - chunk), 0)
            start = jnp.minimum(start, Sk - band) if Sk >= band else 0
            ki = lax.dynamic_slice_in_dim(k, start, min(band, Sk), axis=1)
            vi = lax.dynamic_slice_in_dim(v, start, min(band, Sk), axis=1)
            qpos = q_offset + i * chunk + jnp.arange(chunk)
            kpos = start + jnp.arange(min(band, Sk))
            mask = _mask(qpos, kpos, causal=causal, window=window)
            return carry, _scores_block(qi, ki, vi, scale=scale, cap=cap, mask=mask)

        _, out = lax.scan(body, None, (jnp.arange(n_chunks), qc))
    else:
        def body(carry, inp):
            i, qi = inp
            qpos = q_offset + i * chunk + jnp.arange(chunk)
            mask = _mask(qpos, jnp.arange(Sk), causal=causal, window=window)
            return carry, _scores_block(qi, k, v, scale=scale, cap=cap, mask=mask)

        _, out = lax.scan(body, None, (jnp.arange(n_chunks), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# Train / prefill
# ---------------------------------------------------------------------------


def attn_train(p, x, dims: AttnDims, ctx: ParallelContext, *,
               causal: bool, window: int, cap: Optional[float],
               rope_theta: float, positions=None, dtype="bfloat16",
               chunk: int = 2048, return_cache_len: int = 0):
    """Full-sequence attention layer. Returns (out, cache_kv | None).

    When ``return_cache_len`` > 0 the (roped) k/v are also returned as a
    prefill cache of that length (rolling-trimmed for windowed layers).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, dims, ctx, dtype)
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    ke = expand_kv(k, dims, ctx)
    ve = expand_kv(v, dims, ctx)
    out = attention_core(q, ke, ve, causal=causal, window=window, cap=cap, chunk=chunk)
    out = out.reshape(B, S, dims.q_local * dims.head_dim)
    out = out @ cast(p["wo"], dtype)
    out = ctx.psum_model(out)
    cache = None
    if return_cache_len:
        C = return_cache_len
        if S >= C:
            kc, vc = k[:, S - C:], v[:, S - C:]
            # roll so that slot j holds position p with p % C == j
            shift = (S - C) % C if C else 0
            kc = jnp.roll(kc, shift=shift, axis=1)
            vc = jnp.roll(vc, shift=shift, axis=1)
        else:
            pad = C - S
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = (kc, vc)
    return out, cache


# ---------------------------------------------------------------------------
# Decode (single token, cached)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array   # (B, C, KVl, hd)   C = window or max context
    v: jax.Array


def cache_update(cache: KVCache, k_new, v_new, pos, ctx: ParallelContext):
    """Insert the new (roped) k/v at slot pos % C (seq-sharded aware)."""
    C = cache.k.shape[1]
    slot = pos % C
    if ctx.seq_axis:
        Cl = C  # local length; global slot mapped to a shard
        lo = ctx.seq_index() * Cl
        here = (slot >= lo) & (slot < lo + Cl)
        ku = lax.dynamic_update_slice_in_dim(cache.k, k_new, jnp.clip(slot - lo, 0, Cl - 1), axis=1)
        vu = lax.dynamic_update_slice_in_dim(cache.v, v_new, jnp.clip(slot - lo, 0, Cl - 1), axis=1)
        return KVCache(jnp.where(here, ku, cache.k), jnp.where(here, vu, cache.v))
    ku = lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    vu = lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    return KVCache(ku, vu)


def attn_decode(p, x, cache: KVCache, pos, dims: AttnDims,
                ctx: ParallelContext, *, window: int, cap: Optional[float],
                rope_theta: float, total_len: int, dtype="bfloat16"):
    """One-token decode. x: (B,1,d); pos: scalar int32 (current position).

    ``total_len`` is the global cache length C_total (for seq-sharded caches
    the local cache holds C_total / seq_shards slots).
    Returns (out (B,1,d), new_cache).
    """
    B = x.shape[0]
    hd = dims.head_dim
    q, k, v = _project_qkv(p, x, dims, ctx, dtype)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = rope(q, posv, rope_theta)
    k = rope(k, posv, rope_theta)
    # global slot of the new token
    gslot = pos % total_len
    if ctx.seq_axis:
        Cl = cache.k.shape[1]
        lo = ctx.seq_index() * Cl
        here = (gslot >= lo) & (gslot < lo + Cl)
        local_slot = jnp.clip(gslot - lo, 0, Cl - 1)
        kud = lax.dynamic_update_slice_in_dim(cache.k, k, local_slot, axis=1)
        vud = lax.dynamic_update_slice_in_dim(cache.v, v, local_slot, axis=1)
        new_cache = KVCache(jnp.where(here, kud, cache.k),
                            jnp.where(here, vud, cache.v))
        slot_ids = lo + jnp.arange(Cl)
    else:
        new_cache = KVCache(
            lax.dynamic_update_slice_in_dim(cache.k, k, gslot, axis=1),
            lax.dynamic_update_slice_in_dim(cache.v, v, gslot, axis=1))
        slot_ids = jnp.arange(total_len)

    ke = expand_kv(new_cache.k, dims, ctx)
    ve = expand_kv(new_cache.v, dims, ctx)
    # validity: slot filled (j <= pos or cache has wrapped) and inside window
    filled = (slot_ids <= pos) | (pos >= total_len)
    if window > 0 and total_len > window:
        # slot j holds position p_j = pos - ((gslot - j) % total_len)
        age = (gslot - slot_ids) % total_len
        filled &= age < window
    valid = filled[None, None, None, :]  # (1,1,1,C)

    s = jnp.einsum("bqhd,bkhd->bhqk", q, ke).astype(jnp.float32) * hd ** -0.5
    s = softcap(s, cap)
    s = jnp.where(valid, s, NEG_INF)
    if ctx.seq_axis:
        m_loc = jnp.max(s, axis=-1)                      # (B,H,1)
        m = ctx.pmax_seq(m_loc)
        w = jnp.exp(s - m[..., None])
        denom = ctx.psum_seq(jnp.sum(w, axis=-1))        # (B,H,1)
        part = jnp.einsum("bhqk,bkhd->bqhd", w.astype(ve.dtype), ve)
        out = ctx.psum_seq(part) / denom.transpose(0, 2, 1)[..., None]
    else:
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(ve.dtype), ve)
    out = out.reshape(B, 1, dims.q_local * hd).astype(jnp.dtype(dtype))
    out = ctx.psum_model(out @ cast(p["wo"], dtype))
    return out, new_cache
