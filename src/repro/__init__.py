"""FedCAMS (Communication-Efficient Adaptive Federated Learning, ICML 2022)
as a production multi-pod JAX/Pallas framework.

Public surface:
    repro.core     — FedAMS/FedCAMS, compressors, error feedback, rounds,
                     FederatedTrainer facade
    repro.comm     — wire formats & transport (see below)
    repro.models   — the six-family architecture substrate (Model)
    repro.configs  — the 10 assigned architecture configs + dataclasses
    repro.kernels  — Pallas TPU kernels (+ jnp oracles)
    repro.launch   — production mesh, dry-run, train/serve drivers

Wire formats & transport (repro.comm):
    The paper accounts communication analytically (Table 1 bits);
    ``repro.comm`` makes it physical. ``comm.wire`` packs each compressed
    delta into an actual byte buffer — dense fp32, top-k (uint32 index +
    fp32/fp16/bf16 value), block-top-k (log2(B)-bit packed indices, optional
    int8 values against per-block scales) and sign (1 bit/coord + fp32
    scale) — decoding bit-exactly back to the dense compressor output.
    ``comm.transport`` moves those bytes through a simulated client fleet
    with per-client asymmetric bandwidth, latency jitter and stragglers.
    ``FedConfig(wire=True)`` routes every FedSim round through
    encode→transport→decode (two-way compression exercises the downlink
    codec too) and surfaces measured ``wire_bytes`` / ``round_time_s`` into
    ``FederatedTrainer.history``; ``kernels.bitpack`` provides the Pallas
    1-bit pack/unpack the sign codec selects with ``pack_impl="pallas"``
    (byte-identical to the default jnp path), and
    ``benchmarks/bench_wire.py`` measures codec throughput and
    measured-vs-analytic bytes.
"""

__version__ = "1.0.0"
