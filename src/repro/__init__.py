"""FedCAMS (Communication-Efficient Adaptive Federated Learning, ICML 2022)
as a production multi-pod JAX/Pallas framework.

Public surface:
    repro.core     — FedAMS/FedCAMS, compressors, error feedback, rounds,
                     FederatedTrainer facade
    repro.models   — the six-family architecture substrate (Model)
    repro.configs  — the 10 assigned architecture configs + dataclasses
    repro.kernels  — Pallas TPU kernels (+ jnp oracles)
    repro.launch   — production mesh, dry-run, train/serve drivers
"""

__version__ = "1.0.0"
