"""Mesh/axis plumbing shared by model code and the federated runtime.

Model code is written in the *manual-collective* (shard_map) style: weights
arrive pre-sharded (local shapes), activations are replicated over the
"model" axis, and row-parallel matmuls finish with an explicit
``psum(..., "model")``. A ``ParallelContext`` tells the code which mesh axes
exist; when an axis is absent (unit size) the collective is a no-op, so the
same code runs single-device in tests.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
from jax import lax


import functools

import jax as _jax


@functools.partial(_jax.custom_vjp, nondiff_argnums=(0,))
def _tp_copy(axis, x):
    return x


def _tp_copy_fwd(axis, x):
    return x, None


def _tp_copy_bwd(axis, _, ct):
    return (lax.psum(ct, axis),)


_tp_copy.defvjp(_tp_copy_fwd, _tp_copy_bwd)


def pad_to(n: int, multiple: int) -> int:
    """Round ``n`` up to a multiple of ``multiple``."""
    if multiple <= 1:
        return n
    return ((n + multiple - 1) // multiple) * multiple


def padded_vocab(vocab_size: int, tp: int) -> int:
    return pad_to(vocab_size, tp)


@dataclass(frozen=True)
class ParallelContext:
    """Which mesh axes the current computation runs under.

    ``None`` axis names mean "that form of parallelism is off" — all the
    collective helpers become identities, so model code is oblivious.
    """

    model_axis: Optional[str] = None
    tp: int = 1
    data_axis: Optional[str] = None
    dp: int = 1
    client_axes: Tuple[str, ...] = ()
    num_clients: int = 1
    seq_axis: Optional[str] = None   # sequence-sharded KV cache (long-context decode)
    seq_shards: int = 1
    # TP all-reduce strategy. jax upcasts psum payloads to f32 for
    # deterministic accumulation; "rs_ag" decomposes the activation
    # all-reduce into reduce-scatter (f32 accumulate) + bf16 all-gather —
    # same numerics as a bf16-native TPU all-reduce, 25-50% less ICI
    # payload (see EXPERIMENTS.md §Perf).
    tp_collective: str = "psum"      # "psum" | "rs_ag"

    # -- collectives over the tensor-parallel axis ----------------------
    def tp_copy(self, x):
        """Branch-entry marker (Megatron's [g] operator). With shard_map's
        varying-manual-axes tracking (check_vma=True, which all our launch
        paths use) jax inserts the correct psum-on-transpose automatically,
        so this is an identity; it stays in the code to document where the
        replicated->shard-local fan-outs are. (Under check_vma=False jax
        transposes psum to psum and manual-TP gradients come out wrong by
        factors of tp — see tests/test_sharding.py.)"""
        return x

    def psum_model(self, x):
        if not self.model_axis:
            return x
        if (self.tp_collective == "rs_ag" and x.ndim >= 2
                and x.shape[0] % self.tp == 0 and x.shape[0] >= self.tp):
            from jax._src.lax.parallel import all_gather_invariant
            s = lax.psum_scatter(x, self.model_axis, scatter_dimension=0,
                                 tiled=True)
            return all_gather_invariant(s.astype(x.dtype), self.model_axis,
                                        axis=0, tiled=True)
        return lax.psum(x, self.model_axis)

    def pmax_model(self, x):
        return lax.pmax(x, self.model_axis) if self.model_axis else x

    def all_gather_model(self, x, axis=-1):
        if not self.model_axis:
            return x
        return lax.all_gather(x, self.model_axis, axis=axis, tiled=True)

    def model_index(self):
        return lax.axis_index(self.model_axis) if self.model_axis else 0

    # -- collectives over the client axes (FL aggregation) --------------
    def psum_clients(self, x):
        for ax in self.client_axes:
            x = lax.psum(x, ax)
        return x

    def pmean_clients(self, x):
        x = self.psum_clients(x)
        return x / self.num_clients if self.client_axes else x

    @staticmethod
    def _gather_axes(axes, x, axis):
        """all_gather over ``axes`` with *invariant* (replicated-over-axis)
        output vma where the primitive exists — downstream server math
        relies on gathered tensors being replicated over the gathered
        axes."""
        try:  # public alias pending upstream; primitive exists since 0.7
            from jax._src.lax.parallel import all_gather_invariant
        except ImportError:  # pragma: no cover
            all_gather_invariant = None
        for ax in axes:
            if all_gather_invariant is not None:
                x = all_gather_invariant(x, ax, axis=axis, tiled=True)
            else:
                x = lax.all_gather(x, ax, axis=axis, tiled=True)
        return x

    def all_gather_clients(self, x, axis=0):
        """Gather over ALL client axes — every client ends up with the
        identical gathered tensor (the flat, single-tier aggregation)."""
        return self._gather_axes(self.client_axes, x, axis)

    # -- two-level hierarchical aggregation (DESIGN.md §scale-out) -------
    # Convention: the FIRST client axis is the group axis; the remaining
    # client axes enumerate each group's members. The flat helpers above
    # are oblivious — gathering/psumming over all axes is the same math.

    def all_gather_members(self, x, axis=0):
        """Tier 1: gather over the member axes only. Every member of a
        group sees the group's stacked payload; groups stay distinct (the
        result still varies over the group axis)."""
        if len(self.client_axes) < 2:
            raise ValueError(
                "all_gather_members needs >= 2 client axes — the first is "
                "the group axis (FedConfig.agg_groups hierarchical layout)")
        return self._gather_axes(self.client_axes[1:], x, axis)

    def all_gather_group_partials(self, x, axis=0):
        """Tier 2 (the root collective): gather the per-group partials over
        the group axis — g partials arrive, independent of the member
        count."""
        if len(self.client_axes) < 2:
            raise ValueError(
                "all_gather_group_partials needs >= 2 client axes — the "
                "first is the group axis")
        return self._gather_axes(self.client_axes[:1], x, axis)

    def client_index(self):
        """Linear index of this client across all client axes."""
        idx = 0
        for ax in self.client_axes:
            idx = idx * lax.psum(1, ax) + lax.axis_index(ax)
        return idx

    # -- collectives over within-client data parallelism -----------------
    def psum_data(self, x):
        return lax.psum(x, self.data_axis) if self.data_axis else x

    def pmean_data(self, x):
        return lax.pmean(x, self.data_axis) if self.data_axis else x

    # -- sequence-sharded decode -----------------------------------------
    def psum_seq(self, x):
        return lax.psum(x, self.seq_axis) if self.seq_axis else x

    def pmax_seq(self, x):
        return lax.pmax(x, self.seq_axis) if self.seq_axis else x

    def seq_index(self):
        return lax.axis_index(self.seq_axis) if self.seq_axis else 0

    def with_(self, **kw) -> "ParallelContext":
        return replace(self, **kw)


@dataclass(frozen=True)
class AttnDims:
    """Resolved (padded) attention head layout for a given TP degree.

    ``q_heads``: padded global q-head count (multiple of tp).
    ``q_local``: q heads per model shard.
    ``kv_sharded``: whether kv heads are sharded over "model" (divisible) or
    replicated on every shard (small-kv GQA/MQA).
    ``kv_local``: kv heads materialized per shard.
    ``group``: q-heads per kv-head in the padded layout.
    """

    q_heads: int
    q_local: int
    kv_heads: int
    kv_sharded: bool
    kv_local: int
    group: int
    head_dim: int


def attn_dims(num_heads: int, num_kv_heads: int, head_dim: int, tp: int) -> AttnDims:
    q = pad_to(num_heads, tp)
    if num_kv_heads >= tp and num_kv_heads % tp == 0 and q % num_kv_heads == 0:
        kv = num_kv_heads
        kv_sharded = True
        kv_local = kv // tp
    else:
        # replicate kv heads; pad kv so q % kv == 0 in the padded layout
        kv = num_kv_heads
        while q % kv != 0:
            kv += 1
        kv_sharded = False
        kv_local = kv
    return AttnDims(
        q_heads=q,
        q_local=q // tp,
        kv_heads=kv,
        kv_sharded=kv_sharded,
        kv_local=kv_local,
        group=q // kv,
        head_dim=head_dim,
    )
