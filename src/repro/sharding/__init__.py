from repro.sharding.rules import (  # noqa: F401
    ParallelContext,
    AttnDims,
    attn_dims,
    pad_to,
    padded_vocab,
)
