"""Cumulative communication accounting for a training run.

``CommLog`` accumulates the measured per-round transport numbers (bytes on
the wire both directions, simulated wall-clock) that ``FedSim``'s wire mode
surfaces into ``FederatedTrainer.history`` — the measured counterpart of
the analytic ``bits`` counter the paper plots.

With two-level aggregation (``FedConfig.agg_groups > 1``, DESIGN.md
§scale-out) the uplink is billed per tier: tier 1 is the n client messages
(the codec bytes the transport times), tier 2 the g dense group partials
pushed to the root. ``wire_up_bytes`` then bills the tiers that actually
run (tier 1 + tier 2); the per-tier split is kept in
``wire_tier1_bytes`` / ``wire_tier2_bytes``.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.comm.transport import RoundTiming


@dataclass
class CommLog:
    rounds: int = 0
    uplink_bytes: int = 0      # tier 1: client -> (group | server) messages
    edge_bytes: int = 0        # tier 2: group partial -> root (0 when flat)
    downlink_bytes: int = 0
    sim_time_s: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.edge_bytes + self.downlink_bytes

    def add(self, timing: RoundTiming, tier2_bytes: int = 0) -> None:
        self.rounds += 1
        self.uplink_bytes += timing.uplink_bytes
        self.edge_bytes += tier2_bytes
        self.downlink_bytes += timing.downlink_bytes
        self.sim_time_s += timing.round_time_s

    def record(self, timing: RoundTiming, tier2_bytes: int = 0) -> dict:
        """Add one round and return the history entries for it."""
        self.add(timing, tier2_bytes)
        return {
            "wire_up_bytes": timing.uplink_bytes + tier2_bytes,
            "wire_tier1_bytes": timing.uplink_bytes,
            "wire_tier2_bytes": tier2_bytes,
            "wire_down_bytes": timing.downlink_bytes,
            "wire_bytes": self.total_bytes,
            "round_time_s": timing.round_time_s,
            "sim_time_s": self.sim_time_s,
        }
