"""Cumulative communication accounting for a training run.

``CommLog`` accumulates the measured per-round transport numbers (bytes on
the wire both directions, simulated wall-clock) that ``FedSim``'s wire mode
surfaces into ``FederatedTrainer.history`` — the measured counterpart of
the analytic ``bits`` counter the paper plots."""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.comm.transport import RoundTiming


@dataclass
class CommLog:
    rounds: int = 0
    uplink_bytes: int = 0
    downlink_bytes: int = 0
    sim_time_s: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.downlink_bytes

    def add(self, timing: RoundTiming) -> None:
        self.rounds += 1
        self.uplink_bytes += timing.uplink_bytes
        self.downlink_bytes += timing.downlink_bytes
        self.sim_time_s += timing.round_time_s

    def record(self, timing: RoundTiming) -> dict:
        """Add one round and return the history entries for it."""
        self.add(timing)
        return {
            "wire_up_bytes": timing.uplink_bytes,
            "wire_down_bytes": timing.downlink_bytes,
            "wire_bytes": self.total_bytes,
            "round_time_s": timing.round_time_s,
            "sim_time_s": self.sim_time_s,
        }
