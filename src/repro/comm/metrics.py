"""Cumulative communication accounting for a training run.

``CommLog`` accumulates the measured per-round transport numbers (bytes on
the wire both directions, simulated wall-clock) that ``FedSim``'s wire mode
surfaces into ``FederatedTrainer.history`` — the measured counterpart of
the analytic ``bits`` counter the paper plots.

With two-level aggregation (``FedConfig.agg_groups > 1``, DESIGN.md
§scale-out) the uplink is billed per tier: tier 1 is the n client messages
(the codec bytes the transport times), tier 2 the g dense group partials
pushed to the root. ``wire_up_bytes`` then bills the tiers that actually
run (tier 1 + tier 2); the per-tier split is kept in
``wire_tier1_bytes`` / ``wire_tier2_bytes``.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.comm.transport import RoundTiming


@dataclass
class CommLog:
    rounds: int = 0
    uplink_bytes: int = 0      # tier 1: DELIVERED client messages
    uplink_bytes_attempted: int = 0  # incl. crashed / deadline-cut sends
    edge_bytes: int = 0        # tier 2: group partial -> root (0 when flat)
    downlink_bytes: int = 0
    sim_time_s: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.edge_bytes + self.downlink_bytes

    def add(self, timing: RoundTiming, tier2_bytes: int = 0, *,
            round_time_s: float = None,
            delivered_uplink_bytes: int = None) -> None:
        """Book one round.

        ``round_time_s`` overrides ``timing.round_time_s`` with the
        EFFECTIVE server wall-clock (the fault injector's
        deadline-truncated value, or the async engine's flush delta) so
        ``sim_time_s`` always equals the sum of the recorded per-round
        times — the raw timing carries the untruncated straggler max.
        ``delivered_uplink_bytes`` bills only payloads that reached the
        server; ``timing.uplink_bytes`` (the full cohort's sends) then
        accumulates into the ``uplink_bytes_attempted`` diagnostic —
        crashed and deadline-cut clients consumed their own uplink but
        the server never saw those bytes."""
        if round_time_s is None:
            round_time_s = timing.round_time_s
        if delivered_uplink_bytes is None:
            delivered_uplink_bytes = timing.uplink_bytes
        self.rounds += 1
        self.uplink_bytes += delivered_uplink_bytes
        self.uplink_bytes_attempted += timing.uplink_bytes
        self.edge_bytes += tier2_bytes
        self.downlink_bytes += timing.downlink_bytes
        self.sim_time_s += round_time_s

    def record(self, timing: RoundTiming, tier2_bytes: int = 0, *,
               round_time_s: float = None,
               delivered_uplink_bytes: int = None) -> dict:
        """Add one round and return the history entries for it."""
        self.add(timing, tier2_bytes, round_time_s=round_time_s,
                 delivered_uplink_bytes=delivered_uplink_bytes)
        if round_time_s is None:
            round_time_s = timing.round_time_s
        if delivered_uplink_bytes is None:
            delivered_uplink_bytes = timing.uplink_bytes
        return {
            "wire_up_bytes": delivered_uplink_bytes + tier2_bytes,
            "wire_up_bytes_attempted": timing.uplink_bytes + tier2_bytes,
            "wire_tier1_bytes": delivered_uplink_bytes,
            "wire_tier2_bytes": tier2_bytes,
            "wire_down_bytes": timing.downlink_bytes,
            "wire_bytes": self.total_bytes,
            "round_time_s": round_time_s,
            "sim_time_s": self.sim_time_s,
        }
