"""repro.comm — wire formats & transport for FedCAMS messages.

Turns the paper's *analytic* bit accounting into *measured* bytes moving
through a simulated network:

    wire.py       packed byte codecs (dense32 / topk / blocktopk / sign)
                  with bit-exact decode and exact ``nbytes`` sizing
    transport.py  per-client bandwidth/latency/straggler network model and
                  per-round wall-clock simulation
    metrics.py    cumulative byte/time accounting (``CommLog``)

Enable end-to-end with ``FedConfig(wire=True)`` (see core.sim.FedSim):
every client delta is encoded to packed bytes, timed through the network,
and decoded server-side; ``FederatedTrainer.history`` then carries
``wire_bytes`` / ``round_time_s`` alongside the analytic ``bits``.
"""
from repro.comm.faults import (FAULT_CORRUPT_MODES, FaultConfig,  # noqa: F401
                               FaultInjector, FaultPlan)
from repro.comm.metrics import CommLog  # noqa: F401
from repro.comm.transport import (NetworkConfig, RoundTiming,  # noqa: F401
                                  SimulatedNetwork)
from repro.comm.wire import (HEADER_BYTES, WireCodec,  # noqa: F401
                             make_blocktopk_codec, make_dense32_codec,
                             make_sign_codec, make_topk_codec,
                             make_wire_codec, measured_vs_analytic,
                             parse_header)
