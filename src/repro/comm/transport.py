"""Simulated client-server network for federated rounds.

Models the part of the system the paper's bit counts are a proxy for: how
long a round actually takes when m heterogeneous clients push their encoded
deltas up a slow, asymmetric last-mile link. Per client the model draws a
fixed uplink/downlink bandwidth (log-normal heterogeneity around configured
means — clients keep their link quality across rounds) and per round a
latency sample plus an optional straggler event that multiplies that
client's times.

A round is:  server broadcasts the (possibly compressed) model update down
every participating client's downlink, clients compute (``compute_s``, a
constant knob — compute is not what this module studies), then push their
encoded delta up the uplink; the server waits for the slowest client:

    T_round = max_i [ t_down(i) + compute_s + t_up(i) ]
    t_dir(i) = latency(i) + bytes_dir / bandwidth_dir(i)

Everything is host-side numpy — transport runs between jitted rounds, not
inside them — and deterministic given (seed, round index).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class NetworkConfig:
    """Last-mile link model (defaults: consumer uplink-constrained WAN)."""

    uplink_mbps: float = 20.0       # mean client->server bandwidth
    downlink_mbps: float = 100.0    # mean server->client bandwidth (asym.)
    bandwidth_sigma: float = 0.5    # log-normal spread across clients
    latency_ms: float = 50.0        # mean one-way link setup latency
    latency_jitter_ms: float = 10.0
    straggler_prob: float = 0.05    # P(client is a straggler this round)
    straggler_slowdown: float = 4.0
    compute_s: float = 0.0          # fixed local-training time per round
    seed: int = 0


@dataclass
class RoundTiming:
    """Timing/byte report for one simulated round.

    ``round_time_s`` is the server's wall-clock for the round — the
    straggler max, or the deadline-truncated value when a fault-tolerant
    round cuts stragglers (comm.faults). ``p50_client_time_s`` /
    ``p90_client_time_s`` are per-client completion-time quantiles over
    the cohort (0.0 for an empty round) — the deadline sweep picks its
    cutoffs from these."""

    round_time_s: float
    uplink_bytes: int
    downlink_bytes: int
    slowest_client: int
    mean_client_time_s: float
    client_times_s: np.ndarray
    p50_client_time_s: float = 0.0
    p90_client_time_s: float = 0.0


class SimulatedNetwork:
    """Per-client link state + per-round timing draws (deterministic).

    Link draws are LAZY over the participating index set (DESIGN.md
    §scale-out): each client's fixed bandwidth pair is drawn on first
    participation, keyed by ``(cfg.seed, client_id)`` and cached — so a
    client keeps its link across rounds, the draw is independent of
    participation order, two networks sharing a seed agree per client, and
    constructing a network for m = 10^6 clients allocates nothing."""

    def __init__(self, cfg: NetworkConfig, num_clients: int):
        self.cfg = cfg
        self.num_clients = num_clients
        self._links: dict = {}  # client id -> (up_bps, down_bps)
        # sorted snapshot of the cache for the vectorized warm path: the
        # per-round lookup is a numpy searchsorted over these, not a
        # Python loop over the cohort
        self._ids = np.empty(0, np.int64)
        self._ups = np.empty(0, np.float64)
        self._downs = np.empty(0, np.float64)

    def _draw_links(self, ids: np.ndarray) -> None:
        """Draw + cache the fixed link pair for uncached ids. The draw
        stays keyed by ``(cfg.seed, id)`` — one Generator per id, exactly
        the stream the original per-client loop consumed (bit-identical,
        regression-tested) — but only first-time participants ever reach
        this loop; warm rounds are pure numpy."""
        cfg = self.cfg
        mu = -0.5 * cfg.bandwidth_sigma ** 2
        raw = np.stack([
            np.random.default_rng((cfg.seed, int(c))).normal(
                mu, cfg.bandwidth_sigma, 2)
            for c in ids])
        lu, ld = np.exp(raw[:, 0]), np.exp(raw[:, 1])
        ups = cfg.uplink_mbps * 1e6 / 8.0 * lu
        downs = cfg.downlink_mbps * 1e6 / 8.0 * ld
        for c, u, d in zip(ids, ups, downs):
            self._links[int(c)] = (float(u), float(d))
        all_ids = np.concatenate([self._ids, ids])
        order = np.argsort(all_ids, kind="stable")
        self._ids = all_ids[order]
        self._ups = np.concatenate([self._ups, ups])[order]
        self._downs = np.concatenate([self._downs, downs])[order]

    def _links_for(self, idx: np.ndarray):
        """Fixed per-client heterogeneity for the given clients: a client
        on a bad link stays on it (cached, keyed by (seed, id)). O(n log
        cache) numpy once every cohort member has participated — no
        Python loop over the cohort (the loop at 10^5-client cohorts
        dominated the round)."""
        idx = np.asarray(idx, np.int64)
        if idx.size == 0:
            return np.empty(0), np.empty(0)
        pos = np.searchsorted(self._ids, idx)
        safe = np.minimum(pos, max(self._ids.size - 1, 0))
        hit = (self._ids[safe] == idx) if self._ids.size else \
            np.zeros(idx.size, bool)
        if not hit.all():
            self._draw_links(np.unique(idx[~hit]))
            pos = np.searchsorted(self._ids, idx)
        return self._ups[pos], self._downs[pos]

    def round(self, client_idx: Sequence[int], uplink_bytes_per_client: int,
              downlink_bytes_per_client: int, round_idx: int) -> RoundTiming:
        cfg = self.cfg
        idx = np.asarray(client_idx, np.int64)
        n = idx.size
        up_bps, down_bps = self._links_for(idx)
        rng = np.random.default_rng((cfg.seed + 1) * 1_000_003 + round_idx)
        latency = np.maximum(
            rng.normal(cfg.latency_ms, cfg.latency_jitter_ms, n), 1.0) / 1e3
        slow = np.where(rng.random(n) < cfg.straggler_prob,
                        cfg.straggler_slowdown, 1.0)
        t_down = latency + downlink_bytes_per_client / down_bps
        t_up = latency + uplink_bytes_per_client / up_bps
        per_client = slow * (t_down + cfg.compute_s + t_up)
        worst = int(np.argmax(per_client)) if n else -1
        return RoundTiming(
            round_time_s=float(per_client.max(initial=0.0)),
            uplink_bytes=int(uplink_bytes_per_client) * n,
            downlink_bytes=int(downlink_bytes_per_client) * n,
            slowest_client=int(idx[worst]) if n else -1,
            mean_client_time_s=float(per_client.mean()) if n else 0.0,
            client_times_s=per_client,
            p50_client_time_s=float(np.percentile(per_client, 50)) if n
            else 0.0,
            p90_client_time_s=float(np.percentile(per_client, 90)) if n
            else 0.0,
        )
