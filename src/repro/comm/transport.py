"""Simulated client-server network for federated rounds.

Models the part of the system the paper's bit counts are a proxy for: how
long a round actually takes when m heterogeneous clients push their encoded
deltas up a slow, asymmetric last-mile link. Per client the model draws a
fixed uplink/downlink bandwidth (log-normal heterogeneity around configured
means — clients keep their link quality across rounds) and per round a
latency sample plus an optional straggler event that multiplies that
client's times.

A round is:  server broadcasts the (possibly compressed) model update down
every participating client's downlink, clients compute (``compute_s``, a
constant knob — compute is not what this module studies), then push their
encoded delta up the uplink; the server waits for the slowest client:

    T_round = max_i [ t_down(i) + compute_s + t_up(i) ]
    t_dir(i) = latency(i) + bytes_dir / bandwidth_dir(i)

Everything is host-side numpy — transport runs between jitted rounds, not
inside them — and deterministic given (seed, round index, client id).

The async buffered engine (comm/async_engine.py, DESIGN.md §11) reuses the
same per-client draws but drops the max: :class:`EventClock` orders the
per-client completion times globally so the server can react to each
delivery instead of the slowest one.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class NetworkConfig:
    """Last-mile link model (defaults: consumer uplink-constrained WAN)."""

    uplink_mbps: float = 20.0       # mean client->server bandwidth
    downlink_mbps: float = 100.0    # mean server->client bandwidth (asym.)
    bandwidth_sigma: float = 0.5    # log-normal spread across clients
    latency_ms: float = 50.0        # mean one-way link setup latency
    latency_jitter_ms: float = 10.0
    straggler_prob: float = 0.05    # P(client is a straggler this round)
    straggler_slowdown: float = 4.0
    compute_s: float = 0.0          # fixed local-training time per round
    seed: int = 0


# ---------------------------------------------------------------------------
# Counter-based per-(seed, round, client) draws.
#
# The per-round latency/straggler draws used to come from one Generator per
# round indexed by cohort POSITION, so a client's timing changed whenever
# the cohort was resampled or reordered. These are keyed by the identity
# triple instead — the per-round sibling of the (seed, id) link draws — via
# a vectorized splitmix64 chain (no per-client Generator construction on
# the warm path).
# ---------------------------------------------------------------------------


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer over uint64 (wraps mod 2^64)."""
    x = x + np.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def client_round_u01(seed: int, round_idx: int, ids: np.ndarray,
                     lane: int) -> np.ndarray:
    """U(0, 1) draw keyed by ``(seed, round, client_id, lane)``.

    Position-free and vectorized: permuting or resampling the cohort
    permutes the outputs exactly (regression-tested); ``lane`` separates
    independent draws for the same triple. Never returns exactly 0 (the
    Box–Muller log below needs u > 0)."""
    ids64 = np.asarray(ids, np.int64).astype(np.uint64)
    h = np.full(ids64.shape, np.uint64(seed % 2 ** 64))
    h = _splitmix64(h ^ np.uint64(round_idx % 2 ** 64))
    h = _splitmix64(h ^ ids64)
    h = _splitmix64(h ^ np.uint64(lane))
    return ((h >> np.uint64(11)).astype(np.float64) + 1.0) * 2.0 ** -53


def _client_round_normal(seed: int, round_idx: int, ids: np.ndarray,
                         lane: int) -> np.ndarray:
    """Standard-normal draw per (seed, round, client_id) via Box–Muller
    over two hash lanes."""
    u1 = client_round_u01(seed, round_idx, ids, lane)
    u2 = client_round_u01(seed, round_idx, ids, lane + 1)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


@dataclass
class RoundTiming:
    """Timing/byte report for one simulated round.

    ``round_time_s`` is the server's wall-clock for the round — the
    straggler max, or the deadline-truncated value when a fault-tolerant
    round cuts stragglers (comm.faults). ``p50_client_time_s`` /
    ``p90_client_time_s`` are per-client completion-time quantiles over
    the cohort (0.0 for an empty round) — the deadline sweep picks its
    cutoffs from these."""

    round_time_s: float
    uplink_bytes: int
    downlink_bytes: int
    slowest_client: int
    mean_client_time_s: float
    client_times_s: np.ndarray
    p50_client_time_s: float = 0.0
    p90_client_time_s: float = 0.0


class SimulatedNetwork:
    """Per-client link state + per-round timing draws (deterministic).

    Link draws are LAZY over the participating index set (DESIGN.md
    §scale-out): each client's fixed bandwidth pair is drawn on first
    participation, keyed by ``(cfg.seed, client_id)`` and cached — so a
    client keeps its link across rounds, the draw is independent of
    participation order, two networks sharing a seed agree per client, and
    constructing a network for m = 10^6 clients allocates nothing."""

    def __init__(self, cfg: NetworkConfig, num_clients: int):
        self.cfg = cfg
        self.num_clients = num_clients
        self._links: dict = {}  # client id -> (up_bps, down_bps)
        # sorted snapshot of the cache for the vectorized warm path: the
        # per-round lookup is a numpy searchsorted over these, not a
        # Python loop over the cohort
        self._ids = np.empty(0, np.int64)
        self._ups = np.empty(0, np.float64)
        self._downs = np.empty(0, np.float64)

    def _draw_links(self, ids: np.ndarray) -> None:
        """Draw + cache the fixed link pair for uncached ids. The draw
        stays keyed by ``(cfg.seed, id)`` — one Generator per id, exactly
        the stream the original per-client loop consumed (bit-identical,
        regression-tested) — but only first-time participants ever reach
        this loop; warm rounds are pure numpy."""
        cfg = self.cfg
        mu = -0.5 * cfg.bandwidth_sigma ** 2
        raw = np.stack([
            np.random.default_rng((cfg.seed, int(c))).normal(
                mu, cfg.bandwidth_sigma, 2)
            for c in ids])
        lu, ld = np.exp(raw[:, 0]), np.exp(raw[:, 1])
        ups = cfg.uplink_mbps * 1e6 / 8.0 * lu
        downs = cfg.downlink_mbps * 1e6 / 8.0 * ld
        for c, u, d in zip(ids, ups, downs):
            self._links[int(c)] = (float(u), float(d))
        all_ids = np.concatenate([self._ids, ids])
        order = np.argsort(all_ids, kind="stable")
        self._ids = all_ids[order]
        self._ups = np.concatenate([self._ups, ups])[order]
        self._downs = np.concatenate([self._downs, downs])[order]

    def _links_for(self, idx: np.ndarray):
        """Fixed per-client heterogeneity for the given clients: a client
        on a bad link stays on it (cached, keyed by (seed, id)). O(n log
        cache) numpy once every cohort member has participated — no
        Python loop over the cohort (the loop at 10^5-client cohorts
        dominated the round)."""
        idx = np.asarray(idx, np.int64)
        if idx.size == 0:
            return np.empty(0), np.empty(0)
        pos = np.searchsorted(self._ids, idx)
        safe = np.minimum(pos, max(self._ids.size - 1, 0))
        hit = (self._ids[safe] == idx) if self._ids.size else \
            np.zeros(idx.size, bool)
        if not hit.all():
            self._draw_links(np.unique(idx[~hit]))
            pos = np.searchsorted(self._ids, idx)
        return self._ups[pos], self._downs[pos]

    def round(self, client_idx: Sequence[int], uplink_bytes_per_client: int,
              downlink_bytes_per_client: int, round_idx: int) -> RoundTiming:
        cfg = self.cfg
        idx = np.asarray(client_idx, np.int64)
        n = idx.size
        up_bps, down_bps = self._links_for(idx)
        # per-round draws keyed by (seed, round, client_id) — like the link
        # draws, a client's latency/straggler fate this round is a property
        # of the client, not of its position in a (re)sampled cohort
        z = _client_round_normal(cfg.seed, round_idx, idx, lane=0)
        latency = np.maximum(
            cfg.latency_ms + cfg.latency_jitter_ms * z, 1.0) / 1e3
        u = client_round_u01(cfg.seed, round_idx, idx, lane=2)
        slow = np.where(u < cfg.straggler_prob, cfg.straggler_slowdown, 1.0)
        t_down = latency + downlink_bytes_per_client / down_bps
        t_up = latency + uplink_bytes_per_client / up_bps
        per_client = slow * (t_down + cfg.compute_s + t_up)
        worst = int(np.argmax(per_client)) if n else -1
        return RoundTiming(
            round_time_s=float(per_client.max(initial=0.0)),
            uplink_bytes=int(uplink_bytes_per_client) * n,
            downlink_bytes=int(downlink_bytes_per_client) * n,
            slowest_client=int(idx[worst]) if n else -1,
            mean_client_time_s=float(per_client.mean()) if n else 0.0,
            client_times_s=per_client,
            p50_client_time_s=float(np.percentile(per_client, 50)) if n
            else 0.0,
            p90_client_time_s=float(np.percentile(per_client, 90)) if n
            else 0.0,
        )


class EventClock:
    """Host-side simulated event clock for the async engine (DESIGN.md
    §11): a priority queue of (absolute delivery time, payload) entries
    plus the server's current simulated time.

    ``push`` schedules a delivery; ``pop`` returns the earliest pending
    entry and advances ``now`` to its time (the server experiences
    deliveries in time order). Ties break on insertion order (a
    monotonically increasing sequence number), so the order — and
    everything downstream of it — is deterministic."""

    def __init__(self):
        self.now = 0.0
        self._heap: list = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time_s: float, payload) -> None:
        heapq.heappush(self._heap, (float(time_s), self._seq, payload))
        self._seq += 1

    def pop(self):
        """-> (time_s, payload) of the earliest pending delivery; advances
        ``now``. Pops are nondecreasing in time."""
        t, _, payload = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, payload
