"""Wire formats: serialize a compressed client delta to a flat ``uint8``
buffer and decode it back bit-exactly.

The rest of the repo *accounts* for communication analytically
(``Compressor.bits_per_message``, the paper's Table 1); this module actually
packs the bytes, so the bit counts become *measured* ``wire_bytes``. Every
message is

    [16-byte header][payload]

with the header carrying magic/version/codec/value-dtype plus ``d``, the
per-block keep count and the block size (all native-endian ``uint32`` — the
simulated network never crosses byte orders). Codecs:

``dense32``
    Raw fp32 coordinates — the uncompressed baseline, 32d bits + header.
``topk``
    Exact global top-k: ``k`` uint32 indices + ``k`` values. With fp32
    values this is the paper's "value + index per kept coordinate"
    (64 bits/coord); fp16/bf16 values halve the value bytes.
``blocktopk``
    The TPU-native blockwise top-k: per-block indices packed at
    ``ceil(log2(B))`` bits each (11 bits for B=2048 instead of 32) +
    values. ``value_dtype="int8"`` additionally quantizes values against a
    per-block fp32 scale (max|v|/127).
``sign``
    Scaled sign: one fp32 scale (‖x‖₁/d, or one per block when
    ``block > 0``) + 1 bit per coordinate — Table 1's 32 + d bits.

Bit-exactness: with the default ``value_dtype="float32"``,
``decode(encode(x)) == compressor.compress(x)`` bit-for-bit (same
``lax.top_k`` selection, same scatter; the sign codec and ``make_sign``
share the sign(0) := +1 convention). Narrower value dtypes round the kept
values through fp16/bf16/int8; error feedback stays exact because the
integration tracks the *decoded* value (core.sim wire mode).

Everything here is jit-safe: shapes depend only on ``d`` and the codec
config, so encode/decode trace into fixed-size byte-shuffling that runs
inside the federated round.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.compressors import (Compressor, Selection, block_layout,
                                    make_blocktopk, make_compressor,
                                    make_identity, make_sign, make_topk)

HEADER_BYTES = 16
MAGIC = 0xFC
VERSION = 1

CODEC_IDS = {"dense32": 1, "topk": 2, "blocktopk": 3, "sign": 4}
_VALUE_DTYPES = {
    "float32": (0, jnp.float32, 4),
    "float16": (1, jnp.float16, 2),
    "bfloat16": (2, jnp.bfloat16, 2),
    "int8": (3, jnp.int8, 1),
}


# ---------------------------------------------------------------------------
# byte-level helpers (all jit-safe)
# ---------------------------------------------------------------------------


def _to_bytes(x) -> jnp.ndarray:
    """Bitcast any array to a flat uint8 view (native byte order)."""
    if x.dtype == jnp.uint8:
        return x.reshape(-1)
    return lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


def _from_bytes(buf, dtype, count: int):
    """Inverse of ``_to_bytes``: read ``count`` items of ``dtype``."""
    if jnp.dtype(dtype) == jnp.uint8:
        return buf[:count]
    width = jnp.dtype(dtype).itemsize
    return lax.bitcast_convert_type(
        buf[: count * width].reshape(count, width), dtype)


def pack_uint(vals, nbits: int, impl: str = "jnp") -> jnp.ndarray:
    """Pack unsigned ints (< 2**nbits) at ``nbits`` bits each, MSB-first,
    into a uint8 stream (zero-padded to a whole byte).

    Word-wise shift/or accumulation (kernels.bitpack): never materializes
    the ``(count, nbits)`` bit matrix the naive formulation needs — for
    blocktopk's 11-bit indices that intermediate is a 32× blowup over the
    packed bytes. ``impl="pallas"`` routes through the Pallas kernel
    (byte-identical; compiled on TPU, interpreted elsewhere)."""
    from repro.kernels.bitpack import pack_uint as pack_uint_pl
    from repro.kernels.bitpack import pack_uint_words
    if impl == "pallas":
        return pack_uint_pl(vals, nbits)
    return pack_uint_words(vals, nbits)


def unpack_uint(buf, nbits: int, count: int, impl: str = "jnp") -> jnp.ndarray:
    """Inverse of ``pack_uint``."""
    from repro.kernels.bitpack import unpack_uint as unpack_uint_pl
    from repro.kernels.bitpack import unpack_uint_words
    if impl == "pallas":
        return unpack_uint_pl(buf, nbits, count)
    return unpack_uint_words(buf, nbits, count)


def _header(codec: str, vdtype: str, d: int, k: int, block: int):
    h = np.zeros(HEADER_BYTES, np.uint8)
    h[0], h[1] = MAGIC, VERSION
    h[2] = CODEC_IDS[codec]
    h[3] = _VALUE_DTYPES[vdtype][0]
    h[4:8] = np.frombuffer(np.uint32(d).tobytes(), np.uint8)
    h[8:12] = np.frombuffer(np.uint32(k).tobytes(), np.uint8)
    h[12:16] = np.frombuffer(np.uint32(block).tobytes(), np.uint8)
    return jnp.asarray(h)


def parse_header(buf) -> dict:
    """Host-side header validation/introspection (numpy, not jittable)."""
    h = np.asarray(buf[:HEADER_BYTES], np.uint8)
    if h[0] != MAGIC or h[1] != VERSION:
        raise ValueError(f"bad wire header: magic={h[0]:#x} version={h[1]}")
    names = {v: k for k, v in CODEC_IDS.items()}
    vnames = {v[0]: k for k, v in _VALUE_DTYPES.items()}
    return {
        "codec": names[int(h[2])],
        "value_dtype": vnames[int(h[3])],
        "d": int(h[4:8].view(np.uint32)[0]),
        "k": int(h[8:12].view(np.uint32)[0]),
        "block": int(h[12:16].view(np.uint32)[0]),
    }


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WireCodec:
    """A serializer for compressed deltas.

    ``encode(x, rng=None)`` maps a flat fp32 vector to a packed uint8
    buffer; ``decode(buf, d)`` maps it back to the dense fp32
    representation (``d`` must be the original length — it is static under
    jit). ``rng`` is the per-client key the integration threads through
    (core.stages) — today's codecs are deterministic and ignore it, but the
    plumbing keeps stochastic codecs (e.g. randomized rounding) from
    desyncing client streams later. ``nbytes(d)`` is the exact buffer size,
    so measured wire bytes are available without encoding. ``compressor``
    is the dense-path :class:`Compressor` this codec is the wire format of;
    ``exact`` states whether ``decode(encode(x)) == compressor.compress(x)``
    bit-for-bit.

    Selection fast path (sparse uplink, DESIGN.md §3): codecs whose payload
    is (value, index) pairs also provide

    * ``encode_from_selection(sel, d)`` — pack an already-computed
      :class:`Selection` (byte-identical to ``encode(x)`` when ``sel`` is
      the compressor's own selection of ``x``), so wire mode never re-runs
      ``lax.top_k`` on the dense vector;
    * ``decode_to_selection(buf, d)`` — unpack straight back to a
      :class:`Selection` without materializing the dense vector;
    * ``roundtrip_selection(sel, d)`` — what the server receives:
      bit-identical shortcut for
      ``decode_to_selection(encode_from_selection(sel, d), d)`` that skips
      the byte shuffling (the identity for ``exact`` codecs; the pure
      value-narrowing for fp16/bf16/int8 values — indices always survive
      the wire exactly). Property-tested against the full byte roundtrip.
    """

    name: str
    encode: Callable
    decode: Callable
    nbytes: Callable
    compressor: Compressor
    exact: bool = True
    header_bytes: int = field(default=HEADER_BYTES)
    encode_from_selection: Optional[Callable] = None
    decode_to_selection: Optional[Callable] = None
    roundtrip_selection: Optional[Callable] = None


def make_dense32_codec() -> WireCodec:
    def encode(x, rng=None):
        flat = x.reshape(-1).astype(jnp.float32)
        return jnp.concatenate(
            [_header("dense32", "float32", flat.size, 0, 0), _to_bytes(flat)])

    def decode(buf, d: int):
        return _from_bytes(buf[HEADER_BYTES:], jnp.float32, d)

    return WireCodec(name="dense32", encode=encode, decode=decode,
                     nbytes=lambda d: HEADER_BYTES + 4 * d,
                     compressor=make_identity())


def make_topk_codec(ratio: float, value_dtype: str = "float32") -> WireCodec:
    if value_dtype not in ("float32", "float16", "bfloat16"):
        raise ValueError(f"topk codec: unsupported value_dtype {value_dtype!r}")
    _, vdt, vb = _VALUE_DTYPES[value_dtype]

    def k_of(d: int) -> int:
        return max(1, int(round(ratio * d)))

    def encode_from_selection(sel: Selection, d: int):
        vals = sel.vals.astype(vdt)
        return jnp.concatenate([
            _header("topk", value_dtype, d, k_of(d), 0),
            _to_bytes(sel.idx.astype(jnp.uint32)), _to_bytes(vals)])

    def encode(x, rng=None):
        flat = x.reshape(-1).astype(jnp.float32)
        d = flat.size
        k = k_of(d)
        _, idx = lax.top_k(jnp.abs(flat), k)
        return encode_from_selection(
            Selection(vals=flat[idx], idx=idx.astype(jnp.int32)), d)

    def decode_to_selection(buf, d: int) -> Selection:
        k = k_of(d)
        off = HEADER_BYTES
        idx = _from_bytes(buf[off:], jnp.uint32, k)
        vals = _from_bytes(buf[off + 4 * k:], vdt, k).astype(jnp.float32)
        return Selection(vals=vals, idx=idx.astype(jnp.int32))

    def decode(buf, d: int):
        sel = decode_to_selection(buf, d)
        return jnp.zeros(d, jnp.float32).at[sel.idx].set(sel.vals)

    def roundtrip_selection(sel: Selection, d: int) -> Selection:
        if value_dtype == "float32":
            return sel
        return Selection(vals=sel.vals.astype(vdt).astype(jnp.float32),
                         idx=sel.idx)

    return WireCodec(
        name=f"topk_{ratio:g}_{value_dtype}", encode=encode, decode=decode,
        nbytes=lambda d: HEADER_BYTES + k_of(d) * (4 + vb),
        compressor=make_topk(ratio), exact=value_dtype == "float32",
        encode_from_selection=encode_from_selection,
        decode_to_selection=decode_to_selection,
        roundtrip_selection=roundtrip_selection)


def make_blocktopk_codec(ratio: float, block: int = 2048,
                         value_dtype: str = "float32",
                         pack_impl: str = "jnp") -> WireCodec:
    """``pack_impl="pallas"`` packs/unpacks the sub-word index stream with
    the kernels.bitpack Pallas kernels (byte-identical to the jnp path)."""
    if pack_impl not in ("jnp", "pallas"):
        raise ValueError(f"unknown pack_impl {pack_impl!r}")
    _, vdt, vb = _VALUE_DTYPES[value_dtype]
    int8 = value_dtype == "int8"

    def layout(d: int):
        bs, nb = block_layout(d, block)
        kb = max(1, int(round(ratio * bs)))
        ib = max(1, math.ceil(math.log2(bs)))
        return bs, nb, kb, ib

    def _quantize(vals):
        """Per-block int8 quantization of (nb, kb) kept values; returns
        (scale (nb,), q (nb, kb) int8)."""
        scale = jnp.maximum(jnp.max(jnp.abs(vals), axis=1), 1e-30) / 127.0
        return scale, jnp.round(vals / scale[:, None]).astype(jnp.int8)

    def encode_from_selection(sel: Selection, d: int):
        bs, nb, kb, ib = layout(d)
        # Selection carries padded-domain global positions in block order;
        # the wire packs block-local offsets at ib bits each.
        gidx = sel.idx.reshape(nb, kb)
        idx = gidx - (jnp.arange(nb, dtype=jnp.int32) * bs)[:, None]
        vals = sel.vals.reshape(nb, kb)
        parts = [_header("blocktopk", value_dtype, d, kb, bs),
                 pack_uint(idx.astype(jnp.uint32), ib, pack_impl)]
        if int8:
            scale, q = _quantize(vals)
            parts += [_to_bytes(scale.astype(jnp.float32)),
                      lax.bitcast_convert_type(q, jnp.uint8).reshape(-1)]
        else:
            parts.append(_to_bytes(vals.astype(vdt)))
        return jnp.concatenate(parts)

    def encode(x, rng=None):
        flat = x.reshape(-1).astype(jnp.float32)
        d = flat.size
        bs, nb, kb, ib = layout(d)
        xb = jnp.pad(flat, (0, nb * bs - d)).reshape(nb, bs)
        _, idx = lax.top_k(jnp.abs(xb), kb)              # (nb, kb)
        vals = jnp.take_along_axis(xb, idx, axis=1)
        gidx = idx.astype(jnp.int32) + (jnp.arange(nb, dtype=jnp.int32)
                                        * bs)[:, None]
        return encode_from_selection(
            Selection(vals=vals.reshape(-1), idx=gidx.reshape(-1)), d)

    def decode_to_selection(buf, d: int) -> Selection:
        bs, nb, kb, ib = layout(d)
        off = HEADER_BYTES
        nidx = (nb * kb * ib + 7) // 8
        idx = unpack_uint(buf[off:off + nidx], ib, nb * kb,
                          pack_impl).reshape(nb, kb)
        off += nidx
        if int8:
            scale = _from_bytes(buf[off:], jnp.float32, nb)
            off += 4 * nb
            q = lax.bitcast_convert_type(buf[off:off + nb * kb], jnp.int8)
            vals = q.reshape(nb, kb).astype(jnp.float32) * scale[:, None]
        else:
            vals = _from_bytes(buf[off:], vdt, nb * kb)
            vals = vals.reshape(nb, kb).astype(jnp.float32)
        gidx = idx.astype(jnp.int32) + (jnp.arange(nb, dtype=jnp.int32)
                                        * bs)[:, None]
        return Selection(vals=vals.reshape(-1), idx=gidx.reshape(-1))

    def decode(buf, d: int):
        bs, nb, kb, ib = layout(d)
        sel = decode_to_selection(buf, d)
        out = jnp.zeros(nb * bs, jnp.float32).at[sel.idx].set(sel.vals)
        return out[:d]

    def roundtrip_selection(sel: Selection, d: int) -> Selection:
        if value_dtype == "float32":
            return sel
        bs, nb, kb, ib = layout(d)
        vals = sel.vals.reshape(nb, kb)
        if int8:
            scale, q = _quantize(vals)
            vals = q.astype(jnp.float32) * scale.astype(
                jnp.float32)[:, None]
        else:
            vals = vals.astype(vdt).astype(jnp.float32)
        return Selection(vals=vals.reshape(-1), idx=sel.idx)

    def nbytes(d: int) -> int:
        bs, nb, kb, ib = layout(d)
        n = HEADER_BYTES + (nb * kb * ib + 7) // 8
        return n + (4 * nb + nb * kb if int8 else nb * kb * vb)

    return WireCodec(
        name=f"blocktopk_{ratio:g}_{value_dtype}", encode=encode,
        decode=decode, nbytes=nbytes,
        compressor=make_blocktopk(ratio, block),
        exact=value_dtype == "float32",
        encode_from_selection=encode_from_selection,
        decode_to_selection=decode_to_selection,
        roundtrip_selection=roundtrip_selection)


def _pack_sign_bits(bits_u8, pack_impl: str):
    if pack_impl == "pallas":
        from repro.kernels.bitpack import DEFAULT_BLOCK, pack_bits
        n = bits_u8.size
        pad = -n % DEFAULT_BLOCK
        return pack_bits(jnp.pad(bits_u8, (0, pad)))[: (n + 7) // 8]
    return jnp.packbits(bits_u8)


def _unpack_sign_bits(buf, d: int, pack_impl: str):
    if pack_impl == "pallas":
        from repro.kernels.bitpack import DEFAULT_BLOCK, unpack_bits
        pad = -buf.size % (DEFAULT_BLOCK // 8)
        return unpack_bits(jnp.pad(buf, (0, pad)))[:d]
    return jnp.unpackbits(buf, count=d)


def make_sign_codec(block: int = 0, pack_impl: str = "jnp") -> WireCodec:
    """1 bit/coordinate + fp32 scale(s). ``block=0``: one global ‖x‖₁/d
    scale — the paper's Table 1 format and bit-exact vs ``make_sign``.
    ``block>0``: one scale per block of that size (beyond-paper; tighter
    local scales at 32 bits/block extra). ``pack_impl="pallas"`` routes the
    1-bit packing through the kernels.bitpack Pallas kernels (the TPU
    hot-loop implementation; byte-identical to the default jnp path)."""
    if pack_impl not in ("jnp", "pallas"):
        raise ValueError(f"unknown pack_impl {pack_impl!r}")

    def nb_of(d: int) -> int:
        return 1 if block <= 0 else -(-d // block)

    def scales_of(flat, d: int):
        if block <= 0:
            return jnp.mean(jnp.abs(flat)).reshape(1)
        nb = nb_of(d)
        xb = jnp.pad(jnp.abs(flat), (0, nb * block - d)).reshape(nb, block)
        # per-block mean of |x| over the *real* (unpadded) elements
        counts = jnp.clip(d - jnp.arange(nb) * block, 0, block)
        return jnp.sum(xb, axis=1) / counts

    def encode(x, rng=None):
        flat = x.reshape(-1).astype(jnp.float32)
        d = flat.size
        return jnp.concatenate([
            _header("sign", "float32", d, 0, max(block, 0)),
            _to_bytes(scales_of(flat, d)),
            _pack_sign_bits((flat >= 0).astype(jnp.uint8), pack_impl)])

    def decode(buf, d: int):
        nb = nb_of(d)
        scales = _from_bytes(buf[HEADER_BYTES:], jnp.float32, nb)
        bits = _unpack_sign_bits(buf[HEADER_BYTES + 4 * nb:], d, pack_impl)
        sgn = bits.astype(jnp.float32) * 2.0 - 1.0
        if block <= 0:
            return scales[0] * sgn
        per_coord = jnp.repeat(scales, block)[:d]
        return per_coord * sgn

    def dense_compress(x, rng=None):
        flat = x.reshape(-1).astype(jnp.float32)
        return decode(encode(flat), flat.size).reshape(x.shape)

    base = make_sign()
    comp = base if block <= 0 else Compressor(
        name=f"sign_b{block}", compress=dense_compress,
        bits_per_message=lambda d: 32 * nb_of(d) + d, q_bound=base.q_bound)

    return WireCodec(
        name="sign" if block <= 0 else f"sign_b{block}",
        encode=encode, decode=decode,
        nbytes=lambda d: HEADER_BYTES + 4 * nb_of(d) + (d + 7) // 8,
        compressor=comp)


def make_wire_codec(name: str, ratio: float = 1 / 64, block: int = 2048,
                    value_dtype: str = "float32",
                    pack_impl: str = "jnp") -> WireCodec:
    """Registry mirroring :func:`repro.core.compressors.make_compressor`."""
    if name in ("none", "identity", "dense32"):
        return make_dense32_codec()
    if name == "topk":
        return make_topk_codec(ratio, value_dtype)
    if name == "blocktopk":
        return make_blocktopk_codec(ratio, block, value_dtype, pack_impl)
    if name in ("sign", "packedsign"):
        return make_sign_codec(pack_impl=pack_impl)
    raise ValueError(
        f"no wire codec for compressor {name!r} (randk/int8 deltas have no "
        f"packed format yet — run them with wire=False)")


def measured_vs_analytic(codec: WireCodec, d: int) -> dict:
    """Measured wire size against the Table-1 analytic bit count."""
    analytic_bits = codec.compressor.bits_per_message(d)
    measured_bits = 8 * codec.nbytes(d)
    return {
        "codec": codec.name, "d": d,
        "measured_bytes": codec.nbytes(d),
        "measured_bits": measured_bits,
        "analytic_bits": analytic_bits,
        "header_bits": 8 * codec.header_bytes,
        "overhead_bits": measured_bits - analytic_bits,
    }
