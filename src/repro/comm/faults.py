"""Fault injection + deadline-robust rounds (DESIGN.md §robustness).

A million-user deployment sees crashes, timeouts, and corrupt payloads
every round; this module makes those events first-class and deterministic
so robustness is a property the tests can pin down, not hope for.

Three pieces:

* :class:`FaultConfig` — the declarative fault model: per-round client
  crash probability, scheduled crash windows (``crash_trace``), payload
  corruption (NaN/Inf injection, bit flips, truncation) and the server's
  round ``deadline_s``.
* :class:`FaultInjector` — the host-side planner that sits between
  ``SimulatedNetwork`` and the jitted round (core/sim.py): given the
  cohort and the round's simulated client times it emits a
  :class:`FaultPlan` of per-client arrays (survivor mask, corruption
  flags, bit-flip masks, truncation cuts), all drawn deterministically
  from ``(seed, round)`` with numpy — exactly like the transport's own
  draws.
* Pure jnp stages — :func:`corrupt_selection` / :func:`corrupt_dense`
  apply the wire damage inside the trace, and :func:`validate_selection`
  / :func:`validate_dense` are the server's validation-before-ingest
  gate: NaN/Inf rejection, index-range checks and optional per-client
  norm clipping, so one poisoned payload cannot corrupt the FedAMS
  m/v/v̂ state. Rejected clients are excluded from the masked aggregate
  (and NACKed: their EF residual stays stale, the same semantics
  ``core/error_feedback.py`` documents for dropped clients).

The mesh backend draws its fault mask in-trace from the shared round rng
(:func:`mesh_fault_mask`) — every device must agree on who crashed
without host round-trips — so the two backends share semantics, not
streams.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import numpy as np

#: Known payload corruption modes (validated at FaultConfig construction).
FAULT_CORRUPT_MODES = ("nan", "inf", "bitflip", "truncate")

#: Sentinel index for truncated-away selection entries: far outside any
#: leaf's padded block domain, so the range check rejects the client and
#: the scatter would drop the entry even if it slipped through.
INVALID_IDX = np.int32(2 ** 30)


@dataclass(frozen=True)
class FaultConfig:
    """Declarative fault model for one run (frozen, jit-closure safe).

    ``crash_prob`` — P(a sampled client crashes this round) — independent
    per (round, cohort slot), deterministic in ``(seed, round)``.
    ``crash_trace`` — scheduled outages: ``(client_id, from_round,
    to_round)`` half-open windows during which that client is dead; an
    open-ended entry (``to_round`` large) is a persistent crash.
    ``corrupt_prob``/``corrupt_mode`` — P(a *delivered* payload was
    damaged in transit) and how: ``nan``/``inf`` poison the values,
    ``bitflip`` XORs random bits into the value words (and knocks an
    index out of range, the checksum-less reality of a flipped header),
    ``truncate`` cuts a suffix of the entries (a short read — the length
    check rejects it).
    ``deadline_s`` — server-side round deadline: clients whose simulated
    finish time exceeds it are cut (FedSim wire mode only — the mesh has
    no transport clock). 0 = wait for every survivor.
    ``max_update_norm`` — optional per-client L2 clip applied by the
    server to validated values before ingest. 0 = off.
    """

    crash_prob: float = 0.0
    crash_trace: Tuple[Tuple[int, int, int], ...] = ()
    corrupt_prob: float = 0.0
    corrupt_mode: str = "nan"
    deadline_s: float = 0.0
    max_update_norm: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.crash_prob <= 1.0:
            raise ValueError(
                f"FaultConfig.crash_prob={self.crash_prob} must be in [0, 1]")
        if not 0.0 <= self.corrupt_prob <= 1.0:
            raise ValueError(
                f"FaultConfig.corrupt_prob={self.corrupt_prob} must be in "
                f"[0, 1]")
        if self.corrupt_mode not in FAULT_CORRUPT_MODES:
            raise ValueError(
                f"FaultConfig.corrupt_mode={self.corrupt_mode!r} is not one "
                f"of {FAULT_CORRUPT_MODES}")
        if self.deadline_s < 0:
            raise ValueError(
                f"FaultConfig.deadline_s={self.deadline_s} must be >= 0")
        if self.max_update_norm < 0:
            raise ValueError(
                f"FaultConfig.max_update_norm={self.max_update_norm} must "
                f"be >= 0")
        for entry in self.crash_trace:
            if len(entry) != 3 or entry[1] > entry[2] or entry[0] < 0:
                raise ValueError(
                    f"FaultConfig.crash_trace entry {entry!r} must be "
                    f"(client_id >= 0, from_round, to_round) with "
                    f"from_round <= to_round")

    @property
    def any_faults(self) -> bool:
        return bool(self.crash_prob or self.crash_trace or self.corrupt_prob
                    or self.deadline_s or self.max_update_norm)


class FaultPlan(NamedTuple):
    """Per-client fault arrays for ONE round, fed into the jitted round.

    ``survivors`` (n,) f32 — 1.0 for clients whose payload reached the
    server (not crashed, not past the deadline); the in-trace validation
    mask multiplies into this. ``corrupt`` (n,) f32 — 1.0 where the
    delivered payload is damaged. ``xor_bits`` (n,) uint32 — the bit-flip
    masks (0 for clean clients). ``trunc_keep`` (n,) f32 — kept fraction
    of the selection entries under truncation (1.0 for clean clients)."""

    survivors: np.ndarray
    corrupt: np.ndarray
    xor_bits: np.ndarray
    trunc_keep: np.ndarray


class FaultInjector:
    """Deterministic host-side fault planner (FedSim).

    Sits between :class:`~repro.comm.transport.SimulatedNetwork` and the
    round: :meth:`plan` consumes the cohort ids, the round index and
    (when wire mode runs) the round's :class:`RoundTiming`, and returns
    the :class:`FaultPlan` plus a host-side info dict — survivor /
    crashed / deadline-cut counts and the deadline-truncated
    ``round_time_s`` (the cutoff turns the straggler max into a
    quantile). All draws come from ``default_rng((cfg.seed, 0xFA017,
    round))`` so a run is reproducible given the config alone."""

    def __init__(self, cfg: FaultConfig, num_clients: int):
        self.cfg = cfg
        self.num_clients = num_clients
        self._trace = tuple(cfg.crash_trace)

    def _trace_dead(self, idx: np.ndarray, round_idx: int) -> np.ndarray:
        dead = np.zeros(idx.size, bool)
        for cid, r0, r1 in self._trace:
            if r0 <= round_idx < r1:
                dead |= idx == cid
        return dead

    def plan(self, client_idx, round_idx: int,
             timing: Optional[object] = None):
        """(cohort ids, round, optional RoundTiming) -> (FaultPlan, info).

        ``info`` keys: ``survivors`` (delivered count before server
        validation), ``crashed``, ``deadline_cut``, and ``round_time_s``
        — the effective round wall-clock: with a deadline the server
        stops waiting at ``deadline_s`` whenever anyone failed to
        deliver; without one, crashed clients' connections reset (their
        times drop out of the max)."""
        cfg = self.cfg
        idx = np.asarray(client_idx, np.int64)
        n = idx.size
        rng = np.random.default_rng((cfg.seed, 0xFA017, int(round_idx)))
        crashed = rng.random(n) < cfg.crash_prob if cfg.crash_prob else \
            np.zeros(n, bool)
        crashed |= self._trace_dead(idx, int(round_idx))
        # corruption draws are burned even for crashed clients so the
        # stream per (seed, round) is independent of who crashed
        corrupt = rng.random(n) < cfg.corrupt_prob if cfg.corrupt_prob else \
            np.zeros(n, bool)
        xor_bits = rng.integers(1, 2 ** 32, size=n, dtype=np.uint32)
        trunc_keep = rng.random(n)
        late = np.zeros(n, bool)
        times = None if timing is None else np.asarray(timing.client_times_s)
        if cfg.deadline_s > 0:
            if times is None:
                raise ValueError(
                    "FaultConfig.deadline_s > 0 needs the round's "
                    "RoundTiming (wire mode) — without simulated client "
                    "times there is nothing to cut")
            late = ~crashed & (times > cfg.deadline_s)
        delivered = ~crashed & ~late
        corrupt &= delivered
        if times is not None and n:
            delivered_times = times[delivered]
            if cfg.deadline_s > 0 and not delivered.all():
                round_time = float(cfg.deadline_s)
            elif delivered_times.size:
                round_time = float(delivered_times.max())
            else:
                round_time = 0.0
        else:
            round_time = None if timing is None else 0.0
        plan = FaultPlan(
            survivors=delivered.astype(np.float32),
            corrupt=corrupt.astype(np.float32),
            xor_bits=np.where(corrupt, xor_bits, np.uint32(0)),
            trunc_keep=np.where(corrupt, trunc_keep, 1.0).astype(np.float32),
        )
        info = {
            "survivors": float(delivered.sum()),
            "crashed": float(crashed.sum()),
            "deadline_cut": float(late.sum()),
        }
        if round_time is not None:
            info["round_time_s"] = round_time
        return plan, info


# ---------------------------------------------------------------------------
# In-trace stages: wire corruption + server validation-before-ingest
# (pure jnp; imported lazily so host-only users never pay the jax import)
# ---------------------------------------------------------------------------


def corrupt_selection(vals, idx, plan: FaultPlan, mode: str):
    """Apply the configured wire damage to received ``(vals, idx)``
    selections. ``vals``/``idx``: (..., k); the plan arrays broadcast over
    the leading dims. Runs AFTER the client booked its EF residual — the
    client believes its clean send succeeded; the damage is in transit."""
    import jax.numpy as jnp
    from jax import lax

    c = plan.corrupt[..., None] > 0
    if mode == "nan":
        return jnp.where(c, jnp.nan, vals), idx
    if mode == "inf":
        return jnp.where(c, jnp.inf, vals), idx
    if mode == "bitflip":
        bits = lax.bitcast_convert_type(vals.astype(jnp.float32), jnp.uint32)
        flipped = lax.bitcast_convert_type(
            bits ^ plan.xor_bits[..., None], jnp.float32)
        vals = jnp.where(c, flipped, vals)
        # a flipped length/offset word knocks an index out of the leaf's
        # padded domain — give the server's range check a deterministic
        # trigger on entry 0
        k = idx.shape[-1]
        hit = c & (jnp.arange(k) == 0)
        idx = jnp.where(hit, idx ^ jnp.int32(2 ** 29), idx)
        return vals, idx
    if mode == "truncate":
        k = vals.shape[-1]
        cut = jnp.floor(plan.trunc_keep[..., None] * k)  # in [0, k-1]
        dropped = c & (jnp.arange(k) >= cut)
        return (jnp.where(dropped, 0.0, vals),
                jnp.where(dropped, INVALID_IDX, idx))
    raise ValueError(f"unknown corrupt_mode {mode!r}")


def corrupt_dense(hats, plan: FaultPlan, mode: str):
    """Dense-path sibling of :func:`corrupt_selection` for (..., d) client
    rows. ``truncate`` zeroes the row's suffix; the server's length check
    (:func:`validate_dense` with ``truncated``) rejects it."""
    import jax.numpy as jnp
    from jax import lax

    c = plan.corrupt[..., None] > 0
    if mode == "nan":
        return jnp.where(c, jnp.nan, hats)
    if mode == "inf":
        return jnp.where(c, jnp.inf, hats)
    if mode == "bitflip":
        bits = lax.bitcast_convert_type(hats.astype(jnp.float32), jnp.uint32)
        flipped = lax.bitcast_convert_type(
            bits ^ plan.xor_bits[..., None], jnp.float32)
        return jnp.where(c, flipped, hats)
    if mode == "truncate":
        d = hats.shape[-1]
        cut = jnp.floor(plan.trunc_keep[..., None] * d)
        return jnp.where(c & (jnp.arange(d) >= cut), 0.0, hats)
    raise ValueError(f"unknown corrupt_mode {mode!r}")


def validate_selection(vals, idx, domain: int, max_norm: float = 0.0):
    """Server-side validation before ingest for ``(..., k)`` selections.

    Returns ``(vals', valid)``: ``valid`` (...,) f32 is 1.0 where every
    value is finite and every index sits inside ``[0, domain)`` (the
    leaf's zero-padded block domain — legitimate padded-tail entries pass,
    a flipped or truncated index does not); ``vals'`` has invalid clients'
    values replaced by 0 — NEVER multiply a NaN by a mask — and, when
    ``max_norm > 0``, each client's values clipped to that L2 norm."""
    import jax.numpy as jnp

    finite = jnp.all(jnp.isfinite(vals), axis=-1)
    inrange = jnp.all((idx >= 0) & (idx < domain), axis=-1)
    valid = (finite & inrange).astype(jnp.float32)
    vals = jnp.where(valid[..., None] > 0, vals, 0.0)
    if max_norm > 0:
        nrm = jnp.sqrt(jnp.sum(vals * vals, axis=-1, keepdims=True))
        vals = vals * jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-30))
    return vals, valid


def validate_dense(hats, max_norm: float = 0.0, truncated=None):
    """Dense-path validation: finite check per (..., d) client row, the
    length check (``truncated`` — 1.0 where the payload arrived short),
    and the optional per-client norm clip."""
    import jax.numpy as jnp

    valid = jnp.all(jnp.isfinite(hats), axis=-1)
    if truncated is not None:
        valid &= truncated == 0
    valid = valid.astype(jnp.float32)
    hats = jnp.where(valid[..., None] > 0, hats, 0.0)
    if max_norm > 0:
        nrm = jnp.sqrt(jnp.sum(hats * hats, axis=-1, keepdims=True))
        hats = hats * jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-30))
    return hats, valid


def mesh_fault_mask(cfg: FaultConfig, rng, m: int, round_idx):
    """(m,) f32 alive-mask for the mesh backend, drawn in-trace from the
    shared per-round rng so every device agrees without host round-trips.
    Crash draws use an independent fold of ``rng``; ``crash_trace``
    windows are static tuples evaluated against the traced round index.
    Semantics match the FedSim injector (different streams — the backends
    share the fault *model*, not the draws)."""
    import jax
    import jax.numpy as jnp

    alive = jnp.ones((m,), jnp.float32)
    if cfg.crash_prob > 0:
        u = jax.random.uniform(jax.random.fold_in(rng, 0xFA017), (m,))
        alive = alive * (u >= cfg.crash_prob).astype(jnp.float32)
    for cid, r0, r1 in cfg.crash_trace:
        in_win = (round_idx >= r0) & (round_idx < r1)
        alive = alive.at[cid].set(jnp.where(in_win, 0.0, alive[cid]))
    return alive


def mesh_corruption_plan(cfg: FaultConfig, rng, m: int) -> FaultPlan:
    """Shared in-trace corruption draws for the mesh round: every device
    computes the same (m,) flag/xor/cut arrays from the round rng; device
    i damages its own payload with row i before the client-axis gather
    (so the server — and client i itself, for the NACK — sees the
    corrupted copy)."""
    import jax
    import jax.numpy as jnp

    corrupt = (jax.random.uniform(jax.random.fold_in(rng, 0xFA018), (m,))
               < cfg.corrupt_prob).astype(jnp.float32)
    xor = jax.random.bits(jax.random.fold_in(rng, 0xFA019), (m,), jnp.uint32)
    keep = jax.random.uniform(jax.random.fold_in(rng, 0xFA01A), (m,))
    return FaultPlan(
        survivors=jnp.ones((m,), jnp.float32),
        corrupt=corrupt,
        xor_bits=jnp.where(corrupt > 0, xor, jnp.uint32(0)),
        trunc_keep=jnp.where(corrupt > 0, keep, 1.0).astype(jnp.float32),
    )
