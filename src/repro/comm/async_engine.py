"""Event-driven async buffered rounds (DESIGN.md §11).

A FedBuff-style server loop on top of the simulated network: instead of
waiting for the slowest client (sync) or amputating stragglers at a
deadline (comm.faults), the server reacts to *deliveries*. Each staged
cohort is dispatched at the server's current simulated time; per-client
completion times (the same ``(seed, round, client_id)``-keyed transport
draws the sync round maxes over) schedule delivery events on an
:class:`~repro.comm.transport.EventClock`; every ``FedConfig.async_buffer``
deliveries the server fires one buffered aggregation, weighting each
entry by its staleness τ (server versions advanced since its dispatch).

The device work stays jitted with fixed shapes: a dispatch step is the
existing select-once sparse uplink (EF booked at dispatch), a flush step
consumes a fixed ``(B, k)`` masked buffer plus a weight/fill vector
through the validated scatter (or the fused FedAMS ingest via an exact
pre-scale). The engine itself is host-side Python — the same layer the
transport already lives on — so the event queue never enters a trace.

Determinism & parity anchor
---------------------------
Every draw is keyed by identity triples and the event queue breaks time
ties by insertion order, so a run is a pure function of (config, seed).
Buffered entries are ingested in canonical ``(dispatch cohort, slot)``
order, not arrival order. With ``async_buffer == cohort size`` and unit
staleness weights the loop degenerates to dispatch → full-cohort flush →
dispatch, and every flush is bit-identical to the sync round
(regression-tested): the acceptance anchor all async numbers flow
through.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.faults import FaultPlan
from repro.comm.transport import EventClock, RoundTiming

# Staleness weight rules w(τ), τ = server versions advanced between an
# entry's dispatch and its ingest. All rules give w(0) = 1.0 exactly (in
# float64 AND after the float32 cast), which is what makes the
# buffer==cohort parity anchor hold for every rule, not just "uniform".
STALENESS_WEIGHTS = {
    "uniform": lambda tau: np.ones_like(tau),
    "inv_sqrt": lambda tau: 1.0 / np.sqrt(1.0 + tau),
    "inv_linear": lambda tau: 1.0 / (1.0 + tau),
    "exp": lambda tau: np.exp(-0.5 * tau),
}


def resolve_staleness_weight(name: str) -> Callable[[np.ndarray], np.ndarray]:
    """Look up a staleness rule by ``FedConfig.staleness_weight`` name."""
    try:
        return STALENESS_WEIGHTS[name]
    except KeyError:
        raise ValueError(
            f"unknown staleness weight {name!r}; known: "
            f"{sorted(STALENESS_WEIGHTS)}") from None


class _Delivery(NamedTuple):
    """One client payload in flight (host-side numpy rows)."""
    cohort: int        # staged dispatch index r — canonical sort key 1
    slot: int          # position in its cohort — canonical sort key 2
    client: int        # global client id (diagnostics)
    vals: np.ndarray   # (k,) received selection values
    idx: np.ndarray    # (k,) flat coordinate indices
    loss: float        # this client's local training loss
    t_sent: float      # server sim-time at dispatch
    version: int       # server version at dispatch (staleness base)


class AsyncRoundEngine:
    """Host-side event loop driving a FedSim's jitted dispatch/flush steps.

    ``weight_fn`` (optional) overrides the configured staleness rule with
    any ``τ-array -> weight-array`` callable (the pluggable hook the
    config's string registry is a front-end for).
    """

    def __init__(self, sim, weight_fn: Optional[Callable] = None):
        self.sim = sim
        self.buffer = int(sim.fed.async_buffer)
        self.weight_fn = weight_fn or resolve_staleness_weight(
            sim.fed.staleness_weight)

    def run(self, state, client_batches, client_idx, rngs):
        """Consume ALL staged cohorts; return ``(new_state, mets)``.

        ``client_idx``: (R, n) staged cohorts; one metric dict per FLUSH —
        ``ceil(total deliveries / B)`` of them, which only equals R when
        ``B == n`` and nobody crashes. ``state.round`` advances per flush
        (the server-version counter staleness is measured against).
        """
        sim = self.sim
        B = self.buffer
        sim._ensure_async_fns()
        idx_host = np.asarray(client_idx)
        R, n = int(idx_host.shape[0]), int(idx_host.shape[1])
        if B > n:
            raise ValueError(
                f"async_buffer={B} exceeds the staged cohort size n={n} — "
                f"a flush could never fill")
        up_pc = sim.codec.nbytes(sim._d)
        down_pc = sim._down_codec.nbytes(sim._d)
        bpm = int(sim.comp.bits_per_message(sim._d))
        clock = EventClock()
        errors = state.errors
        core = (state.params, state.opt, state.server_error, state.x_client)
        version = 0          # server flushes so far == len(mets)
        next_r = 0           # next staged cohort to dispatch
        # byte/fault tallies accumulated since the last flush (a flush
        # bills everything dispatched on its watch)
        pend = {"attempted": 0, "down": 0, "crashed": 0.0}
        bits = state.bits
        t_prev = 0.0
        mets = []

        def dispatch(r: int) -> None:
            nonlocal errors
            ridx = state.round + r  # absolute round: the transport/fault
            # draw key AND the local-LR schedule index, same as sync staging
            timing = sim.network.round(idx_host[r], up_pc, down_pc, ridx)
            delivered = np.ones(n, bool)
            fplan_dev = None
            if sim.faults is not None:
                fplan, finfo = sim.faults.plan(idx_host[r], ridx, timing)
                delivered = fplan.survivors > 0  # crashed never deliver
                pend["crashed"] += finfo["crashed"]
                fplan_dev = FaultPlan(*(jnp.asarray(a) for a in fplan))
            batches_r = jax.tree.map(lambda x: x[r], client_batches)
            errors, vals, sidx, losses = sim._async_dispatch_fn(
                errors, core[3], batches_r, jnp.asarray(idx_host[r]),
                rngs[r], jnp.int32(ridx), fplan_dev)
            vals_h, sidx_h = np.asarray(vals), np.asarray(sidx)
            losses_h = np.asarray(losses)
            t0 = clock.now  # dispatched at the server's current sim time
            for i in range(n):
                if delivered[i]:
                    clock.push(
                        t0 + float(timing.client_times_s[i]),
                        _Delivery(r, i, int(idx_host[r, i]), vals_h[i],
                                  sidx_h[i], float(losses_h[i]), t0,
                                  version))
            pend["attempted"] += timing.uplink_bytes
            pend["down"] += timing.downlink_bytes

        high_water = max(B, n)
        while True:
            # dispatch-ahead up to the high-water mark: keep a full
            # cohort's worth of deliveries in flight so a straggler from
            # cohort r never starves the buffer — cohorts r and r+1
            # overlap and the server keeps flushing on the fast clients'
            # cadence. At B == n the mark equals the buffer, so the loop
            # degenerates exactly to the sync cadence (dispatch →
            # full-cohort flush → dispatch): the parity anchor
            while len(clock) < high_water and next_r < R:
                dispatch(next_r)
                next_r += 1
            if len(clock) == 0:
                break  # every staged cohort dispatched and drained
            take = min(B, len(clock))
            popped = [clock.pop() for _ in range(take)]  # time-ordered
            t_now = clock.now
            # canonical buffer order (dispatch cohort, slot), NOT arrival
            # order: the flush's scatter order — and hence its bit pattern
            # — is independent of arrival-time ties, and at B == n it is
            # exactly the sync cohort order (the parity anchor)
            entries = sorted((e for _, e in popped),
                             key=lambda e: (e.cohort, e.slot))
            k = entries[0].vals.shape[0]
            vals_buf = np.zeros((B, k), np.float32)
            idx_buf = np.zeros((B, k), np.int32)
            loss_buf = np.zeros((B,), np.float32)
            fill = np.zeros((B,), np.float32)
            tau = np.zeros((B,), np.float64)
            for s, e in enumerate(entries):
                vals_buf[s], idx_buf[s], loss_buf[s] = e.vals, e.idx, e.loss
                fill[s] = 1.0
                tau[s] = version - e.version
            w = (fill.astype(np.float64)
                 * self.weight_fn(tau)).astype(np.float32)
            core, met_dev = sim._async_flush_fn(
                core, jnp.asarray(vals_buf), jnp.asarray(idx_buf),
                jnp.asarray(w), jnp.asarray(fill), jnp.asarray(loss_buf))
            version += 1
            bits += take * bpm
            met = dict(jax.device_get(met_dev))
            # per-flush wall-clock is the event-time delta; the sojourn of
            # each ingested payload plays the per-client-time role
            sojourn = np.array([t - e.t_sent for t, e in popped])
            dt = t_now - t_prev
            t_prev = t_now
            timing = RoundTiming(
                round_time_s=dt,
                uplink_bytes=pend["attempted"],
                downlink_bytes=pend["down"],
                slowest_client=popped[-1][1].client,
                mean_client_time_s=float(sojourn.mean()),
                client_times_s=sojourn,
                p50_client_time_s=float(np.percentile(sojourn, 50)),
                p90_client_time_s=float(np.percentile(sojourn, 90)),
            )
            met.update(sim.comm_log.record(
                timing, delivered_uplink_bytes=take * up_pc))
            met["bits"] = bits
            met["staleness_mean"] = float(tau[:take].mean())
            met["staleness_max"] = float(tau[:take].max())
            met["buffer_fill"] = float(take)
            met["survivors"] = float(take) - float(met["rejected"])
            met["crashed"] = pend["crashed"]
            pend.update(attempted=0, down=0, crashed=0.0)
            mets.append(met)

        new_state = state._replace(
            params=core[0], opt=core[1], errors=errors,
            server_error=core[2], x_client=core[3], bits=bits,
            round=state.round + len(mets))
        return new_state, mets
