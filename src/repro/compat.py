"""Version shims for the jax APIs this repo uses from both the 0.4.x and
the >=0.7 (vma-era) lines.

``shard_map``
    Newer jax exposes ``jax.shard_map`` with varying-manual-axes (vma)
    tracking (``check_vma=True``), which our TP gradient correctness relies
    on. jax 0.4.x only has ``jax.experimental.shard_map.shard_map``; its
    ``check_rep=True`` replication checker predates the vma rules our model
    code is typed against (explicit ``pvary`` + invariant gathers), so on
    0.4.x we run with ``check_rep=False``. Forward-only paths (serving,
    seq-sharded decode) and client-axis federation are numerically
    identical; only multi-device TP *gradient* exactness needs the newer
    line (tests/test_sharding.py marks that test ``requires_vma``).

``pvary``
    ``lax.pvary`` (vma-type cast, no communication) does not exist on 0.4.x.
    There it is a no-op: with ``check_rep=False`` there is no replication
    typing to cast against.
"""
from __future__ import annotations

import jax
from jax import lax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` when available, else the 0.4.x experimental one.
    ``check_vma`` is forwarded on the vma line and ignored on 0.4.x (where
    the equivalent knob is ``check_rep``, see module docstring)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pvary(x, axis_names):
    """``lax.pvary`` when available (vma era), identity on 0.4.x."""
    if hasattr(lax, "pvary"):
        return lax.pvary(x, axis_names)
    return x
