"""Blockwise top-k + fused error feedback as a Pallas TPU kernel.

TPU adaptation of the paper's top-k compressor (DESIGN.md §3): a global
top-k over 10^8-10^9 gradient elements requires a full sort through HBM; the
blockwise variant streams fixed-size tiles HBM→VMEM, selects the top-k'
inside the tile (one pass + an in-register top_k), and writes both the
compressed tile and the residual error in the same pass — the error-feedback
update is fused, so the delta is read exactly once.

Two output layouts share the selection logic:

* :func:`topk_ef` — dense (hat, new_err), the historical contract used by
  ``KernelImpl.ef_compress_tree`` on the mesh path.
* :func:`topk_ef_sparse` — the compacted ``(vals, idx)`` block the sparse
  uplink keeps end-to-end (DESIGN.md §3), emitted directly from the same
  single HBM pass (plus ``new_err``); ``idx`` are global flat positions.

Selection keeps EXACTLY k entries per block with ``lax.top_k``'s
tie-breaking (lowest index first) — a pure threshold ``|x| >= kth`` keeps
more than k on ties, which breaks the wire format's fixed (vals, idx)
buffer sizes and the ``bits_per_message`` accounting
(tests/test_kernels.py ties regression).

The per-block contraction ‖C(x_b)−x_b‖² ≤ (1−k'/B)‖x_b‖² preserves the
paper's Assumption 4.14 with the same q = sqrt(1−r).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _select_block(tot, k: int):
    """Exact-k selection inside one VMEM tile: (vals, idx) in descending
    |value| order plus the membership mask, matching ``lax.top_k`` ties."""
    _, idx = lax.top_k(jnp.abs(tot), k)
    vals = jnp.take(tot, idx)
    # membership mask via a (k, block) comparison table — stays on the VPU
    # (no in-kernel scatter); 2D iota for TPU compatibility
    pos = lax.broadcasted_iota(jnp.int32, (1, tot.shape[0]), 1)
    keep = jnp.any(idx[:, None] == pos, axis=0)
    return vals, idx, keep


def _topk_ef_kernel(x_ref, e_ref, hat_ref, err_ref, *, k: int):
    tot = x_ref[...] + e_ref[...]
    _, _, keep = _select_block(tot, k)
    hat = jnp.where(keep, tot, 0.0)
    hat_ref[...] = hat
    err_ref[...] = tot - hat


def _topk_ef_sparse_kernel(x_ref, e_ref, vals_ref, idx_ref, err_ref, *,
                           k: int, block: int):
    tot = x_ref[...] + e_ref[...]
    vals, idx, keep = _select_block(tot, k)
    vals_ref[...] = vals[None, :]
    idx_ref[...] = (idx + pl.program_id(0) * block)[None, :]  # global flat
    err_ref[...] = jnp.where(keep, 0.0, tot)


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def topk_ef(x, err, *, k: int, block: int = DEFAULT_BLOCK,
            interpret: bool = True):
    """x, err: (N,) fp32 with N % block == 0. Returns (hat, new_err)."""
    assert x.ndim == 1 and x.shape == err.shape
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    out_shape = (jax.ShapeDtypeStruct(x.shape, x.dtype),
                 jax.ShapeDtypeStruct(x.shape, x.dtype))
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_topk_ef_kernel, k=k),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, err)


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def topk_ef_sparse(x, err, *, k: int, block: int = DEFAULT_BLOCK,
                   interpret: bool = True):
    """x, err: (N,) fp32 with N % block == 0. One HBM pass per tile
    emitting the compacted selection directly:

    Returns ``(vals, idx, new_err)`` with ``vals``/``idx`` shaped
    (N // block, k) — per-block kept values and their GLOBAL flat
    positions, ``lax.top_k`` order — and ``new_err`` (N,) the fused EF
    residual (``x + err`` with the selected entries zeroed). The dense
    equivalent ``zeros(N).at[idx].set(vals)`` equals :func:`topk_ef`'s hat
    bit-for-bit (tests/test_kernels.py)."""
    assert x.ndim == 1 and x.shape == err.shape
    n = x.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    spec = pl.BlockSpec((block,), lambda i: (i,))
    sel_spec = pl.BlockSpec((1, k), lambda i: (i, 0))
    out_shape = (jax.ShapeDtypeStruct((nb, k), x.dtype),
                 jax.ShapeDtypeStruct((nb, k), jnp.int32),
                 jax.ShapeDtypeStruct(x.shape, x.dtype))
    return pl.pallas_call(
        functools.partial(_topk_ef_sparse_kernel, k=k, block=block),
        grid=(nb,),
        in_specs=[spec, spec],
        out_specs=[sel_spec, sel_spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, err)
