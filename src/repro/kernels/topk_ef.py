"""Blockwise top-k + fused error feedback as a Pallas TPU kernel.

TPU adaptation of the paper's top-k compressor (DESIGN.md §3): a global
top-k over 10^8-10^9 gradient elements requires a full sort through HBM; the
blockwise variant streams fixed-size tiles HBM→VMEM, selects the top-k'
inside the tile (one pass + an in-register top_k), and writes both the
compressed tile and the residual error in the same pass — the error-feedback
update is fused, so the delta is read exactly once.

The per-block contraction ‖C(x_b)−x_b‖² ≤ (1−k'/B)‖x_b‖² preserves the
paper's Assumption 4.14 with the same q = sqrt(1−r).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _topk_ef_kernel(x_ref, e_ref, hat_ref, err_ref, *, k: int):
    tot = x_ref[...] + e_ref[...]
    absx = jnp.abs(tot)
    # k-th largest |value| in this VMEM tile -> keep threshold
    kth = lax.top_k(absx, k)[0][-1]
    keep = absx >= kth
    hat = jnp.where(keep, tot, 0.0)
    hat_ref[...] = hat
    err_ref[...] = tot - hat


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def topk_ef(x, err, *, k: int, block: int = DEFAULT_BLOCK,
            interpret: bool = True):
    """x, err: (N,) fp32 with N % block == 0. Returns (hat, new_err)."""
    assert x.ndim == 1 and x.shape == err.shape
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    out_shape = (jax.ShapeDtypeStruct(x.shape, x.dtype),
                 jax.ShapeDtypeStruct(x.shape, x.dtype))
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_topk_ef_kernel, k=k),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, err)
