"""1-bit pack/unpack as Pallas TPU kernels (the sign codec's hot loop).

The sign wire format (comm.wire) carries 1 bit per coordinate; packing
8 sign bits into each uint8 is a pure byte-shuffle that on TPU should
stream HBM→VMEM once per tile instead of materializing an 8× larger bit
tensor. Each grid step packs a ``block``-bit tile: reshape to (block/8, 8),
weight by MSB-first powers of two (matching ``jnp.packbits``'s big-endian
bit order, which the wire format uses), and reduce. ``unpack_bits`` is the
inverse (shift + mask against the same weights).

``pack_bits_ref`` / ``unpack_bits_ref`` are the jnp oracles the kernels are
validated against in tests/test_wire.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048  # bits per grid step (must be a multiple of 8)

_WEIGHTS = (128, 64, 32, 16, 8, 4, 2, 1)  # MSB-first, like jnp.packbits


def pack_bits_ref(bits):
    """bits: (N,) uint8/bool in {0,1}, N % 8 == 0. Returns (N/8,) uint8."""
    b = bits.reshape(-1, 8).astype(jnp.int32)
    w = jnp.asarray(_WEIGHTS, jnp.int32)
    return jnp.sum(b * w, axis=1).astype(jnp.uint8)


def unpack_bits_ref(packed):
    """packed: (M,) uint8. Returns (8*M,) uint8 in {0,1}."""
    p = packed.astype(jnp.int32)
    shifts = jnp.arange(7, -1, -1, dtype=jnp.int32)
    return ((p[:, None] >> shifts) & 1).reshape(-1).astype(jnp.uint8)


def _msb_first_shifts(rows: int):
    # 7..0 per byte lane, built with an in-kernel iota (pallas kernels may
    # not capture host constants)
    return 7 - jax.lax.broadcasted_iota(jnp.int32, (rows, 8), 1)


def _pack_kernel(b_ref, out_ref):
    b = b_ref[...].reshape(-1, 8).astype(jnp.int32)
    out_ref[...] = jnp.sum(b << _msb_first_shifts(b.shape[0]),
                           axis=1).astype(jnp.uint8)


def _unpack_kernel(p_ref, out_ref):
    p = p_ref[...].astype(jnp.int32)
    shifts = _msb_first_shifts(p.shape[0])
    out_ref[...] = ((p[:, None] >> shifts) & 1).reshape(-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pack_bits(bits, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    """bits: (N,) uint8 in {0,1} with N % block == 0, block % 8 == 0.
    Returns (N/8,) uint8, identical to ``pack_bits_ref``."""
    assert bits.ndim == 1 and block % 8 == 0
    n = bits.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block // 8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // 8,), jnp.uint8),
        interpret=interpret,
    )(bits.astype(jnp.uint8))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def unpack_bits(packed, *, block: int = DEFAULT_BLOCK,
                interpret: bool = True):
    """packed: (M,) uint8 with 8*M % block == 0. Returns (8*M,) uint8."""
    assert packed.ndim == 1 and block % 8 == 0
    m = packed.shape[0]
    assert (8 * m) % block == 0, (m, block)
    grid = (8 * m // block,)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block // 8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((8 * m,), jnp.uint8),
        interpret=interpret,
    )(packed)
