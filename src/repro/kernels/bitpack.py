"""n-bit pack/unpack as Pallas TPU kernels (the wire formats' hot loop).

The wire formats (comm.wire) carry sub-word payloads: 1 bit per coordinate
for the sign codec, ``ceil(log2(B))`` bits per kept index for blocktopk
(11 bits for B=2048). Packing those into a uint8 stream is a pure
byte-shuffle that on TPU should stream HBM→VMEM once per tile — the naive
formulation (expand every value to an ``(count, nbits)`` bit matrix, then
``packbits``) materializes an 8–32× larger intermediate, which is exactly
the memory traffic the wire format exists to avoid.

Both directions here are *word-wise shift/or accumulations* with no bit
matrix. MSB-first at ``nbits`` each, value slot ``s`` of the stream spans
stream bits ``[s·nbits, (s+1)·nbits)`` and byte ``k`` spans ``[8k, 8k+8)``;
every overlapping (k, s) pair contributes one contiguous bit run whose
alignment is the *constant* shift ``8k + 8 − (s+1)·nbits``, so

    byte_k = OR_s  shift(value_s, 8k + 8 − (s+1)·nbits)  & 0xFF
    value_s = OR_k shift(byte_k, (s+1)·nbits − 8k − 8)   & (2^nbits − 1)

With ``L = lcm(nbits, 8)`` the stream tiles into groups of ``L/nbits``
values ↔ ``L/8`` bytes, making the (k, s) pairs a small static table the
kernels unroll (≤ ``L/8 · (⌈8/nbits⌉+1)`` shift/or ops per group).

``pack_uint_words`` / ``unpack_uint_words`` are the pure-jnp form of the
same algorithm — the oracle the kernels are validated against in
tests/test_wire.py, and the default path ``comm.wire`` uses under jit.
``pack_bits_ref`` / ``unpack_bits_ref`` are the original 1-bit oracles.

``interpret=None`` (default) selects the backend automatically: compiled
Pallas on TPU, interpreter everywhere else — so the "pallas" wire paths
run the real kernels exactly where Pallas can compile them.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048  # bits per grid step for the 1-bit API (multiple of 8)

_WEIGHTS = (128, 64, 32, 16, 8, 4, 2, 1)  # MSB-first, like jnp.packbits


def _resolve_interpret(interpret):
    """Backend-aware default: compile on TPU, interpret elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def group_shape(nbits: int):
    """(values, bytes) per stream group: L/nbits and L/8 for L=lcm(nbits,8)."""
    if not 1 <= nbits <= 32:
        raise ValueError(f"nbits must be in [1, 32], got {nbits}")
    lcm = math.lcm(nbits, 8)
    return lcm // nbits, lcm // 8


def _umask(nbits: int):
    return jnp.uint32(((1 << nbits) - 1) & 0xFFFFFFFF)


def _pack_pairs(nbits: int):
    """Static (byte k) -> [(value slot s, shift)] table for one group."""
    gv, gb = group_shape(nbits)
    return [
        [(s, 8 * k + 8 - (s + 1) * nbits)
         for s in range((8 * k) // nbits,
                        min((8 * k + 7) // nbits, gv - 1) + 1)]
        for k in range(gb)
    ]


def _unpack_pairs(nbits: int):
    """Static (value slot s) -> [(byte k, shift)] table (pack's transpose)."""
    gv, gb = group_shape(nbits)
    return [
        [(k, 8 * k + 8 - (s + 1) * nbits)
         for k in range((s * nbits) // 8,
                        min(((s + 1) * nbits - 1) // 8, gb - 1) + 1)]
        for s in range(gv)
    ]


def _shl(x, sh: int):
    """Shift by a signed static amount (left for positive)."""
    return x << sh if sh >= 0 else x >> -sh


# ---------------------------------------------------------------------------
# jnp word-wise forms (oracle + default wire path)
# ---------------------------------------------------------------------------


def pack_uint_words(vals, nbits: int) -> jnp.ndarray:
    """vals: (count,) uints < 2**nbits. Returns ceil(count*nbits/8) bytes,
    MSB-first — byte-identical to the bit-matrix formulation but without
    ever materializing it (peak intermediate is one uint32 per output byte).
    """
    flat = vals.reshape(-1).astype(jnp.uint32) & _umask(nbits)
    count = flat.size
    gv, gb = group_shape(nbits)
    groups = -(-count // gv)
    v = jnp.pad(flat, (0, groups * gv - count)).reshape(groups, gv)
    cols = []
    for pairs in _pack_pairs(nbits):
        acc = jnp.zeros((groups,), jnp.uint32)
        for s, sh in pairs:
            acc = acc | _shl(v[:, s], sh)
        cols.append(acc & 0xFF)
    out = jnp.stack(cols, axis=1).reshape(-1).astype(jnp.uint8)
    return out[: (count * nbits + 7) // 8]


def unpack_uint_words(buf, nbits: int, count: int) -> jnp.ndarray:
    """Inverse of :func:`pack_uint_words`: read ``count`` values (uint32)."""
    flat = buf.reshape(-1).astype(jnp.uint32)
    gv, gb = group_shape(nbits)
    groups = -(-count // gv)
    b = jnp.pad(flat, (0, max(groups * gb - flat.size, 0))).reshape(-1)
    b = b[: groups * gb].reshape(groups, gb)
    mask = _umask(nbits)
    cols = []
    for pairs in _unpack_pairs(nbits):
        acc = jnp.zeros((groups,), jnp.uint32)
        for k, sh in pairs:
            acc = acc | _shl(b[:, k], -sh)
        cols.append(acc & mask)
    return jnp.stack(cols, axis=1).reshape(-1)[:count]


# 1-bit oracles (kept as an independent reference for the kernels)


def pack_bits_ref(bits):
    """bits: (N,) uint8/bool in {0,1}, N % 8 == 0. Returns (N/8,) uint8."""
    b = bits.reshape(-1, 8).astype(jnp.int32)
    w = jnp.asarray(_WEIGHTS, jnp.int32)
    return jnp.sum(b * w, axis=1).astype(jnp.uint8)


def unpack_bits_ref(packed):
    """packed: (M,) uint8. Returns (8*M,) uint8 in {0,1}."""
    p = packed.astype(jnp.int32)
    shifts = jnp.arange(7, -1, -1, dtype=jnp.int32)
    return ((p[:, None] >> shifts) & 1).reshape(-1).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def _pack_uint_kernel(v_ref, out_ref, *, nbits: int, rows: int):
    gv, gb = group_shape(nbits)
    v = v_ref[...].reshape(rows, gv).astype(jnp.uint32) & _umask(nbits)
    cols = []
    for pairs in _pack_pairs(nbits):
        acc = jnp.zeros((rows,), jnp.uint32)
        for s, sh in pairs:
            acc = acc | _shl(v[:, s], sh)
        cols.append(acc & 0xFF)
    out_ref[...] = jnp.stack(cols, axis=1).reshape(-1).astype(jnp.uint8)


def _unpack_uint_kernel(p_ref, out_ref, *, nbits: int, rows: int):
    gv, gb = group_shape(nbits)
    b = p_ref[...].reshape(rows, gb).astype(jnp.uint32)
    mask = _umask(nbits)
    cols = []
    for pairs in _unpack_pairs(nbits):
        acc = jnp.zeros((rows,), jnp.uint32)
        for k, sh in pairs:
            acc = acc | _shl(b[:, k], -sh)
        cols.append(acc & mask)
    out_ref[...] = jnp.stack(cols, axis=1).reshape(-1)


@functools.partial(jax.jit,
                   static_argnames=("nbits", "group_block", "interpret"))
def pack_uint(vals, nbits: int, *, group_block: int = 256,
              interpret=None) -> jnp.ndarray:
    """Pallas form of :func:`pack_uint_words`: vals (count,) uints
    < 2**nbits -> ceil(count*nbits/8) bytes. Pads internally to whole grid
    steps of ``group_block`` stream groups; byte-identical to the jnp path.
    """
    flat = vals.reshape(-1).astype(jnp.uint32)
    count = flat.size
    gv, gb = group_shape(nbits)
    groups = -(-count // gv)
    gpad = -(-groups // group_block) * group_block
    v = jnp.pad(flat, (0, gpad * gv - count))
    out = pl.pallas_call(
        functools.partial(_pack_uint_kernel, nbits=nbits, rows=group_block),
        grid=(gpad // group_block,),
        in_specs=[pl.BlockSpec((group_block * gv,), lambda i: (i,))],
        out_specs=pl.BlockSpec((group_block * gb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((gpad * gb,), jnp.uint8),
        interpret=_resolve_interpret(interpret),
    )(v)
    return out[: (count * nbits + 7) // 8]


@functools.partial(jax.jit,
                   static_argnames=("nbits", "count", "group_block",
                                    "interpret"))
def unpack_uint(buf, nbits: int, count: int, *, group_block: int = 256,
                interpret=None) -> jnp.ndarray:
    """Pallas inverse of :func:`pack_uint`: read ``count`` uint32 values."""
    flat = buf.reshape(-1)
    gv, gb = group_shape(nbits)
    groups = -(-count // gv)
    gpad = -(-groups // group_block) * group_block
    b = jnp.pad(flat, (0, max(gpad * gb - flat.size, 0)))[: gpad * gb]
    out = pl.pallas_call(
        functools.partial(_unpack_uint_kernel, nbits=nbits, rows=group_block),
        grid=(gpad // group_block,),
        in_specs=[pl.BlockSpec((group_block * gb,), lambda i: (i,))],
        out_specs=pl.BlockSpec((group_block * gv,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((gpad * gv,), jnp.uint32),
        interpret=_resolve_interpret(interpret),
    )(b)
    return out[:count]


# -- 1-bit API (the sign codec's path; nbits=1 specialization) --------------


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pack_bits(bits, *, block: int = DEFAULT_BLOCK, interpret=None):
    """bits: (N,) uint8 in {0,1} with N % block == 0, block % 8 == 0.
    Returns (N/8,) uint8, identical to ``pack_bits_ref``."""
    assert bits.ndim == 1 and block % 8 == 0
    n = bits.shape[0]
    assert n % block == 0, (n, block)
    return pack_uint(bits, 1, group_block=block // 8, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def unpack_bits(packed, *, block: int = DEFAULT_BLOCK, interpret=None):
    """packed: (M,) uint8 with 8*M % block == 0. Returns (8*M,) uint8."""
    assert packed.ndim == 1 and block % 8 == 0
    m = packed.shape[0]
    assert (8 * m) % block == 0, (m, block)
    return unpack_uint(packed, 1, 8 * m, group_block=block // 8,
                       interpret=interpret).astype(jnp.uint8)
