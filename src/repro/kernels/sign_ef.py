"""Scaled-sign + fused error feedback as a Pallas TPU kernel.

Two-pass structure (the scale  ‖x+e‖₁/d  is a global reduction):
  pass 1: blockwise |·| partial sums (kernel below, accumulated in fp32);
  pass 2: elementwise  hat = scale·sign(x+e),  err = (x+e) − hat,
          with the scalar scale broadcast to every tile.

On TPU the sign bits would additionally be packed 8→1 into int8 lanes for
the wire (see core.stages.packed_sign_leaf for the collective side); the
kernel emits the dense hat used by the local error-feedback update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _l1_partial_kernel(x_ref, e_ref, out_ref):
    out_ref[...] = jnp.sum(jnp.abs(x_ref[...] + e_ref[...]))[None]


def _sign_ef_kernel(scale_ref, x_ref, e_ref, hat_ref, err_ref):
    tot = x_ref[...] + e_ref[...]
    scale = scale_ref[0]
    # sign(0) := +1, matching make_sign and the 1-bit wire format (a 1-bit
    # lane cannot carry a third "zero" state)
    hat = scale * jnp.where(tot >= 0, 1.0, -1.0)
    hat_ref[...] = hat
    err_ref[...] = tot - hat


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sign_ef(x, err, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    """x, err: (N,) fp32 with N % block == 0. Returns (hat, new_err)."""
    assert x.ndim == 1 and x.shape == err.shape
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))

    partials = pl.pallas_call(
        _l1_partial_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), x.dtype),
        interpret=interpret,
    )(x, err)
    scale = (jnp.sum(partials) / n).reshape(1)

    out_shape = (jax.ShapeDtypeStruct(x.shape, x.dtype),
                 jax.ShapeDtypeStruct(x.shape, x.dtype))
    return pl.pallas_call(
        _sign_ef_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(scale, x, err)
