"""Fused FedAMS server update as a Pallas TPU kernel.

One HBM pass over five operand streams (x, m, v, v̂, Δ̂) producing four
outputs — the unfused jnp version reads/writes each array separately (9+
passes). The update is purely elementwise so it tiles trivially: 1-D blocks
sized to keep 9 fp32 streams resident in VMEM.

Implements both paper options:
  option 1:  v̂ = max(v̂, v, ε);  x += η·m/√v̂
  option 2:  v̂ = max(v̂, v);     x += η·m/(√v̂+ε)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _fedams_kernel(x_ref, m_ref, v_ref, vh_ref, d_ref,
                   x_out, m_out, v_out, vh_out, *,
                   eta: float, beta1: float, beta2: float, eps: float,
                   option: int):
    d = d_ref[...]
    m2 = beta1 * m_ref[...] + (1.0 - beta1) * d
    v2 = beta2 * v_ref[...] + (1.0 - beta2) * d * d
    if option == 1:
        vh2 = jnp.maximum(jnp.maximum(vh_ref[...], v2), eps)
        x2 = x_ref[...] + eta * m2 * jax.lax.rsqrt(vh2)
    else:
        vh2 = jnp.maximum(vh_ref[...], v2)
        x2 = x_ref[...] + eta * m2 / (jnp.sqrt(vh2) + eps)
    x_out[...] = x2
    m_out[...] = m2
    v_out[...] = v2
    vh_out[...] = vh2


@functools.partial(jax.jit, static_argnames=("eta", "beta1", "beta2", "eps",
                                             "option", "block", "interpret"))
def fedams_update(x, m, v, vhat, delta, *, eta: float, beta1: float,
                  beta2: float, eps: float, option: int = 1,
                  block: int = DEFAULT_BLOCK, interpret: bool = True):
    """All inputs (N,) fp32, N % block == 0. Returns (x, m, v, vhat)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = tuple(jax.ShapeDtypeStruct(x.shape, x.dtype) for _ in range(4))
    return pl.pallas_call(
        functools.partial(_fedams_kernel, eta=eta, beta1=beta1, beta2=beta2,
                          eps=eps, option=option),
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(x, m, v, vhat, delta)
