"""Fused FedAMS server update as a Pallas TPU kernel.

One HBM pass over five operand streams (x, m, v, v̂, Δ̂) producing four
outputs — the unfused jnp version reads/writes each array separately (9+
passes). The update is purely elementwise so it tiles trivially: 1-D blocks
sized to keep 9 fp32 streams resident in VMEM.

Implements both paper options with the jnp ``server_update``'s exact op
sequence (``eta·m/√v̂`` is a true division, not a rsqrt multiply, and the
v update is ``(1-β₂)·square(δ)`` — the left-associated form is 1 ulp
off): m/v/v̂ are bit-identical to ``server_update`` everywhere, and x is
bit-identical when both programs compile at the same shape; across
differently-shaped programs XLA may contract the x division into an
FMA/rsqrt form, a few ulp of each increment (regression-tested both ways
in tests/test_server_opt.py):
  option 1:  v̂ = max(v̂, v, ε);  x += η·m/√v̂
  option 2:  v̂ = max(v̂, v);     x += η·m/(√v̂+ε)

Ragged sizes are handled by zero-padding the operands to a block multiple
and slicing the outputs back: pad lanes carry d=0 so every output pad lane
is a constant (m2=0, v2=0, x2=0) that the slice discards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _fedams_kernel(x_ref, m_ref, v_ref, vh_ref, d_ref,
                   x_out, m_out, v_out, vh_out, *,
                   eta: float, beta1: float, beta2: float, eps: float,
                   option: int):
    d = d_ref[...]
    m2 = beta1 * m_ref[...] + (1.0 - beta1) * d
    v2 = beta2 * v_ref[...] + (1.0 - beta2) * jnp.square(d)
    if option == 1:
        vh2 = jnp.maximum(jnp.maximum(vh_ref[...], v2), eps)
        x2 = x_ref[...] + eta * m2 / jnp.sqrt(vh2)
    else:
        vh2 = jnp.maximum(vh_ref[...], v2)
        x2 = x_ref[...] + eta * m2 / (jnp.sqrt(vh2) + eps)
    x_out[...] = x2
    m_out[...] = m2
    v_out[...] = v2
    vh_out[...] = vh2


@functools.partial(jax.jit, static_argnames=("eta", "beta1", "beta2", "eps",
                                             "option", "block", "interpret"))
def fedams_update(x, m, v, vhat, delta, *, eta: float, beta1: float,
                  beta2: float, eps: float, option: int = 1,
                  block: int = DEFAULT_BLOCK, interpret: bool = True):
    """All inputs (N,) fp32, any N. Returns (x, m, v, vhat)."""
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x, m, v, vhat, delta = (jnp.pad(a, (0, pad))
                                for a in (x, m, v, vhat, delta))
    np_ = n + pad
    grid = (np_ // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = tuple(jax.ShapeDtypeStruct((np_,), jnp.float32)
                      for _ in range(4))
    outs = pl.pallas_call(
        functools.partial(_fedams_kernel, eta=eta, beta1=beta1, beta2=beta2,
                          eps=eps, option=option),
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(x, m, v, vhat, delta)
    if pad:
        outs = tuple(o[:n] for o in outs)
    return outs
