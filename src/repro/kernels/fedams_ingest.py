"""One-pass fused server ingest: sparse scatter-mean + FedAMS update.

The two-pass server round first materializes the dense mean delta
(``server_aggregate_sparse``: one scatter pass writing d words) and then
re-reads it alongside x/m/v/v̂ (``fedams_update``: 5 reads + 4 writes) —
~13 fp32 streams over d per round. This kernel consumes the gathered
``(vals, idx)`` selections from n clients directly: each grid step owns one
selection block of the optimizer state, rebuilds that block's mean delta in
VMEM from the O(n·k) compacted entries, and applies the full FedAMS
m/v/v̂/x update in the same read-modify-write — the dense mean delta never
touches HBM, so the round moves ~9 streams plus the O(n·k) selection
traffic.

Second-moment storage is configurable (``state_dtype``): v/v̂ live in HBM
as fp32, bf16, or int8 with one fp32 absmax scale per selection block;
dequant → fp32 update math → requant is fused into the same pass (bf16
halves, int8 quarters, the v/v̂ residency — the update math itself always
runs in fp32, so the quantization error enters only through the *stored*
state read back next round).

Layout contract (matches ``Compressor.select`` / ``topk_ef_sparse``):
``idx`` are global int32 positions in the zero-padded block domain
(N = nb·block); ``vals``/``idx`` are (n, nb, k) — client-major, one row of
k entries per selection block.

Numerics contract (tests/test_fused_ingest.py): the jnp blocked-scatter
impl (``server_ingest_leaf(impl="jnp")``) is *bitwise identical* to the
two-pass ``server_aggregate_sparse`` + ``server_update`` baseline at every
state dtype — XLA lowers both to one scatter-add over the same update
sequence. This kernel accumulates collisions per client inside a
``fori_loop``, which XLA's single scatter may reassociate, so the kernel
(and its oracle ``fedams_ingest_ref``, bitwise equal to the kernel) sits
within ≤1 ulp of the baseline on collided coordinates and is bitwise
everywhere else.

Implements both paper options (division, not rsqrt — see fedams_update):
  option 1:  v̂ = max(v̂, v, ε);  x += η·m/√v̂
  option 2:  v̂ = max(v̂, v);     x += η·m/(√v̂+ε)

NB compiled-TPU int8 tiling wants block % 4096 == 0 for the (block,) int8
refs; the container runs the interpreter, where any 128-multiple works.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

#: Supported second-moment storage dtypes (mirrors
#: ``configs.base.FED_SERVER_STATE_DTYPES``).
STATE_DTYPES = ("float32", "bfloat16", "int8")


def _ingest_kernel(*refs, n: int, k: int, block: int, n_div, eta: float,
                   beta1: float, beta2: float, eps: float, option: int,
                   state_dtype: str):
    if state_dtype == "int8":
        (x_ref, m_ref, v_ref, vh_ref, vals_ref, idx_ref, vs_ref, vhs_ref,
         x_out, m_out, v_out, vh_out, vs_out, vhs_out) = refs
    else:
        (x_ref, m_ref, v_ref, vh_ref, vals_ref, idx_ref,
         x_out, m_out, v_out, vh_out) = refs
    i = pl.program_id(0)

    # -- scatter-mean of this block's selected entries, entirely in VMEM.
    # One (k, block) compare table per client keeps the working set bounded
    # (an (n·k, block) table would blow VMEM at production n); the fori_loop
    # adds clients in order, so collision accumulation bit-matches the jnp
    # scatter-add's client-major update sequence. Within one client the k
    # selected positions are distinct, so the k-sum adds exact zeros plus at
    # most one value — no reassociation.
    vals = vals_ref[...].reshape(n, k)
    idxl = idx_ref[...].reshape(n, k) - i * block
    pos = lax.broadcasted_iota(jnp.int32, (1, block), 1)

    def add_client(j, acc):
        vj = lax.dynamic_index_in_dim(vals, j, keepdims=False)   # (k,)
        ij = lax.dynamic_index_in_dim(idxl, j, keepdims=False)   # (k,)
        hit = ij[:, None] == pos                                 # (k, block)
        return acc + jnp.sum(jnp.where(hit, vj[:, None], 0.0), axis=0)

    acc = lax.fori_loop(0, n, add_client, jnp.zeros((block,), jnp.float32))
    d = acc / n_div

    # -- dequant stored second moments to fp32 for the update math
    if state_dtype == "int8":
        vv = v_ref[...].astype(jnp.float32) * vs_ref[0, 0]
        vh = vh_ref[...].astype(jnp.float32) * vhs_ref[0, 0]
    else:
        vv = v_ref[...].astype(jnp.float32)
        vh = vh_ref[...].astype(jnp.float32)

    m2 = beta1 * m_ref[...] + (1.0 - beta1) * d
    v2 = beta2 * vv + (1.0 - beta2) * jnp.square(d)
    if option == 1:
        vh2 = jnp.maximum(jnp.maximum(vh, v2), eps)
        x2 = x_ref[...] + eta * m2 / jnp.sqrt(vh2)
    else:
        vh2 = jnp.maximum(vh, v2)
        x2 = x_ref[...] + eta * m2 / (jnp.sqrt(vh2) + eps)
    x_out[...] = x2
    m_out[...] = m2

    # -- requant the refreshed second moments into storage form
    if state_dtype == "int8":
        vs2 = jnp.maximum(jnp.max(jnp.abs(v2)) / 127.0, 1e-30)
        vhs2 = jnp.maximum(jnp.max(jnp.abs(vh2)) / 127.0, 1e-30)
        v_out[...] = jnp.clip(jnp.round(v2 / vs2), -127, 127).astype(jnp.int8)
        vh_out[...] = jnp.clip(jnp.round(vh2 / vhs2), -127,
                               127).astype(jnp.int8)
        vs_out[0, 0] = vs2
        vhs_out[0, 0] = vhs2
    elif state_dtype == "bfloat16":
        v_out[...] = v2.astype(jnp.bfloat16)
        vh_out[...] = vh2.astype(jnp.bfloat16)
    else:
        v_out[...] = v2
        vh_out[...] = vh2


@functools.partial(jax.jit, static_argnames=("n_div", "eta", "beta1", "beta2",
                                             "eps", "option", "block",
                                             "state_dtype", "interpret"))
def fedams_ingest(x, m, v, vhat, vals, idx, v_scale=None, vh_scale=None, *,
                  n_div, eta: float, beta1: float, beta2: float, eps: float,
                  option: int = 1, block: int = 2048,
                  state_dtype: str = "float32", interpret: bool = True):
    """Fused scatter-mean + FedAMS step over the padded block domain.

    ``x``/``m``: (N,) fp32 with N = nb·block; ``v``/``vhat``: (N,) in the
    storage dtype (int8 additionally takes ``v_scale``/``vh_scale``: (nb,)
    fp32 per-block scales); ``vals``/``idx``: (n, nb, k) fp32/int32 global
    selections. ``n_div`` is the (static) mean divisor — the participating
    client count. Returns ``(x, m, v, vhat)`` with state in storage form,
    plus ``(v_scale, vh_scale)`` when ``state_dtype == 'int8'``.
    """
    assert state_dtype in STATE_DTYPES, state_dtype
    n_clients, nb, k = vals.shape
    N = x.shape[0]
    assert N == nb * block, (N, nb, block)
    grid = (nb,)
    svec = pl.BlockSpec((block,), lambda i: (i,))
    ssel = pl.BlockSpec((n_clients, 1, k), lambda i: (0, i, 0))
    sscale = pl.BlockSpec((1, 1), lambda i: (i, 0))
    sdt = jnp.dtype(state_dtype)
    ins = [x, m, v, vhat, vals, idx]
    in_specs = [svec, svec, svec, svec, ssel, ssel]
    out_shape = [jax.ShapeDtypeStruct((N,), jnp.float32),
                 jax.ShapeDtypeStruct((N,), jnp.float32),
                 jax.ShapeDtypeStruct((N,), sdt),
                 jax.ShapeDtypeStruct((N,), sdt)]
    out_specs = [svec, svec, svec, svec]
    if state_dtype == "int8":
        ins += [v_scale.reshape(nb, 1), vh_scale.reshape(nb, 1)]
        in_specs += [sscale, sscale]
        out_shape += [jax.ShapeDtypeStruct((nb, 1), jnp.float32)] * 2
        out_specs += [sscale, sscale]
    return pl.pallas_call(
        functools.partial(_ingest_kernel, n=n_clients, k=k, block=block,
                          n_div=n_div, eta=eta, beta1=beta1, beta2=beta2,
                          eps=eps, option=option, state_dtype=state_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(*ins)
