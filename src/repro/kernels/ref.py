"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

Semantics notes:
  * ``topk_ef_ref`` is *threshold* top-k within each block: every element with
    |x| >= (k-th largest |x| in its block) is kept. With ties this keeps more
    than k elements — both kernel and oracle implement the same rule, and the
    contraction bound q = sqrt(1-k/B) only improves when extra elements are
    kept. (The exact-k scatter variant lives in core.compressors for the
    paper-faithful simulation.)
  * ``sign_ef_ref``: scaled sign with the *global* l1 scale (computed outside
    the kernel in one reduction pass) and fused error feedback.
  * ``fedams_update_ref``: the fused server update, Options 1 and 2.
  * ``fedams_ingest_ref``: the one-pass sparse ingest (scatter-mean fused
    into the FedAMS step, with dequant/requant of quantized second-moment
    state) — the bit-identity ground truth for ``kernels.fedams_ingest``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def topk_ef_ref(x, err, k: int, block: int):
    """x, err: (N,) with N % block == 0. Returns (hat, new_err)."""
    tot = (x + err).reshape(-1, block)
    absx = jnp.abs(tot)
    kth = lax.top_k(absx, k)[0][:, -1]
    keep = absx >= kth[:, None]
    hat = jnp.where(keep, tot, 0.0)
    new_err = tot - hat
    return hat.reshape(-1), new_err.reshape(-1)


def sign_ef_ref(x, err):
    """x, err: (N,). Returns (hat, new_err). Scale = mean |x+err| (global);
    sign(0) := +1 (the convention the 1-bit wire format carries)."""
    tot = x + err
    scale = jnp.mean(jnp.abs(tot))
    hat = scale * jnp.where(tot >= 0, 1.0, -1.0)
    return hat, tot - hat


def fedams_update_ref(x, m, v, vhat, delta, *, eta: float, beta1: float,
                      beta2: float, eps: float, option: int = 1):
    """Fused FedAMS server update on flat fp32 vectors."""
    m2 = beta1 * m + (1 - beta1) * delta
    v2 = beta2 * v + (1 - beta2) * jnp.square(delta)
    if option == 1:
        vh2 = jnp.maximum(jnp.maximum(vhat, v2), eps)
        x2 = x + eta * m2 / jnp.sqrt(vh2)
    else:
        vh2 = jnp.maximum(vhat, v2)
        x2 = x + eta * m2 / (jnp.sqrt(vh2) + eps)
    return x2, m2, v2, vh2


def fedams_ingest_ref(x, m, v, vhat, vals, idx, v_scale=None, vh_scale=None,
                      *, n_div, eta: float, beta1: float, beta2: float,
                      eps: float, option: int = 1, block: int = 2048,
                      state_dtype: str = "float32"):
    """One-pass sparse ingest oracle, same contract as ``fedams_ingest``.

    The scatter accumulates client-major — a per-client loop of
    unique-index scatter-adds, the same accumulation order as the kernel's
    client fori_loop (kernel ≡ ref bitwise). XLA's single flat scatter-add
    may reassociate collided updates, so vs the two-pass baseline this
    oracle is within ≤1 ulp on collided coordinates. The elementwise
    FedAMS step runs in fp32 with dequant/requant of the stored second
    moments. Returns ``(x, m, v, vhat)`` (+ scales for int8).
    """
    n, nb, k = vals.shape
    N = x.shape[0]
    acc = jnp.zeros(N, jnp.float32)
    for j in range(n):   # client-major; within a client indices are unique
        acc = acc.at[idx[j].reshape(-1)].add(vals[j].reshape(-1))
    d = acc / n_div
    if state_dtype == "int8":
        vv = (v.astype(jnp.float32).reshape(nb, block)
              * v_scale[:, None]).reshape(-1)
        vh = (vhat.astype(jnp.float32).reshape(nb, block)
              * vh_scale[:, None]).reshape(-1)
    else:
        vv = v.astype(jnp.float32)
        vh = vhat.astype(jnp.float32)
    x2, m2, v2, vh2 = fedams_update_ref(x, m, vv, vh, d, eta=eta,
                                        beta1=beta1, beta2=beta2, eps=eps,
                                        option=option)
    if state_dtype == "int8":
        def requant(a):
            ab = a.reshape(nb, block)
            scale = jnp.maximum(jnp.max(jnp.abs(ab), axis=1) / 127.0, 1e-30)
            q = jnp.clip(jnp.round(ab / scale[:, None]), -127,
                         127).astype(jnp.int8)
            return q.reshape(-1), scale
        qv, sv = requant(v2)
        qvh, svh = requant(vh2)
        return x2, m2, qv, qvh, sv, svh
    if state_dtype == "bfloat16":
        return x2, m2, v2.astype(jnp.bfloat16), vh2.astype(jnp.bfloat16)
    return x2, m2, v2, vh2
