"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

Semantics notes:
  * ``topk_ef_ref`` is *threshold* top-k within each block: every element with
    |x| >= (k-th largest |x| in its block) is kept. With ties this keeps more
    than k elements — both kernel and oracle implement the same rule, and the
    contraction bound q = sqrt(1-k/B) only improves when extra elements are
    kept. (The exact-k scatter variant lives in core.compressors for the
    paper-faithful simulation.)
  * ``sign_ef_ref``: scaled sign with the *global* l1 scale (computed outside
    the kernel in one reduction pass) and fused error feedback.
  * ``fedams_update_ref``: the fused server update, Options 1 and 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def topk_ef_ref(x, err, k: int, block: int):
    """x, err: (N,) with N % block == 0. Returns (hat, new_err)."""
    tot = (x + err).reshape(-1, block)
    absx = jnp.abs(tot)
    kth = lax.top_k(absx, k)[0][:, -1]
    keep = absx >= kth[:, None]
    hat = jnp.where(keep, tot, 0.0)
    new_err = tot - hat
    return hat.reshape(-1), new_err.reshape(-1)


def sign_ef_ref(x, err):
    """x, err: (N,). Returns (hat, new_err). Scale = mean |x+err| (global);
    sign(0) := +1 (the convention the 1-bit wire format carries)."""
    tot = x + err
    scale = jnp.mean(jnp.abs(tot))
    hat = scale * jnp.where(tot >= 0, 1.0, -1.0)
    return hat, tot - hat


def fedams_update_ref(x, m, v, vhat, delta, *, eta: float, beta1: float,
                      beta2: float, eps: float, option: int = 1):
    """Fused FedAMS server update on flat fp32 vectors."""
    m2 = beta1 * m + (1 - beta1) * delta
    v2 = beta2 * v + (1 - beta2) * delta * delta
    if option == 1:
        vh2 = jnp.maximum(jnp.maximum(vhat, v2), eps)
        x2 = x + eta * m2 / jnp.sqrt(vh2)
    else:
        vh2 = jnp.maximum(vhat, v2)
        x2 = x + eta * m2 / (jnp.sqrt(vh2) + eps)
    return x2, m2, v2, vh2
