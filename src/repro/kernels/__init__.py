"""Pallas TPU kernels for the paper's compute hot-spots: the compression
path (blockwise top-k / scaled-sign, fused with error feedback) and the
fused FedAMS server update. Validated in interpret mode against ref.py."""
from repro.kernels.bitpack import (pack_bits, pack_bits_ref,  # noqa: F401
                                   pack_uint, pack_uint_words, unpack_bits,
                                   unpack_bits_ref, unpack_uint,
                                   unpack_uint_words)
from repro.kernels.fedams_update import fedams_update  # noqa: F401
from repro.kernels.ops import KernelImpl  # noqa: F401
from repro.kernels.sign_ef import sign_ef  # noqa: F401
from repro.kernels.topk_ef import topk_ef, topk_ef_sparse  # noqa: F401
