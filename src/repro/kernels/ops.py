"""jit'd pytree wrappers around the Pallas kernels.

``KernelImpl`` plugs into ``core.mesh.build_fed_round(kernel_impl=...)``:
it provides the same (hat, new_err) / server-update contracts as the jnp
path but runs the compress + update math through the fused kernels. Leaves
are flattened and zero-padded to a block multiple (zero padding is exact for
both compressors: pad elements produce hat=0 / carry err=0; the l1 scale
uses the true element count).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.compressors import Compressor, Selection
from repro.core.server_opt import ServerState
from repro.kernels.bitpack import _resolve_interpret
from repro.kernels.fedams_update import fedams_update as _fedams_update
from repro.kernels.sign_ef import sign_ef as _sign_ef
from repro.kernels.topk_ef import topk_ef as _topk_ef
from repro.kernels.topk_ef import topk_ef_sparse as _topk_ef_sparse


def _pad_flat(x, block):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n


@dataclass(frozen=True)
class KernelImpl:
    """``interpret=None`` (the default) resolves per backend exactly like
    ``kernels.bitpack``: compiled Pallas on TPU, interpreter elsewhere —
    so constructing a ``KernelImpl`` on TPU runs the real kernels without
    the caller having to know about interpret mode."""

    block: int = 2048
    interpret: Optional[bool] = None

    @property
    def _interp(self) -> bool:
        return _resolve_interpret(self.interpret)

    @property
    def compiled(self) -> bool:
        """True when the kernels run as compiled Pallas (TPU) rather than
        the interpreter — what ``mesh_sparse_impl='auto'`` keys off."""
        return not self._interp

    # -- error-feedback compression ------------------------------------
    def ef_compress_leaf(self, comp_name: str, ratio: float, x, err):
        from repro.core.compressors import block_layout
        if comp_name in ("topk", "blocktopk"):
            bs, _ = block_layout(x.size, self.block)
            flat, n = _pad_flat(x, bs)
            eflat, _ = _pad_flat(err, bs)
            k = max(1, int(round(ratio * bs)))
            hat, ne = _topk_ef(flat, eflat, k=k, block=bs,
                                 interpret=self._interp)
        elif comp_name in ("sign", "packedsign"):
            flat, n = _pad_flat(x, self.block)
            eflat, _ = _pad_flat(err, self.block)
            # scale over the padded vector differs from mean over n; rescale
            hat, ne = _sign_ef(flat, eflat, block=self.block,
                                 interpret=self._interp)
            if flat.size != n:
                hat = hat * (flat.size / n)
                ne = (flat + eflat) - hat
        else:
            raise ValueError(f"no kernel for compressor {comp_name!r}")
        hat = hat[:n].reshape(x.shape)
        ne = ne[:n].reshape(err.shape)
        return hat, ne

    def topk_select_leaf(self, ratio: float, x, err):
        """Fused EF + compacted selection for one leaf (the sparse-uplink
        kernel form): returns ``(Selection, new_err)`` where the Selection's
        ``idx`` are flat positions in the zero-padded domain (entries past
        ``x.size`` carry 0.0, matching
        :meth:`repro.core.compressors.Compressor.select`'s padded-block
        convention) and ``new_err`` has ``x``'s shape.

        This is the TPU entry point for the select-once pipeline
        (DESIGN.md §3): one HBM pass per tile emits the compacted block.
        ``mesh_uplink``'s sparse aggregation routes through it (via
        :meth:`topk_select_tree`) when ``fed.mesh_sparse_impl`` resolves
        to the kernel; the sim backend and the off-TPU mesh default use
        the jnp ``Compressor.select`` (compiled XLA beats interpret-mode
        Pallas off-TPU)."""
        from repro.core.compressors import block_layout
        bs, _ = block_layout(x.size, self.block)
        flat, n = _pad_flat(x, bs)
        eflat, _ = _pad_flat(err, bs)
        k = max(1, int(round(ratio * bs)))
        vals, idx, ne = _topk_ef_sparse(flat, eflat, k=k, block=bs,
                                        interpret=self._interp)
        sel = Selection(vals=vals.reshape(-1), idx=idx.reshape(-1))
        return sel, ne[:n].reshape(err.shape)

    def topk_select_tree(self, ratio: float, delta, err, mask):
        """Fused select-once uplink for every leaf of this device's shard
        tree — the kernel sibling of
        :func:`repro.core.stages.topk_select_tree` (identical contract):
        per leaf one ``topk_ef_sparse`` HBM pass emits the compacted
        ``(vals, idx)`` Selection AND the EF residual; no dense hat is
        materialized anywhere. Non-participating clients (``mask == 0``)
        contribute zero values and keep their error unchanged.

        Returns ``(sel_tree, err_tree)`` with
        :class:`~repro.core.compressors.Selection` leaves whose ``idx``
        are flat positions in each leaf's zero-padded block domain —
        bit-identical to ``Compressor.select`` on ``delta + err``
        (tests/test_kernels.py)."""
        from repro.core.stages import select_tree
        return select_tree(
            lambda d, e: self.topk_select_leaf(ratio, d, e),
            delta, err, mask)

    def ef_compress_tree(self, comp: Compressor, delta, err, mask):
        name = comp.name.split("_")[0]
        ratio = comp.ratio

        def leaf(d, e):
            return self.ef_compress_leaf(name, ratio, d, e)

        flat_d, tdef = jax.tree_util.tree_flatten(delta)
        flat_e = jax.tree_util.tree_leaves(err)
        hats, errs = [], []
        for d, e in zip(flat_d, flat_e):
            h, ne = leaf(d, e)
            hats.append(jnp.where(mask > 0, h, jnp.zeros_like(h)))
            errs.append(jnp.where(mask > 0, ne, e))
        return (jax.tree_util.tree_unflatten(tdef, hats),
                jax.tree_util.tree_unflatten(tdef, errs))

    # -- fused server update ---------------------------------------------
    def fedams_update_tree(self, fed: FedConfig, st: ServerState, params, agg):
        """Same update math as the jnp ``server_update`` for the whole
        {fedams, fedcams, fedamsgrad} × {option 1, 2} grid (fedamsgrad IS
        Option 2, same mapping as the jnp branches): m/v/v̂ bit-identical;
        x within a few ulp across the two differently-shaped programs
        (XLA may contract the x division into an FMA/rsqrt form —
        regression-tested in tests/test_server_opt.py, which also owns
        the same-shape bitwise gate). bf16 v/v̂ storage is dequantized by
        the fp32 pad and requantized by the output cast — the same
        round-trip the quantized ``server_update`` wrapper runs."""
        option = 2 if fed.algorithm == "fedamsgrad" else fed.option
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_m = jax.tree_util.tree_leaves(st.m)
        flat_v = jax.tree_util.tree_leaves(st.v)
        flat_vh = jax.tree_util.tree_leaves(st.vhat)
        flat_d = jax.tree_util.tree_leaves(agg)
        xs, ms, vs, vhs = [], [], [], []
        for x, m, v, vh, d in zip(flat_p, flat_m, flat_v, flat_vh, flat_d):
            xf, n = _pad_flat(x, self.block)
            mf, _ = _pad_flat(m, self.block)
            vf, _ = _pad_flat(v, self.block)
            vhf, _ = _pad_flat(vh, self.block)
            df, _ = _pad_flat(d, self.block)
            x2, m2, v2, vh2 = _fedams_update(
                xf, mf, vf, vhf, df, eta=fed.eta, beta1=fed.beta1,
                beta2=fed.beta2, eps=fed.eps, option=option,
                block=self.block, interpret=self._interp)
            xs.append(x2[:n].reshape(x.shape).astype(x.dtype))
            ms.append(m2[:n].reshape(x.shape))
            vs.append(v2[:n].reshape(x.shape).astype(v.dtype))
            vhs.append(vh2[:n].reshape(x.shape).astype(vh.dtype))
        unf = lambda ls: jax.tree_util.tree_unflatten(tdef, ls)
        return unf(xs), ServerState(m=unf(ms), v=unf(vs), vhat=unf(vhs),
                                    t=st.t + 1)

    # -- one-pass fused ingest (DESIGN.md §3) ------------------------------
    def fedams_ingest_tree(self, fed: FedConfig, st: ServerState, params,
                           sels, n_div, gather):
        """Kernel-routed one-pass server ingest: per leaf, gather the
        compacted client Selections and run ``kernels.fedams_ingest``
        (scatter-mean + FedAMS step + state dequant/requant in one pass —
        no dense mean delta). Same contract as
        :func:`repro.core.server_opt.server_ingest_tree` with
        ``impl='kernel'`` and this impl's block/interpret."""
        from repro.core.server_opt import server_ingest_tree
        return server_ingest_tree(fed, st, params, sels, n_div, gather,
                                  block=self.block, impl="kernel",
                                  interpret=self.interpret)
