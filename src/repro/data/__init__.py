from repro.data.synthetic import (  # noqa: F401
    FederatedClassification,
    FederatedLMData,
    dirichlet_label_partition,
)
