"""Synthetic federated datasets with Dirichlet non-IID client skew.

No external datasets ship in this container (DESIGN.md §7), so the paper's
CIFAR-10/100 setup is replaced by:

  * ``FederatedClassification`` — Gaussian class prototypes + noise, images
    or flat features, Dirichlet(α) label skew across clients (the standard
    FL non-IID protocol, Hsu et al. 2019). α→∞ is IID (σ_g→0 in the paper's
    Assumption 4.3), small α is highly non-IID.
  * ``FederatedLMData`` — token streams where each client draws from its own
    Zipf-reweighted unigram distribution over the vocabulary; a planted
    bigram structure gives the model something learnable.

Everything is generated deterministically from a seed on the fly: no disk,
no host copies of the full dataset.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


def dirichlet_label_partition(rng: np.random.Generator, num_classes: int,
                              num_clients: int, alpha: float) -> np.ndarray:
    """(num_clients, num_classes) label distribution per client."""
    if np.isinf(alpha):
        return np.full((num_clients, num_classes), 1.0 / num_classes)
    return rng.dirichlet([alpha] * num_classes, size=num_clients)


@dataclass
class FederatedClassification:
    num_clients: int = 100
    num_classes: int = 10
    feature_dim: int = 64          # flat features; or image=(H,W,C) below
    image_shape: Tuple[int, ...] = ()   # e.g. (32,32,3) for ConvMixer
    alpha: float = 0.3             # Dirichlet non-IID concentration
    noise: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        dim = int(np.prod(self.image_shape)) if self.image_shape else self.feature_dim
        self.prototypes = rng.normal(size=(self.num_classes, dim)).astype(np.float32)
        self.prototypes /= np.linalg.norm(self.prototypes, axis=1, keepdims=True)
        self.label_dist = dirichlet_label_partition(
            rng, self.num_classes, self.num_clients, self.alpha)

    def client_batch(self, client: int, step: int, batch_size: int) -> Dict:
        rng = np.random.default_rng(
            hash((self.seed, int(client), int(step))) % (2**63))
        y = rng.choice(self.num_classes, size=batch_size, p=self.label_dist[client])
        x = self.prototypes[y] + self.noise * rng.normal(
            size=(batch_size, self.prototypes.shape[1])).astype(np.float32)
        x = x.astype(np.float32)
        if self.image_shape:
            x = x.reshape((batch_size,) + tuple(self.image_shape))
        return {"x": x, "y": y.astype(np.int32)}

    def round_batches(self, clients, round_idx: int, local_steps: int,
                      batch_size: int) -> Dict:
        """Stacked batches for the sampled clients: leaves (n, K, B, ...)."""
        out = [[self.client_batch(c, round_idx * local_steps + k, batch_size)
                for k in range(local_steps)] for c in clients]
        return {
            "x": np.stack([[b["x"] for b in row] for row in out]),
            "y": np.stack([[b["y"] for b in row] for row in out]),
        }


@dataclass
class FederatedLMData:
    num_clients: int = 16
    vocab_size: int = 256
    alpha: float = 0.5
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        base = 1.0 / np.arange(1, self.vocab_size + 1) ** 1.1  # zipf
        skew = rng.dirichlet([self.alpha] * self.vocab_size, size=self.num_clients)
        dist = base[None, :] * (0.5 + skew * self.vocab_size * 0.5)
        self.unigram = dist / dist.sum(1, keepdims=True)
        # planted deterministic bigram: next = (tok * 31 + 7) % V with prob 0.5
        self.mult, self.add = 31, 7

    def client_batch(self, client: int, step: int, batch_size: int,
                     seq_len: int) -> Dict:
        rng = np.random.default_rng(
            hash((self.seed, int(client), int(step))) % (2**63))
        toks = np.empty((batch_size, seq_len + 1), np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=batch_size,
                                p=self.unigram[client])
        for t in range(seq_len):
            fresh = rng.choice(self.vocab_size, size=batch_size,
                               p=self.unigram[client])
            follow = (toks[:, t] * self.mult + self.add) % self.vocab_size
            coin = rng.random(batch_size) < 0.5
            toks[:, t + 1] = np.where(coin, follow, fresh)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def round_batches(self, clients, round_idx: int, local_steps: int,
                      batch_size: int, seq_len: int) -> Dict:
        rows = [[self.client_batch(c, round_idx * local_steps + k, batch_size,
                                   seq_len) for k in range(local_steps)]
                for c in clients]
        return {
            "tokens": np.stack([[b["tokens"] for b in r] for r in rows]),
            "labels": np.stack([[b["labels"] for b in r] for r in rows]),
        }

    def mesh_batch(self, round_idx: int, local_steps: int, global_batch: int,
                   seq_len: int) -> Dict:
        """Batch for the mesh path: (K, GB, S) with client c owning the
        contiguous slice c·GB/m ... (c+1)·GB/m."""
        per = global_batch // self.num_clients
        rows = [self.client_batch(c, round_idx * local_steps + k, per, seq_len)
                for k in range(local_steps) for c in range(self.num_clients)]
        toks = np.stack([b["tokens"] for b in rows]).reshape(
            local_steps, self.num_clients * per, seq_len)
        labs = np.stack([b["labels"] for b in rows]).reshape(
            local_steps, self.num_clients * per, seq_len)
        return {"tokens": toks, "labels": labs}
