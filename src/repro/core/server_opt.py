"""Server-side optimizers: the paper's FedAMS family plus all baselines.

The server treats the aggregated client delta Δ̂_t as a pseudo-gradient
(paper eq. 3.2-3.4) and performs one adaptive step. Note the *sign*: the
global update is  x ← x + η·m/√v̂  (deltas already point downhill).

    fedavg     : x += η Δ
    fedadagrad : v += Δ²                                   (Reddi et al. 2020)
    fedadam    : Adam(m, v)                                (Reddi et al. 2020)
    fedyogi    : Yogi variance update                      (Reddi et al. 2020)
    fedamsgrad : Option 2 — v̂=max(v̂,v),  x += η m/(√v̂+ε)  (Tong et al. 2020)
    fedams     : Option 1 — v̂=max(v̂,v,ε), x += η m/√v̂     (this paper)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig


class ServerState(NamedTuple):
    m: object       # momentum pytree (zeros for fedavg)
    v: object       # second moment
    vhat: object    # max-stabilized second moment
    t: jax.Array    # round counter


def init_server_state(params) -> ServerState:
    # m, v, vhat must be DISTINCT buffers: the round executable donates the
    # whole state, and XLA rejects donating one buffer for three parameters
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return ServerState(m=zeros(), v=zeros(), vhat=zeros(),
                       t=jnp.zeros((), jnp.int32))


def server_update(fed: FedConfig, state: ServerState, params, delta):
    """One server step. Returns (new_params, new_state)."""
    algo, b1, b2, eta, eps = fed.algorithm, fed.beta1, fed.beta2, fed.eta, fed.eps
    t = state.t + 1

    if algo == "fedavg":
        new_params = jax.tree.map(
            lambda x, d: x + eta * d.astype(x.dtype), params, delta)
        return new_params, ServerState(state.m, state.v, state.vhat, t)

    m = jax.tree.map(lambda mm, d: b1 * mm + (1 - b1) * d.astype(jnp.float32),
                     state.m, delta)

    if algo == "fedyogi":
        def vup(vv, d):
            d2 = jnp.square(d.astype(jnp.float32))
            return vv - (1 - b2) * d2 * jnp.sign(vv - d2)
        v = jax.tree.map(vup, state.v, delta)
    elif algo == "fedadagrad":
        v = jax.tree.map(
            lambda vv, d: vv + jnp.square(d.astype(jnp.float32)),
            state.v, delta)
    else:
        v = jax.tree.map(
            lambda vv, d: b2 * vv + (1 - b2) * jnp.square(d.astype(jnp.float32)),
            state.v, delta)

    if algo in ("fedadam", "fedyogi", "fedadagrad"):
        vhat = state.vhat  # unused
        new_params = jax.tree.map(
            lambda x, mm, vv: x + (eta * mm / (jnp.sqrt(vv) + eps)).astype(x.dtype),
            params, m, v)
    elif algo == "fedamsgrad":                       # Option 2
        vhat = jax.tree.map(jnp.maximum, state.vhat, v)
        new_params = jax.tree.map(
            lambda x, mm, vh: x + (eta * mm / (jnp.sqrt(vh) + eps)).astype(x.dtype),
            params, m, vhat)
    elif algo in ("fedams", "fedcams"):
        if fed.option == 1:                          # Option 1 (max stabilization)
            vhat = jax.tree.map(
                lambda vh, vv: jnp.maximum(jnp.maximum(vh, vv), eps),
                state.vhat, v)
            new_params = jax.tree.map(
                lambda x, mm, vh: x + (eta * mm / jnp.sqrt(vh)).astype(x.dtype),
                params, m, vhat)
        else:                                        # Option 2
            vhat = jax.tree.map(jnp.maximum, state.vhat, v)
            new_params = jax.tree.map(
                lambda x, mm, vh: x + (eta * mm / (jnp.sqrt(vh) + eps)).astype(x.dtype),
                params, m, vhat)
    else:
        raise ValueError(f"unknown algorithm {algo!r}")

    return new_params, ServerState(m, v, vhat, t)
