"""Server-side optimizers: the paper's FedAMS family plus all baselines.

The server treats the aggregated client delta Δ̂_t as a pseudo-gradient
(paper eq. 3.2-3.4) and performs one adaptive step. Note the *sign*: the
global update is  x ← x + η·m/√v̂  (deltas already point downhill).

    fedavg     : x += η Δ
    fedadagrad : v += Δ²                                   (Reddi et al. 2020)
    fedadam    : Adam(m, v)                                (Reddi et al. 2020)
    fedyogi    : Yogi variance update                      (Reddi et al. 2020)
    fedamsgrad : Option 2 — v̂=max(v̂,v),  x += η m/(√v̂+ε)  (Tong et al. 2020)
    fedams     : Option 1 — v̂=max(v̂,v,ε), x += η m/√v̂     (this paper)

Second-moment storage (``FedConfig.server_state_dtype``): v/v̂ may live as
bf16 or int8-blockscale (:class:`QuantState`) — the update math always runs
in fp32 with dequant/requant at the edges, so the quantization error enters
only through the stored state read back next round. The one-pass fused
ingest entry points (:func:`server_ingest_leaf` / :func:`server_ingest` /
:func:`server_ingest_tree`, DESIGN.md §3) consume the compacted
``(vals, idx)`` client selections directly and fold scatter-mean + update +
dequant/requant into a single read-modify-write over the optimizer state —
no dense mean delta is ever materialized.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig


class ServerState(NamedTuple):
    m: object       # momentum pytree (zeros for fedavg)
    v: object       # second moment (fp32/bf16 arrays or QuantState)
    vhat: object    # max-stabilized second moment (same storage as v)
    t: jax.Array    # round counter


class QuantState(NamedTuple):
    """int8-blockscale storage for one flat second-moment leaf: ``q`` is
    the (N,) int8 payload over the zero-padded block domain (N = nb·block)
    and ``scale`` the (nb,) fp32 per-block absmax scales — dequant is
    ``q * scale[block]``, requant ``scale = max(|v|)/127`` per block."""
    q: jax.Array
    scale: jax.Array


_is_quant = lambda x: isinstance(x, QuantState)

#: Why hierarchical rounds (``FedConfig.agg_groups > 1``) cannot fuse the
#: server ingest: tier 1 pre-merges each group's selections into a DENSE
#: group partial, so the root consumes g dense partials — there is no
#: compacted (vals, idx) stream left for ``server_ingest`` to scatter.
#: Both backends append this to their ``resolve_fused_ingest`` detail so a
#: forced ``fused_ingest='kernel'/'jnp'`` with groups fails with the same
#: explanation everywhere.
FUSED_INGEST_GROUPS_DETAIL = (
    "; hierarchical aggregation (agg_groups > 1) is also ineligible — the "
    "group tier pre-merges selections into dense partials, leaving no "
    "compacted (vals, idx) stream for the one-pass ingest")


def _dequant_flat(qs: QuantState) -> jax.Array:
    nb = qs.scale.shape[0]
    return (qs.q.astype(jnp.float32).reshape(nb, -1)
            * qs.scale[:, None]).reshape(-1)


def _requant_flat(v, nb: int) -> QuantState:
    vb = v.reshape(nb, -1)
    # absmax as max(max v, -min v): the same float exactly, but avoids
    # materializing a full |v| buffer on backends (CPU XLA) that don't
    # fuse abs into the row reduction
    amax = jnp.maximum(jnp.max(vb, axis=1), -jnp.min(vb, axis=1))
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(vb / scale[:, None]), -127, 127).astype(jnp.int8)
    return QuantState(q=q.reshape(-1), scale=scale)


def init_server_state(params, state_dtype: str = "float32",
                      block: int = 2048) -> ServerState:
    """``state_dtype`` selects the v/v̂ storage (m is always fp32); int8
    leaves are stored padded to the ``block`` quantization layout."""
    # m, v, vhat must be DISTINCT buffers: the round executable donates the
    # whole state, and XLA rejects donating one buffer for three parameters
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    if state_dtype == "bfloat16":
        second = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    elif state_dtype == "int8":
        from repro.core.compressors import block_layout

        def qleaf(p):
            bs, nb = block_layout(p.size, block)
            return QuantState(q=jnp.zeros(nb * bs, jnp.int8),
                              scale=jnp.full((nb,), 1e-30, jnp.float32))
        second = lambda: jax.tree.map(qleaf, params)
    else:
        second = zeros
    return ServerState(m=zeros(), v=second(), vhat=second(),
                       t=jnp.zeros((), jnp.int32))


def _state_is_quantized(v_tree) -> bool:
    leaves = jax.tree.leaves(v_tree, is_leaf=_is_quant)
    return any(_is_quant(l) or l.dtype != jnp.float32 for l in leaves)


def server_update(fed: FedConfig, state: ServerState, params, delta):
    """One server step. Returns (new_params, new_state). Quantized v/v̂
    storage (bf16 arrays or :class:`QuantState` leaves) is dequantized to
    fp32, updated with the exact fp32 math, and requantized — bit-identical
    to the fused ingest's storage round-trip."""
    if _state_is_quantized(state.v):
        return _server_update_quantized(fed, state, params, delta)
    return _server_update_f32(fed, state, params, delta)


def _server_update_quantized(fed: FedConfig, state: ServerState, params,
                             delta):
    if _is_quant(state.v):
        # flat sim leaf: the int8 payload lives on the padded block domain
        # — pad the fp32 streams up, update, slice back
        d = params.size
        N = state.v.q.size
        nb = state.v.scale.shape[0]
        pad = N - d
        padf = lambda a: (jnp.pad(a.reshape(-1).astype(jnp.float32),
                                  (0, pad)) if pad
                          else a.reshape(-1).astype(jnp.float32))
        st = ServerState(m=padf(state.m), v=_dequant_flat(state.v),
                         vhat=_dequant_flat(state.vhat), t=state.t)
        newx, st2 = _server_update_f32(fed, st, padf(params), padf(delta))
        return newx[:d].astype(params.dtype), ServerState(
            m=st2.m[:d], v=_requant_flat(st2.v, nb),
            vhat=_requant_flat(st2.vhat, nb), t=st2.t)
    to32 = lambda t: jax.tree.map(lambda a: a.astype(jnp.float32), t)
    st = ServerState(m=state.m, v=to32(state.v), vhat=to32(state.vhat),
                     t=state.t)
    newp, st2 = _server_update_f32(fed, st, params, delta)
    back = lambda new, old: jax.tree.map(
        lambda a, o: a.astype(o.dtype), new, old)
    return newp, ServerState(m=st2.m, v=back(st2.v, state.v),
                             vhat=back(st2.vhat, state.vhat), t=st2.t)


def _server_update_f32(fed: FedConfig, state: ServerState, params, delta):
    algo, b1, b2, eta, eps = fed.algorithm, fed.beta1, fed.beta2, fed.eta, fed.eps
    t = state.t + 1

    if algo == "fedavg":
        new_params = jax.tree.map(
            lambda x, d: x + eta * d.astype(x.dtype), params, delta)
        return new_params, ServerState(state.m, state.v, state.vhat, t)

    m = jax.tree.map(lambda mm, d: b1 * mm + (1 - b1) * d.astype(jnp.float32),
                     state.m, delta)

    if algo == "fedyogi":
        def vup(vv, d):
            d2 = jnp.square(d.astype(jnp.float32))
            return vv - (1 - b2) * d2 * jnp.sign(vv - d2)
        v = jax.tree.map(vup, state.v, delta)
    elif algo == "fedadagrad":
        v = jax.tree.map(
            lambda vv, d: vv + jnp.square(d.astype(jnp.float32)),
            state.v, delta)
    else:
        v = jax.tree.map(
            lambda vv, d: b2 * vv + (1 - b2) * jnp.square(d.astype(jnp.float32)),
            state.v, delta)

    if algo in ("fedadam", "fedyogi", "fedadagrad"):
        vhat = state.vhat  # unused
        new_params = jax.tree.map(
            lambda x, mm, vv: x + (eta * mm / (jnp.sqrt(vv) + eps)).astype(x.dtype),
            params, m, v)
    elif algo == "fedamsgrad":                       # Option 2
        vhat = jax.tree.map(jnp.maximum, state.vhat, v)
        new_params = jax.tree.map(
            lambda x, mm, vh: x + (eta * mm / (jnp.sqrt(vh) + eps)).astype(x.dtype),
            params, m, vhat)
    elif algo in ("fedams", "fedcams"):
        if fed.option == 1:                          # Option 1 (max stabilization)
            vhat = jax.tree.map(
                lambda vh, vv: jnp.maximum(jnp.maximum(vh, vv), eps),
                state.vhat, v)
            new_params = jax.tree.map(
                lambda x, mm, vh: x + (eta * mm / jnp.sqrt(vh)).astype(x.dtype),
                params, m, vhat)
        else:                                        # Option 2
            vhat = jax.tree.map(jnp.maximum, state.vhat, v)
            new_params = jax.tree.map(
                lambda x, mm, vh: x + (eta * mm / (jnp.sqrt(vh) + eps)).astype(x.dtype),
                params, m, vhat)
    else:
        raise ValueError(f"unknown algorithm {algo!r}")

    return new_params, ServerState(m, v, vhat, t)


# ===========================================================================
# One-pass fused ingest (DESIGN.md §3)
# ===========================================================================


def _ingest_option(fed: FedConfig) -> int:
    """fedamsgrad IS Option 2 regardless of ``fed.option`` — the same
    mapping the jnp ``server_update`` branches implement."""
    return 2 if fed.algorithm == "fedamsgrad" else fed.option


def server_ingest_leaf(fed: FedConfig, x, m, v, vh, vals, idx, n_div, *,
                       block: int, impl: str, interpret=None):
    """One-pass sparse ingest for one padded flat leaf.

    ``x``/``m``: (N,) fp32, N = nb·block (the selection's zero-padded block
    domain); ``v``/``vh``: (N,) fp32/bf16 storage or :class:`QuantState`;
    ``vals``/``idx``: (n, nb·k) client-major gathered selections (global
    indices). ``impl``: ``"kernel"`` (Pallas ``fedams_ingest``) or
    ``"jnp"`` (blocked scatter — the scatter domain is (nb, block), so no
    (N,)-shaped dense delta appears in the jaxpr). Returns
    ``(x2, m2, v2, vh2)`` with state in storage form.

    Numerics: ``"jnp"`` is bitwise identical to the two-pass
    ``server_aggregate_sparse`` + ``server_update`` baseline (the blocked
    (nb, block) scatter-add lowers to the same update sequence as the flat
    one). ``"kernel"`` accumulates collisions per client in a fori_loop —
    bitwise equal to ``fedams_ingest_ref`` but within ≤1 ulp of the
    baseline on coordinates where several clients collide.
    """
    N = x.shape[0]
    nb = N // block
    n = vals.shape[0]
    k = vals.reshape(n, -1).shape[1] // nb
    vals3 = vals.reshape(n, nb, k)
    idx3 = idx.reshape(n, nb, k)
    option = _ingest_option(fed)
    state_dtype = ("int8" if _is_quant(v) else str(jnp.dtype(v.dtype)))

    if impl == "kernel":
        from repro.kernels.bitpack import _resolve_interpret
        from repro.kernels.fedams_ingest import fedams_ingest
        kw = dict(n_div=n_div, eta=fed.eta, beta1=fed.beta1, beta2=fed.beta2,
                  eps=fed.eps, option=option, block=block,
                  state_dtype=state_dtype,
                  interpret=_resolve_interpret(interpret))
        if state_dtype == "int8":
            x2, m2, qv, qvh, sv, svh = fedams_ingest(
                x, m, v.q, vh.q, vals3, idx3, v.scale, vh.scale, **kw)
            return x2, m2, QuantState(qv, sv.reshape(-1)), QuantState(
                qvh, svh.reshape(-1))
        return fedams_ingest(x, m, v, vh, vals3, idx3, **kw)

    # -- blocked jnp path: scatter-mean on the (nb, block) domain, then the
    # elementwise step per block — the same ops server_update runs flat
    rows = (idx3 // block).reshape(-1)
    cols = (idx3 % block).reshape(-1)
    acc = jnp.zeros((nb, block), jnp.float32).at[rows, cols].add(
        vals3.reshape(-1))
    dm = acc / n_div
    xb, mb = x.reshape(nb, block), m.reshape(nb, block)
    if state_dtype == "int8":
        vv = v.q.astype(jnp.float32).reshape(nb, block) * v.scale[:, None]
        vhd = vh.q.astype(jnp.float32).reshape(nb, block) * vh.scale[:, None]
    else:
        vv = v.astype(jnp.float32).reshape(nb, block)
        vhd = vh.astype(jnp.float32).reshape(nb, block)
    b1, b2, eta, eps = fed.beta1, fed.beta2, fed.eta, fed.eps
    m2 = b1 * mb + (1 - b1) * dm
    v2 = b2 * vv + (1 - b2) * jnp.square(dm)
    if option == 1:
        vh2 = jnp.maximum(jnp.maximum(vhd, v2), eps)
        x2 = xb + eta * m2 / jnp.sqrt(vh2)
    else:
        vh2 = jnp.maximum(vhd, v2)
        x2 = xb + eta * m2 / (jnp.sqrt(vh2) + eps)
    if state_dtype == "int8":
        return (x2.reshape(-1), m2.reshape(-1),
                _requant_flat(v2, nb), _requant_flat(vh2, nb))
    if state_dtype == "bfloat16":
        return (x2.reshape(-1), m2.reshape(-1),
                v2.astype(jnp.bfloat16).reshape(-1),
                vh2.astype(jnp.bfloat16).reshape(-1))
    return x2.reshape(-1), m2.reshape(-1), v2.reshape(-1), vh2.reshape(-1)


def server_ingest(fed: FedConfig, state: ServerState, xflat, vals, idx,
                  n_div, *, block: int, impl: str, interpret=None):
    """FedSim entry point: fused ingest on the flat (d,) sim vector.

    ``block`` is the selection block size (``block_layout(d,
    fed.wire_block)[0]``); x/m (and fp32/bf16 v/v̂ storage) are padded to
    the nb·block domain for the pass and sliced back — int8
    :class:`QuantState` leaves already live padded. Returns
    ``(new_flat, new_state)`` exactly like the two-pass
    ``server_aggregate_sparse`` + ``server_update``.
    """
    d = xflat.size
    nb = -(-d // block)
    pad = nb * block - d
    padf = lambda a: jnp.pad(a, (0, pad)) if pad else a
    pad_s = lambda s: s if _is_quant(s) else padf(s)
    unpad_s = lambda s: s if _is_quant(s) else s[:d]
    x2, m2, v2, vh2 = server_ingest_leaf(
        fed, padf(xflat), padf(state.m), pad_s(state.v), pad_s(state.vhat),
        vals, idx, n_div, block=block, impl=impl, interpret=interpret)
    return x2[:d], ServerState(m=m2[:d], v=unpad_s(v2), vhat=unpad_s(vh2),
                               t=state.t + 1)


def server_ingest_tree(fed: FedConfig, st: ServerState, params, sels, n_div,
                       gather, *, block: int, impl: str, interpret=None):
    """Mesh entry point: per-leaf gather + fused ingest over the shard tree.

    ``sels`` has :class:`~repro.core.compressors.Selection` leaves (this
    device's compacted uplink); ``gather`` lifts one (nb·k,) array to the
    gathered (n, nb·k) client-major stack (the client-axis all_gather —
    the identical collective ``stages.sparse_topk_leaf`` runs, so the wire
    payload is unchanged). Returns ``(new_params, new_state)`` like
    ``KernelImpl.fedams_update_tree``.
    """
    from repro.core.compressors import Selection, block_layout
    is_sel = lambda s: isinstance(s, Selection)
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_m = jax.tree_util.tree_leaves(st.m)
    flat_v = jax.tree_util.tree_leaves(st.v, is_leaf=_is_quant)
    flat_vh = jax.tree_util.tree_leaves(st.vhat, is_leaf=_is_quant)
    flat_s = jax.tree_util.tree_leaves(sels, is_leaf=is_sel)
    xs, ms, vs, vhs = [], [], [], []
    for x, m, v, vh, sel in zip(flat_p, flat_m, flat_v, flat_vh, flat_s):
        bs, nb = block_layout(x.size, block)
        pad = nb * bs - x.size
        padf = lambda a: (jnp.pad(a.reshape(-1).astype(jnp.float32),
                                  (0, pad)) if pad
                          else a.reshape(-1).astype(jnp.float32))
        pads = lambda a: (a if _is_quant(a) else
                          (jnp.pad(a.reshape(-1), (0, pad)) if pad
                           else a.reshape(-1)))
        x2, m2, v2, vh2 = server_ingest_leaf(
            fed, padf(x), padf(m), pads(v), pads(vh),
            gather(sel.vals), gather(sel.idx), n_div,
            block=bs, impl=impl, interpret=interpret)
        n = x.size
        xs.append(x2[:n].reshape(x.shape).astype(x.dtype))
        ms.append(m2[:n].reshape(x.shape))
        vs.append(v2 if _is_quant(v2) else v2[:n].reshape(x.shape))
        vhs.append(vh2 if _is_quant(vh2) else vh2[:n].reshape(x.shape))
    unf = lambda ls: jax.tree_util.tree_unflatten(tdef, ls)
    return unf(xs), ServerState(m=unf(ms), v=unf(vs), vhat=unf(vhs),
                                t=st.t + 1)
