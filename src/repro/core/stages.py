"""Shared round stages: the EF→compress→wire plumbing both backends use
(DESIGN.md §8).

Before the split these lived duplicated inside the rounds monolith — once
in the simulation's per-client block and once in the mesh ``fed_round``.
Each stage is a pure function over arrays; the backends (core/sim.py,
core/mesh.py) compose them around their own execution strategy (vmapped
flat vectors vs. shard_map collectives over pytree shards).

Simulation-side stages (flat per-client vectors):

* :func:`client_uplink`   — EF + compressor and/or wire codec for a block
  of client deltas; EF always tracks the value the wire actually carried.
* :func:`client_uplink_sparse` / :func:`server_aggregate_sparse` — the
  select-once sparse fast path (DESIGN.md §3): the compressor's
  :class:`~repro.core.compressors.Selection` stays a compacted
  ``(vals, idx)`` pair from client to server, the aggregate is an
  O(n·k + d) segment scatter instead of a dense (n, d) mean, and no dense
  per-client hat is ever materialized.
* :func:`server_downlink` — the beyond-paper two-way (server→client)
  EF-compressed downlink (paper appendix D).
* :func:`gamma_diagnostic` — the Assumption 4.17 γ measurement (Fig. 6).

Mesh-side stages (per-device pytree shards + client-axis collectives):

* :func:`agg_dense`        — paper-faithful dense psum aggregation.
* :func:`sparse_topk_leaf` — wire-size-true blockwise top-k all_gather.
* :func:`packed_sign_leaf` — 1-bit/coordinate packed-sign all_gather.
* :func:`mesh_uplink`      — the full uplink: aggregation-strategy
  selection + masked EF + delta-dtype narrowing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import FedConfig
from repro.core.compressors import Compressor
from repro.core.error_feedback import ef_compress, ef_compress_masked
from repro.sharding.rules import ParallelContext


# ===========================================================================
# Simulation-side stages (flat per-client vectors)
# ===========================================================================


def client_uplink(comp: Optional[Compressor], codec, d: int, rng,
                  delta, errs, pos):
    """Local delta → (what the server receives, next EF error) for a block
    of clients.

    ``delta``: (c, d) flat deltas; ``errs``: (c, d) EF errors — ignored
    (and returned unchanged) when ``comp`` is None; ``pos``: (c,) global
    positions in the round (the per-client rng stream). Four cases:

    * comp + codec — wire mode: the EF total really goes through
      encode→decode; EF tracks the *decoded* value, so narrowed wire value
      dtypes stay exact in the error-feedback sense.
    * comp only — in-memory EF compression (``ef_compress``).
    * codec only — uncompressed algorithm over a dense32 wire.
    * neither — the delta passes through untouched.
    """
    if comp is not None:
        if codec is not None:
            def one(dd, ee, i):
                # the per-client key reaches the codec so a stochastic
                # wire format (randomized rounding) can't desync streams
                tot = dd + ee
                hat = codec.decode(
                    codec.encode(tot, jax.random.fold_in(rng, i)), d)
                return hat, tot - hat
        else:
            def one(dd, ee, i):
                return ef_compress(comp, dd, ee, jax.random.fold_in(rng, i))
        return jax.vmap(one)(delta, errs, pos)
    if codec is not None:
        hats = jax.vmap(lambda t: codec.decode(codec.encode(t), d))(delta)
    else:
        hats = delta
    return hats, errs


def client_uplink_sparse(comp: Compressor, codec, d: int, rng, tot, pos):
    """The select-once fast path for a block of clients (DESIGN.md §3).

    ``tot``: (c, d) EF totals (delta + carried error). The selection
    happens ONCE (``comp.select``) and the server-bound message stays the
    compacted ``(vals, idx)`` pair: no dense hat is built, and in wire mode
    the codec's ``roundtrip_selection`` narrows the values exactly the way
    the packed bytes would (bit-identical to the full encode→decode,
    property-tested) instead of re-running ``lax.top_k`` over the dense
    vector.

    Returns ``(sel_vals, idx, rx_vals)``, each (c, k): the selected values,
    their flat positions, and the values as the server receives them
    (``rx_vals == sel_vals`` on a float32 wire). The caller finishes error
    feedback with :func:`ef_update_sparse` — only selected coordinates
    change the error, so the EF write is an O(c·k) scatter, not a dense
    (c, d) rebuild.
    """
    def one(t, i):
        sel = comp.select(t, jax.random.fold_in(rng, i))
        rx = (codec.roundtrip_selection(sel, d) if codec is not None
              else sel)
        return sel.vals, sel.idx, rx.vals
    return jax.vmap(one)(tot, pos)


def ef_update_sparse(errors, rows, idx, sel_vals, rx_vals):
    """Finish sparse-path error feedback in place on the (m, d) buffer.

    ``errors`` rows already hold this round's totals (``err += delta``);
    only the selected coordinates change: they become ``sel_vals −
    rx_vals`` — exact zeros on a float32 wire (tot − tot), the quantization
    residual on narrowed wires — which equals the dense path's
    ``tot − hat`` coordinate for coordinate. ``rows``: (c,) client rows;
    ``idx``/``sel_vals``/``rx_vals``: (c, k). Padded-block positions
    (``idx >= d``, blockwise compressors) are dropped by the scatter,
    mirroring the dense pad-and-slice."""
    r = jnp.broadcast_to(rows[:, None], idx.shape)
    return errors.at[r, idx].set(sel_vals - rx_vals)


def server_aggregate_sparse(vals, idx, d: int, n: int):
    """Mean of n sparse client messages as one segment scatter-add over the
    (n·k) received entries — O(n·k + d) instead of the dense (n, d) mean.

    Collisions (a coordinate selected by several clients) accumulate in
    client order; floating-point reassociation against the dense mean's
    reduce is at most 1 ulp per colliding coordinate (see
    tests/test_sparse_uplink.py). Out-of-range padded indices are dropped.
    """
    return jnp.zeros(d, jnp.float32).at[idx.reshape(-1)].add(
        vals.reshape(-1)) / n


def server_downlink(fed: FedConfig, comp: Optional[Compressor], codec,
                    d: int, rng, new_flat, x_client, server_error):
    """Two-way (server→client) EF compression, paper appendix D.

    Returns ``(new_x_client, new_server_error)``: the model as clients will
    see it next round plus the carried server-side error. With ``two_way``
    off the clients see the exact new model and the error passes through."""
    if not (fed.two_way and comp is not None):
        return new_flat, server_error
    upd = new_flat - x_client
    tot = upd + server_error
    if codec is not None:  # downlink exercises the wire codec too
        hat = codec.decode(codec.encode(tot), d)
    else:
        hat = comp.compress(tot, jax.random.fold_in(rng, 10**6))
    return x_client + hat, tot - hat


def gamma_diagnostic(comp: Optional[Compressor], rng, mean_tot, agg,
                     mean_delta):
    """Assumption 4.17 diagnostic (paper Fig. 6):
    γ = ‖C(mean(Δ+e)) − mean(C(Δ+e))‖ / ‖mean(Δ)‖ — zero when
    uncompressed."""
    if comp is None:
        return jnp.zeros(())
    c_of_mean = comp.compress(mean_tot, jax.random.fold_in(rng, 999983))
    return (jnp.linalg.norm(c_of_mean - agg)
            / jnp.maximum(jnp.linalg.norm(mean_delta), 1e-12))


# ===========================================================================
# Mesh-side stages (per-device pytree shards, client-axis collectives)
# ===========================================================================


def agg_dense(hat_tree, my_mask, n_eff, ctx: ParallelContext,
              wire_dtype: str = "float32"):
    """Paper-faithful: dense psum over the client axes. ``wire_dtype``
    narrows the collective payload (bf16 halves client-axis bytes; the
    caller keeps error feedback exact by tracking the narrowed value)."""
    wd = jnp.dtype(wire_dtype)
    contrib = jax.tree.map(
        lambda h: jnp.where(my_mask > 0, h, 0.0).astype(wd), hat_tree)
    return jax.tree.map(
        lambda c: ctx.psum_clients(c).astype(jnp.float32) / n_eff, contrib)


def sparse_topk_leaf(tot, ratio, my_mask, n_eff, ctx: ParallelContext,
                     block: int = 2048):
    """Beyond-paper: all_gather (values, indices) of the local blockwise
    top-k and scatter-add — the wire carries ~2k words instead of d, and the
    selection is bit-identical to the dense blocktopk path (same
    ``block_layout``). Returns (aggregated dense leaf, this client's dense
    hat for error feedback)."""
    from repro.core.compressors import block_layout
    flat = tot.reshape(-1)
    d = flat.size
    bs, nb = block_layout(d, block)
    pad = nb * bs - d
    xb = jnp.pad(flat, (0, pad)).reshape(nb, bs)
    k = max(1, int(round(ratio * bs)))
    _, idx = lax.top_k(jnp.abs(xb), k)                       # (nb, k)
    vals = jnp.take_along_axis(xb, idx, axis=1)
    gidx = (idx + (jnp.arange(nb) * bs)[:, None]).reshape(-1)
    kept = vals.reshape(-1)
    hat = jnp.zeros(nb * bs, flat.dtype).at[gidx].set(kept)[:d]
    masked = kept * (my_mask > 0)
    g_vals = ctx.all_gather_clients(masked[None], axis=0).reshape(-1)
    g_idx = ctx.all_gather_clients(gidx[None], axis=0).reshape(-1)
    # NB: fresh zeros (replicated vma) — zeros_like(varying) would taint the
    # aggregate as client-varying.
    zeros = jnp.zeros(nb * bs, flat.dtype)
    agg = (zeros.at[g_idx].add(g_vals) / n_eff)[:d]
    return agg.reshape(tot.shape), hat.reshape(tot.shape)


def packed_sign_leaf(tot, my_mask, n_eff, ctx: ParallelContext):
    """Beyond-paper: scaled-sign with the sign bits packed 8->1 in uint8 for
    the client-axis all_gather (1 bit/coordinate on the wire)."""
    flat = tot.reshape(-1)
    d = flat.size
    scale = jnp.mean(jnp.abs(flat)) * (my_mask > 0)
    bits = jnp.packbits((flat >= 0).astype(jnp.uint8))
    g_bits = ctx.all_gather_clients(bits[None], axis=0)      # (m, d/8)
    g_scale = ctx.all_gather_clients(scale[None], axis=0)    # (m,)
    signs = jnp.unpackbits(g_bits, axis=1)[:, :d].astype(jnp.float32) * 2.0 - 1.0
    agg = (g_scale[:, None] * signs).sum(0) / n_eff
    # sign(0) := +1 to match the packed bits (error feedback must track the
    # value the wire actually carried)
    hat = jnp.mean(jnp.abs(flat)) * jnp.where(flat >= 0, 1.0, -1.0)
    return agg.reshape(tot.shape), hat.reshape(tot.shape)


def _split_pairs(pairs):
    is_pair = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda pr: pr[0], pairs, is_leaf=is_pair),
            jax.tree.map(lambda pr: pr[1], pairs, is_leaf=is_pair))


def mesh_uplink(fed: FedConfig, comp: Optional[Compressor],
                ctx: ParallelContext, kernel_impl, rng, delta, my_err,
                my_mask, n_eff):
    """This device's delta shards → (aggregated update, next EF error).

    Selects the aggregation strategy (DESIGN.md §3) — dense psum, sparse
    blockwise-top-k gather, or packed-sign gather — applies masked error
    feedback, and narrows the dense collective to ``fed.delta_dtype`` with
    EF tracking the narrowed value."""
    if comp is None:
        return agg_dense(delta, my_mask, n_eff, ctx, fed.delta_dtype), my_err

    sparse = fed.aggregation == "sparse"
    if sparse and fed.compressor in ("topk", "blocktopk", "packedsign"):
        if fed.compressor == "packedsign":
            leaf_fn = lambda t: packed_sign_leaf(t, my_mask, n_eff, ctx)
        else:
            leaf_fn = lambda t: sparse_topk_leaf(t, fed.compress_ratio,
                                                 my_mask, n_eff, ctx)
        tot = jax.tree.map(lambda dd, ee: dd + ee, delta, my_err)
        agg, hat = _split_pairs(jax.tree.map(leaf_fn, tot))
        new_err = jax.tree.map(
            lambda t, h, eo: jnp.where(my_mask > 0, t - h, eo),
            tot, hat, my_err)
        return agg, new_err

    if kernel_impl is not None:
        hat, new_err = kernel_impl.ef_compress_tree(comp, delta, my_err,
                                                    my_mask)
    else:
        hat, new_err = ef_compress_masked(comp, delta, my_err, my_mask,
                                          jax.random.fold_in(rng, 2))
    if fed.delta_dtype != "float32":
        # error feedback must track the value actually sent
        wd = jnp.dtype(fed.delta_dtype)
        hat_tx = jax.tree.map(
            lambda h: h.astype(wd).astype(jnp.float32), hat)
        new_err = jax.tree.map(
            lambda d, e, h: jnp.where(my_mask > 0, d + e - h, e),
            delta, my_err, hat_tx)
        hat = hat_tx
    return agg_dense(hat, my_mask, n_eff, ctx, fed.delta_dtype), new_err
