"""Shared round stages: the EF→compress→wire plumbing both backends use
(DESIGN.md §8).

Before the split these lived duplicated inside the rounds monolith — once
in the simulation's per-client block and once in the mesh ``fed_round``.
Each stage is a pure function over arrays; the backends (core/sim.py,
core/mesh.py) compose them around their own execution strategy (vmapped
flat vectors vs. shard_map collectives over pytree shards).

Simulation-side stages (flat per-client vectors):

* :func:`client_uplink`   — EF + compressor and/or wire codec for a block
  of client deltas; EF always tracks the value the wire actually carried.
* :func:`client_uplink_sparse` / :func:`server_aggregate_sparse` — the
  select-once sparse fast path (DESIGN.md §3): the compressor's
  :class:`~repro.core.compressors.Selection` stays a compacted
  ``(vals, idx)`` pair from client to server, the aggregate is an
  O(n·k + d) segment scatter instead of a dense (n, d) mean, and no dense
  per-client hat is ever materialized.
* :func:`server_downlink` — the beyond-paper two-way (server→client)
  EF-compressed downlink (paper appendix D).
* :func:`gamma_diagnostic` — the Assumption 4.17 γ measurement (Fig. 6).

Mesh-side stages (per-device pytree shards + client-axis collectives):

* :func:`agg_dense`         — paper-faithful dense psum aggregation.
* :func:`mesh_agg_strategy` — single resolver for which client-axis
  collective a config actually runs (mesh_uplink and the
  ``mesh_wire_bytes`` metric share it, so the byte accounting can never
  drift from the executed path).
* :func:`topk_select_tree`  — per-leaf select-once ``Selection`` + fused
  O(k)-scatter EF (the jnp sibling of
  ``KernelImpl.topk_select_tree``).
* :func:`sparse_topk_leaf`  — wire-size-true all_gather of one leaf's
  compacted ``(vals, idx)`` Selection + server scatter-add.
* :func:`sparse_topk_hier_leaf` — the two-level form (``agg_groups > 1``):
  member-axis Selection gather into a dense group partial, root consumes
  the g partials (DESIGN.md §scale-out).
* :func:`packed_sign_leaf`  — 1-bit/coordinate packed-sign all_gather.
* :func:`mesh_uplink`       — the full uplink: aggregation-strategy
  selection + masked EF + delta-dtype narrowing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.compressors import Compressor, Selection
from repro.core.error_feedback import ef_compress, ef_compress_masked
from repro.sharding.rules import ParallelContext


# ===========================================================================
# Simulation-side stages (flat per-client vectors)
# ===========================================================================


def client_uplink(comp: Optional[Compressor], codec, d: int, rng,
                  delta, errs, pos):
    """Local delta → (what the server receives, next EF error) for a block
    of clients.

    ``delta``: (c, d) flat deltas; ``errs``: (c, d) EF errors — ignored
    (and returned unchanged) when ``comp`` is None; ``pos``: (c,) global
    positions in the round (the per-client rng stream). Four cases:

    * comp + codec — wire mode: the EF total really goes through
      encode→decode; EF tracks the *decoded* value, so narrowed wire value
      dtypes stay exact in the error-feedback sense.
    * comp only — in-memory EF compression (``ef_compress``).
    * codec only — uncompressed algorithm over a dense32 wire.
    * neither — the delta passes through untouched.
    """
    if comp is not None:
        if codec is not None:
            def one(dd, ee, i):
                # the per-client key reaches the codec so a stochastic
                # wire format (randomized rounding) can't desync streams
                tot = dd + ee
                hat = codec.decode(
                    codec.encode(tot, jax.random.fold_in(rng, i)), d)
                return hat, tot - hat
        else:
            def one(dd, ee, i):
                return ef_compress(comp, dd, ee, jax.random.fold_in(rng, i))
        return jax.vmap(one)(delta, errs, pos)
    if codec is not None:
        hats = jax.vmap(lambda t: codec.decode(codec.encode(t), d))(delta)
    else:
        hats = delta
    return hats, errs


def client_uplink_sparse(comp: Compressor, codec, d: int, rng, tot, pos):
    """The select-once fast path for a block of clients (DESIGN.md §3).

    ``tot``: (c, d) EF totals (delta + carried error). The selection
    happens ONCE (``comp.select``) and the server-bound message stays the
    compacted ``(vals, idx)`` pair: no dense hat is built, and in wire mode
    the codec's ``roundtrip_selection`` narrows the values exactly the way
    the packed bytes would (bit-identical to the full encode→decode,
    property-tested) instead of re-running ``lax.top_k`` over the dense
    vector.

    Returns ``(sel_vals, idx, rx_vals)``, each (c, k): the selected values,
    their flat positions, and the values as the server receives them
    (``rx_vals == sel_vals`` on a float32 wire). The caller finishes error
    feedback with :func:`ef_update_sparse` — only selected coordinates
    change the error, so the EF write is an O(c·k) scatter, not a dense
    (c, d) rebuild.
    """
    def one(t, i):
        sel = comp.select(t, jax.random.fold_in(rng, i))
        rx = (codec.roundtrip_selection(sel, d) if codec is not None
              else sel)
        return sel.vals, sel.idx, rx.vals
    return jax.vmap(one)(tot, pos)


def ef_update_sparse(errors, rows, idx, sel_vals, rx_vals):
    """Finish sparse-path error feedback in place on the (m, d) buffer.

    ``errors`` rows already hold this round's totals (``err += delta``);
    only the selected coordinates change: they become ``sel_vals −
    rx_vals`` — exact zeros on a float32 wire (tot − tot), the quantization
    residual on narrowed wires — which equals the dense path's
    ``tot − hat`` coordinate for coordinate. ``rows``: (c,) client rows;
    ``idx``/``sel_vals``/``rx_vals``: (c, k). Padded-block positions
    (``idx >= d``, blockwise compressors) are dropped by the scatter,
    mirroring the dense pad-and-slice."""
    r = jnp.broadcast_to(rows[:, None], idx.shape)
    return errors.at[r, idx].set(sel_vals - rx_vals)


def server_aggregate_sparse(vals, idx, d: int, n: int):
    """Mean of n sparse client messages as one segment scatter-add over the
    (n·k) received entries — O(n·k + d) instead of the dense (n, d) mean.

    Collisions (a coordinate selected by several clients) accumulate in
    client order; floating-point reassociation against the dense mean's
    reduce is at most 1 ulp per colliding coordinate (see
    tests/test_sparse_uplink.py). Out-of-range padded indices are dropped.
    """
    return jnp.zeros(d, jnp.float32).at[idx.reshape(-1)].add(
        vals.reshape(-1)) / n


def server_aggregate_sparse_grouped(vals, idx, d: int, n: int, groups: int):
    """Two-tier mean of n sparse client messages (DESIGN.md §scale-out):
    the clients split into ``groups`` contiguous groups of n/g members;
    each group segment-scatters its members' ``(vals, idx)`` entries into
    a FRESH dense partial (tier 1 — the group-local merge), and the root
    sums the g partials (tier 2). Exactly the entries
    :func:`server_aggregate_sparse` consumes and the association the mesh's
    :func:`sparse_topk_hier_leaf` executes: within a group the scatter
    accumulates in member order, across groups the partial stack reduces —
    so vs the flat scatter only coordinates selected by clients in ≥2
    groups can reassociate, at ≤1 ulp each (the PR-4 collision analysis
    one level up)."""
    k = vals.shape[-1]
    vg = vals.reshape(groups, -1, k)
    ig = idx.reshape(groups, -1, k)
    partials = jax.vmap(
        lambda v, i: jnp.zeros(d, jnp.float32).at[i.reshape(-1)].add(
            v.reshape(-1)))(vg, ig)
    return jnp.sum(partials, axis=0) / n


def server_aggregate_sparse_masked(vals, idx, d: int, surv):
    """Survivor-masked sibling of :func:`server_aggregate_sparse`
    (DESIGN.md §robustness): mean of the sparse client messages over the
    SURVIVORS only — ``surv`` (n,) f32 is the fault-round survivor mask
    (delivered ∧ validated). Non-survivor entries are replaced by 0 with
    ``where`` (never multiply: a poisoned NaN times 0.0 is still NaN) and
    the divisor is the survivor count (min 1 — an all-dead round yields a
    zero aggregate, not a NaN). With an all-ones mask this is bit-identical
    to :func:`server_aggregate_sparse`: same scatter order, and the traced
    f32 count equals the Python ``n`` the unmasked path divides by."""
    contrib = jnp.where(surv[:, None] > 0, vals, 0.0)
    n_surv = jnp.maximum(jnp.sum(surv), 1.0)
    return jnp.zeros(d, jnp.float32).at[idx.reshape(-1)].add(
        contrib.reshape(-1)) / n_surv


def server_aggregate_sparse_weighted(vals, idx, d: int, w):
    """Weighted sibling of :func:`server_aggregate_sparse_masked` for the
    async buffered flush (DESIGN.md §11): ``w`` (n,) f32 carries each
    buffer entry's staleness weight × validity × fill mask, the aggregate
    is ``Σ_i w_i · vals_i / max(Σ w, 1)``. Zero-weight entries are
    replaced by 0 with ``where`` BEFORE the multiply (a rejected payload's
    NaN times 0.0 is still NaN). With all-ones ``w`` this is bit-identical
    to :func:`server_aggregate_sparse`: ``vals * 1.0`` is an IEEE
    identity, the scatter order is unchanged, and the traced f32 weight
    sum equals the Python ``n`` divisor (the parity anchor the async
    engine's acceptance flows through)."""
    contrib = jnp.where(w[:, None] > 0, vals, 0.0) * w[:, None]
    den = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.zeros(d, jnp.float32).at[idx.reshape(-1)].add(
        contrib.reshape(-1)) / den


def server_downlink(fed: FedConfig, comp: Optional[Compressor], codec,
                    d: int, rng, new_flat, x_client, server_error):
    """Two-way (server→client) EF compression, paper appendix D.

    Returns ``(new_x_client, new_server_error)``: the model as clients will
    see it next round plus the carried server-side error. With ``two_way``
    off the clients see the exact new model and the error passes through."""
    if not (fed.two_way and comp is not None):
        return new_flat, server_error
    upd = new_flat - x_client
    tot = upd + server_error
    if codec is not None:  # downlink exercises the wire codec too
        hat = codec.decode(codec.encode(tot), d)
    else:
        hat = comp.compress(tot, jax.random.fold_in(rng, 10**6))
    return x_client + hat, tot - hat


def gamma_diagnostic(comp: Optional[Compressor], rng, mean_tot, agg,
                     mean_delta):
    """Assumption 4.17 diagnostic (paper Fig. 6):
    γ = ‖C(mean(Δ+e)) − mean(C(Δ+e))‖ / ‖mean(Δ)‖ — zero when
    uncompressed."""
    if comp is None:
        return jnp.zeros(())
    c_of_mean = comp.compress(mean_tot, jax.random.fold_in(rng, 999983))
    return (jnp.linalg.norm(c_of_mean - agg)
            / jnp.maximum(jnp.linalg.norm(mean_delta), 1e-12))


# ===========================================================================
# Mesh-side stages (per-device pytree shards, client-axis collectives)
# ===========================================================================


def agg_dense(hat_tree, my_mask, n_eff, ctx: ParallelContext,
              wire_dtype: str = "float32"):
    """Paper-faithful: dense psum over the client axes. ``wire_dtype``
    narrows the collective payload (bf16 halves client-axis bytes; the
    caller keeps error feedback exact by tracking the narrowed value)."""
    wd = jnp.dtype(wire_dtype)
    contrib = jax.tree.map(
        lambda h: jnp.where(my_mask > 0, h, 0.0).astype(wd), hat_tree)
    return jax.tree.map(
        lambda c: ctx.psum_clients(c).astype(jnp.float32) / n_eff, contrib)


def mesh_agg_strategy(fed: FedConfig) -> str:
    """Which client-axis collective the mesh round actually runs for this
    config: ``"sparse_topk"`` (compacted Selection all_gather),
    ``"sparse_topk_hier"`` (two-level: member-axis Selection gather into a
    dense group partial, then the root consumes the g partials —
    ``agg_groups > 1``, DESIGN.md §scale-out), ``"packed_sign"`` (1-bit
    packed gather), or ``"dense"`` (psum — including every fallback:
    non-fedcams algorithms, and sparse aggregation requested for a
    compressor with no compacted form). ``mesh_uplink`` and
    ``mesh_wire_bytes`` both resolve through here, so the wire accounting
    reports the path (and the tiers) that execute, never what the config
    merely asked for."""
    if fed.algorithm != "fedcams" or fed.aggregation != "sparse":
        return "dense"
    if fed.compressor in ("topk", "blocktopk"):
        return "sparse_topk_hier" if fed.agg_groups > 1 else "sparse_topk"
    if fed.compressor == "packedsign":
        return "packed_sign"
    return "dense"


def resolve_mesh_sparse_impl(fed: FedConfig, kernel_impl) -> str:
    """``fed.mesh_sparse_impl`` → the selection provider that will run:
    ``"kernel"`` (fused Pallas ``topk_ef_sparse`` via
    ``KernelImpl.topk_select_tree``) or ``"jnp"`` (``Compressor.select``).
    ``auto`` picks the kernel only when it would compile (TPU) — off-TPU
    the interpreter loses to compiled XLA, so auto falls back to jnp even
    when a KernelImpl is supplied (it still serves the dense-hat
    ``ef_compress_tree`` path)."""
    impl = fed.mesh_sparse_impl
    if impl == "kernel":
        if kernel_impl is None:
            raise ValueError(
                "FedConfig.mesh_sparse_impl='kernel' but no kernel_impl "
                "was supplied — pass KernelImpl() to build_fed_round "
                "(launch/train.py: --use-kernels or --mesh-sparse-impl "
                "kernel)")
        return "kernel"
    if impl == "jnp":
        return "jnp"
    return ("kernel" if kernel_impl is not None and kernel_impl.compiled
            else "jnp")


def resolve_fused_ingest(fed: FedConfig, *, eligible: bool,
                         have_kernel: bool, compiled: bool,
                         detail: str = "") -> str:
    """``fed.fused_ingest`` → the ingest path that will run: ``"kernel"``
    (Pallas ``kernels.fedams_ingest``), ``"jnp"`` (the blocked-scatter
    fused path in ``server_opt.server_ingest_leaf``), or ``"off"`` (the
    two-pass ``server_aggregate_sparse`` + ``server_update`` baseline).

    Resolved at BUILD time, like :func:`resolve_mesh_sparse_impl`: the
    backend passes ``eligible`` (can this round fuse at all — sparse
    blocktopk uplink, no dense-aggregate consumers like the γ diagnostic,
    no client chunking / state sharding) and the resolver errors on a
    forced knob the build cannot honor instead of silently falling back.
    ``auto`` fuses whenever eligible, picking the kernel only where it
    compiles (TPU) — exactly the ``mesh_sparse_impl`` auto rule."""
    knob = fed.fused_ingest
    if knob == "off":
        return "off"
    if not eligible:
        if knob in ("kernel", "jnp"):
            raise ValueError(
                f"FedConfig.fused_ingest={knob!r} but this round cannot "
                f"fuse the server ingest: {detail}")
        return "off"
    if knob == "kernel" and not have_kernel:
        raise ValueError(
            "FedConfig.fused_ingest='kernel' but no kernel_impl was "
            "supplied — pass KernelImpl() to build_fed_round "
            "(launch/train.py: --use-kernels or --fused-ingest kernel)")
    if knob in ("kernel", "jnp"):
        return knob
    return "kernel" if (have_kernel and compiled) else "jnp"


def select_tree(select_leaf, delta, err, mask):
    """Shared select-once tree plumbing for BOTH selection providers (the
    jnp :func:`topk_select_tree` and the Pallas
    :meth:`repro.kernels.ops.KernelImpl.topk_select_tree` — the masking
    semantics live exactly once so the providers' documented bit-identity
    cannot drift). ``select_leaf(delta_leaf, err_leaf) -> (Selection,
    new_err_leaf)`` produces one leaf's compacted selection + fused EF
    residual; this wrapper applies the participation mask —
    non-participating clients (``mask == 0``) contribute zero values to
    the collective and keep their error unchanged.

    Returns ``(sel_tree, err_tree)`` where ``sel_tree`` has
    :class:`~repro.core.compressors.Selection` leaves (flat global ``idx``
    in the per-leaf zero-padded block domain)."""
    flat_d, tdef = jax.tree_util.tree_flatten(delta)
    flat_e = jax.tree_util.tree_leaves(err)
    sels, errs = [], []
    for dd, ee in zip(flat_d, flat_e):
        sel, ne = select_leaf(dd, ee)
        sels.append(Selection(vals=sel.vals * (mask > 0), idx=sel.idx))
        errs.append(jnp.where(mask > 0, ne, ee))
    return (jax.tree_util.tree_unflatten(tdef, sels),
            jax.tree_util.tree_unflatten(tdef, errs))


def topk_select_tree(comp: Compressor, delta, err, mask):
    """Select-once uplink for every leaf of this device's shard tree —
    the jnp sibling of :meth:`repro.kernels.ops.KernelImpl.topk_select_tree`
    (identical contract, bit-identical selection/EF).

    Per leaf: the EF total ``delta + err`` is selected ONCE
    (``comp.select`` — the same ``lax.top_k``/argmax semantics as the
    dense blocktopk path) and error feedback finishes as an O(k) scatter
    that zeroes exactly the selected coordinates (``tot − hat`` is ``tot``
    with the kept entries zeroed; no dense hat is ever built). Padded-tail
    indices (``idx >= d``) carry value 0.0 and are dropped by the
    scatter."""

    def leaf(dd, ee):
        tot = (dd + ee).reshape(-1)
        sel = comp.select(tot)
        return sel, tot.at[sel.idx].set(0.0).reshape(ee.shape)

    return select_tree(leaf, delta, err, mask)


def sparse_topk_leaf(sel: Selection, leaf, n_eff, ctx: ParallelContext):
    """Beyond-paper: aggregate one leaf from the clients' compacted
    Selections — the client-axis all_gather carries the already-selected
    ``(vals, idx)`` pairs (~2k words instead of d) and the server side is
    one scatter-add, exactly :func:`server_aggregate_sparse` over the
    gathered entries. The selection itself (and its fused EF residual)
    comes from the provider — :func:`topk_select_tree` or the Pallas
    ``KernelImpl.topk_select_tree`` — so no dense per-client hat exists on
    this path. ``leaf`` supplies the output shape; padded-tail indices
    (``idx >= leaf.size``) are dropped by the scatter."""
    d = leaf.size
    g_vals = ctx.all_gather_clients(sel.vals[None], axis=0).reshape(-1)
    g_idx = ctx.all_gather_clients(sel.idx[None], axis=0).reshape(-1)
    # NB: fresh zeros (replicated vma) — zeros_like(varying) would taint the
    # aggregate as client-varying.
    zeros = jnp.zeros(d, jnp.float32)
    agg = zeros.at[g_idx].add(g_vals) / n_eff
    return agg.reshape(leaf.shape)


def sparse_topk_leaf_validated(sel: Selection, leaf, mask,
                               ctx: ParallelContext, domain: int,
                               max_norm: float):
    """Fault-tolerant sibling of :func:`sparse_topk_leaf` (DESIGN.md
    §robustness): the gathered ``(vals, idx)`` selections pass the
    server's validation-before-ingest gate (NaN/Inf rejection, index-range
    check against the leaf's padded block ``domain``, optional per-client
    norm clip) and the scatter-mean runs over the combined survivor mask
    ``alive ∧ valid`` — an invalid payload contributes nothing and shrinks
    the divisor, so one poisoned client cannot corrupt the aggregate.

    ``mask``: (m,) f32 alive-mask (participation ∧ fault). Returns
    ``(agg, my_valid, rejected)``: the aggregated leaf, THIS device's own
    validity (every device sees the gathered copies, including its own
    damaged payload, so the NACK needs no extra collective), and the
    count of delivered-but-rejected clients for this leaf."""
    d = leaf.size
    from repro.comm.faults import validate_selection
    g_vals = ctx.all_gather_clients(sel.vals[None], axis=0)   # (m, k)
    g_idx = ctx.all_gather_clients(sel.idx[None], axis=0)     # (m, k)
    vvals, valid = validate_selection(g_vals, g_idx, domain, max_norm)
    surv = mask * valid
    contrib = jnp.where(surv[:, None] > 0, vvals, 0.0)
    zeros = jnp.zeros(d, jnp.float32)
    agg = zeros.at[g_idx.reshape(-1)].add(contrib.reshape(-1)) \
        / jnp.maximum(jnp.sum(surv), 1.0)
    rejected = jnp.sum(mask * (1.0 - valid))
    return agg.reshape(leaf.shape), valid[ctx.client_index()], rejected


def sparse_topk_hier_leaf(sel: Selection, leaf, n_eff,
                          ctx: ParallelContext):
    """Two-level aggregation of one leaf (DESIGN.md §scale-out). Tier 1:
    the member-axis all_gather carries each group's compacted ``(vals,
    idx)`` Selections (the same O(k)/client payload as
    :func:`sparse_topk_leaf`, but fanned into g independent gathers), and
    every group merges its members' entries into a dense partial with the
    same blocked scatter-add. Tier 2 — the root collective — gathers the g
    group partials over the group axis and sums them: the root consumes g
    messages of d words, independent of how many clients each group holds,
    instead of n·k client entries. Association matches
    :func:`server_aggregate_sparse_grouped` (within-group member-order
    scatter, then the partial-stack reduce)."""
    d = leaf.size
    g_vals = ctx.all_gather_members(sel.vals[None], axis=0).reshape(-1)
    g_idx = ctx.all_gather_members(sel.idx[None], axis=0).reshape(-1)
    # fresh zeros (replicated vma), exactly like sparse_topk_leaf
    partial = jnp.zeros(d, jnp.float32).at[g_idx].add(g_vals)
    partials = ctx.all_gather_group_partials(partial[None], axis=0)  # (g, d)
    agg = jnp.sum(partials, axis=0) / n_eff
    return agg.reshape(leaf.shape)


def packed_sign_leaf(tot, my_mask, n_eff, ctx: ParallelContext):
    """Beyond-paper: scaled-sign with the sign bits packed 8->1 in uint8 for
    the client-axis all_gather (1 bit/coordinate on the wire)."""
    flat = tot.reshape(-1)
    d = flat.size
    scale = jnp.mean(jnp.abs(flat)) * (my_mask > 0)
    bits = jnp.packbits((flat >= 0).astype(jnp.uint8))
    g_bits = ctx.all_gather_clients(bits[None], axis=0)      # (m, d/8)
    g_scale = ctx.all_gather_clients(scale[None], axis=0)    # (m,)
    signs = jnp.unpackbits(g_bits, axis=1)[:, :d].astype(jnp.float32) * 2.0 - 1.0
    agg = (g_scale[:, None] * signs).sum(0) / n_eff
    # sign(0) := +1 to match the packed bits (error feedback must track the
    # value the wire actually carried)
    hat = jnp.mean(jnp.abs(flat)) * jnp.where(flat >= 0, 1.0, -1.0)
    return agg.reshape(tot.shape), hat.reshape(tot.shape)


def _split_pairs(pairs):
    is_pair = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda pr: pr[0], pairs, is_leaf=is_pair),
            jax.tree.map(lambda pr: pr[1], pairs, is_leaf=is_pair))


_is_selection = lambda x: isinstance(x, Selection)


def mesh_uplink(fed: FedConfig, comp: Optional[Compressor],
                ctx: ParallelContext, kernel_impl, rng, delta, my_err,
                my_mask, n_eff):
    """This device's delta shards → (aggregated update, next EF error).

    Resolves the aggregation strategy (:func:`mesh_agg_strategy`,
    DESIGN.md §3) — dense psum, compacted-Selection gather, or packed-sign
    gather — applies masked error feedback, and narrows the dense
    collective to ``fed.delta_dtype`` with EF tracking the narrowed value.

    On the sparse top-k strategy the selection happens ONCE per leaf
    (``fed.mesh_sparse_impl``: the fused Pallas kernel emits the compacted
    ``(vals, idx)`` block and the EF residual in one HBM pass; the jnp
    fallback is ``Compressor.select`` + an O(k) EF scatter — bit-identical
    selection either way), and the client-axis collective carries that
    Selection, never a dense hat."""
    if comp is None:
        return agg_dense(delta, my_mask, n_eff, ctx, fed.delta_dtype), my_err

    strategy = mesh_agg_strategy(fed)
    if strategy == "packed_sign":
        tot = jax.tree.map(lambda dd, ee: dd + ee, delta, my_err)
        agg, hat = _split_pairs(jax.tree.map(
            lambda t: packed_sign_leaf(t, my_mask, n_eff, ctx), tot))
        new_err = jax.tree.map(
            lambda t, h, eo: jnp.where(my_mask > 0, t - h, eo),
            tot, hat, my_err)
        return agg, new_err

    if strategy in ("sparse_topk", "sparse_topk_hier"):
        if resolve_mesh_sparse_impl(fed, kernel_impl) == "kernel":
            sels, new_err = kernel_impl.topk_select_tree(
                comp.ratio, delta, my_err, my_mask)
        else:
            sels, new_err = topk_select_tree(comp, delta, my_err, my_mask)
        # selection and EF are identical across tiers — only the collective
        # topology differs (flat gather vs member-gather + group partials)
        leaf_fn = (sparse_topk_hier_leaf if strategy == "sparse_topk_hier"
                   else sparse_topk_leaf)
        agg = jax.tree.map(
            lambda s, lf: leaf_fn(s, lf, n_eff, ctx),
            sels, delta, is_leaf=_is_selection)
        return agg, new_err

    if kernel_impl is not None:
        hat, new_err = kernel_impl.ef_compress_tree(comp, delta, my_err,
                                                    my_mask)
    else:
        hat, new_err = ef_compress_masked(comp, delta, my_err, my_mask,
                                          jax.random.fold_in(rng, 2))
    if fed.delta_dtype != "float32":
        # error feedback must track the value actually sent
        wd = jnp.dtype(fed.delta_dtype)
        hat_tx = jax.tree.map(
            lambda h: h.astype(wd).astype(jnp.float32), hat)
        new_err = jax.tree.map(
            lambda d, e, h: jnp.where(my_mask > 0, d + e - h, e),
            delta, my_err, hat_tx)
        hat = hat_tx
    return agg_dense(hat, my_mask, n_eff, ctx, fed.delta_dtype), new_err
