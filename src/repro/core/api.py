"""High-level facade: ``FederatedTrainer`` wires data, model/loss, the
paper's algorithm and checkpointing into a train() loop — the 10-line entry
point the examples and external users drive.

Two backends, selected by ``mesh``:
  * ``mesh=None``  — the pure simulation path (FedSim): arbitrary client
    count, exact paper semantics, single device.
  * ``mesh=...``   — the SPMD mesh path (shard_map fed_round): clients are
    mesh-axis indices with TP-sharded replicas.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import FedConfig, TrainConfig
from repro.core.mesh import (build_fed_round, fed_batch_defs,
                             fed_state_defs, init_fed_state,
                             mesh_metric_specs)
from repro.core.sim import FedSim
from repro.core.sampling import sample_clients
from repro.models import params as pdefs
from repro.sharding.rules import ParallelContext


@dataclass
class FederatedTrainer:
    fed: FedConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    # simulation backend
    loss_fn: Optional[Callable] = None          # (params, batch) -> (loss, aux)
    init_params: Optional[object] = None
    data: Optional[object] = None                # needs .round_batches(...)
    # mesh backend
    model: Optional[object] = None               # repro.models.Model
    mesh: Optional[object] = None
    lm_data: Optional[object] = None             # needs .mesh_batch(...)
    # wire mode (fed.wire=True): optional custom repro.comm.SimulatedNetwork
    network: Optional[object] = None

    def __post_init__(self):
        self.history: List[Dict] = []
        if self.mesh is None:
            assert self.loss_fn is not None and self.init_params is not None
            self._sim = FedSim(self.loss_fn, self.fed, network=self.network)
            self._state = self._sim.init(self.init_params)
        else:
            if self.network is not None:
                raise ValueError(
                    "network= is a simulation-backend (mesh=None) feature; "
                    "the mesh path reports measured wire_up_bytes but does "
                    "not simulate transport")
            if self.fed.async_buffer:
                raise ValueError(
                    "fed.async_buffer is a simulation-backend (mesh=None) "
                    "feature — the event-driven buffered engine drives "
                    "FedSim's transport simulation (DESIGN.md §11)")
            tp = dict(zip(self.mesh.axis_names,
                          self.mesh.devices.shape)).get("model", 1)
            assert self.model is not None and self.model.tp == tp
            sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            hierarchical = "data" not in self.fed.client_axes
            ctx = ParallelContext(
                # name the axis even at size 1: vma tracking needs the psum
                # to prove replication over a mesh axis that exists
                model_axis="model" if "model" in sizes else None, tp=tp,
                data_axis="data" if (hierarchical and "data" in sizes) else None,
                dp=sizes.get("data", 1) if hierarchical else 1,
                client_axes=self.fed.client_axes,
                num_clients=self.fed.num_clients,
                tp_collective=self.train.tp_collective)
            sdefs = fed_state_defs(self.model, self.fed)
            ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
            bdefs = fed_batch_defs(self.model, self.fed, self.train)
            bsp = jax.tree.map(lambda d: d.spec, bdefs, is_leaf=pdefs.is_def)
            rnd = build_fed_round(self.model, self.fed, self.train, ctx)
            # state buffers are donated: FedMeshState (params, opt moments,
            # per-client EF errors) updates in place round over round
            self._step = jax.jit(compat.shard_map(
                rnd, mesh=self.mesh, in_specs=(ssp, bsp, P()),
                out_specs=(ssp, mesh_metric_specs(self.fed))),
                donate_argnums=(0,))
            self._rnd, self._ssp, self._bsp = rnd, ssp, bsp
            self._scan_step = None
            self._state = init_fed_state(self.model, self.fed,
                                         jax.random.PRNGKey(self.train.seed))

    @property
    def params(self):
        return self._state.params

    def _mesh_scan_step(self):
        """Lazily build the scan-driven mesh step: R rounds of stacked
        batches/seeds scanned inside one shard_map (jit retraces per R)."""
        if self._scan_step is None:
            from repro.core.mesh import (build_fed_rounds_scan,
                                         scan_batch_specs)
            self._scan_step = jax.jit(compat.shard_map(
                build_fed_rounds_scan(self._rnd), mesh=self.mesh,
                in_specs=(self._ssp, scan_batch_specs(self._bsp), P(None)),
                out_specs=(self._ssp, mesh_metric_specs(self.fed,
                                                        scan=True))),
                donate_argnums=(0,))
        return self._scan_step

    def _stage_sim_rounds(self, rng, r0: int, count: int, batch_size: int):
        """Host-side staging for ``count`` rounds: the same rng stream and
        data order the per-round loop consumes, stacked with leading R."""
        n = self.fed.participating or self.fed.num_clients
        idxs, keys, batches = [], [], []
        for r in range(r0, r0 + count):
            rng, k1, k2 = jax.random.split(rng, 3)
            idx = np.asarray(sample_clients(k1, self.fed.num_clients, n))
            batches.append(self.data.round_batches(
                idx, r, self.fed.local_steps, batch_size))
            idxs.append(idx)
            keys.append(k2)
        stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)),
                               *batches)
        return rng, stacked, jnp.asarray(np.stack(idxs)), jnp.stack(keys)

    def run(self, rounds: Optional[int] = None, *, batch_size: int = 20,
            scan_rounds: int = 0,
            log: Optional[Callable[[str], None]] = print):
        """Train for ``rounds``. With ``scan_rounds=R > 1`` the driver
        stages R rounds of client indices and batches at a time and runs
        them as one on-device ``lax.scan`` (one dispatch + one metrics sync
        per R rounds, bit-identical history); otherwise one jitted call per
        round."""
        rounds = rounds or self.train.rounds
        rng = jax.random.PRNGKey(self.train.seed + 1)
        t0 = time.time()
        if self.mesh is None and self.fed.async_buffer:
            # the async buffered engine consumes ALL staged cohorts in one
            # run_rounds call (DESIGN.md §11) — its flush count need not
            # equal the staged cohort count, so the whole run is one chunk;
            # ``rounds`` then means dispatched cohorts, history rows are
            # flushes (max(·, 2) keeps the single-round case on the staged
            # path, which the engine requires)
            scan_rounds = max(rounds, 2)

        def record(met, r):
            rec = {k: float(v) for k, v in met.items()}
            rec["round"] = r
            self.history.append(rec)
            if log and (r % self.train.log_every == 0 or r == rounds - 1):
                log(f"round {r:4d}  loss {rec['loss']:8.4f}  "
                    f"({time.time() - t0:.1f}s)")

        if scan_rounds and scan_rounds > 1:
            r = 0
            while r < rounds:
                chunk = min(scan_rounds, rounds - r)
                if self.mesh is None:
                    rng, batches, idx, keys = self._stage_sim_rounds(
                        rng, r, chunk, batch_size)
                    self._state, mets = self._sim.run_rounds(
                        self._state, batches, idx, keys)
                else:
                    from repro.core.mesh import stage_mesh_rounds
                    batches, seeds = stage_mesh_rounds(
                        self.lm_data, r, chunk, self.fed.local_steps,
                        self.train.global_batch, self.train.seq_len)
                    self._state, stacked = self._mesh_scan_step()(
                        self._state, batches, seeds)
                    stacked = jax.device_get(stacked)
                    mets = [{k: v[i] for k, v in stacked.items()}
                            for i in range(chunk)]
                for i, met in enumerate(mets):
                    record(met, r + i)
                r += chunk
                ce = self.train.checkpoint_every
                if ce and any(rr % ce == 0 and rr > 0
                              for rr in range(r - chunk, r)):
                    # only chunk-boundary states exist under scan: snapshot
                    # once per chunk that crossed a checkpoint round
                    self.save(f"ckpt_round{r - 1}")
            return self.history

        for r in range(rounds):
            if self.mesh is None:
                rng, k1, k2 = jax.random.split(rng, 3)
                n = self.fed.participating or self.fed.num_clients
                idx = np.asarray(sample_clients(k1, self.fed.num_clients, n))
                raw = self.data.round_batches(idx, r, self.fed.local_steps,
                                              batch_size)
                self._state, met = self._sim.round(
                    self._state, jax.tree.map(jnp.asarray, raw),
                    jnp.asarray(idx), k2)
            else:
                raw = self.lm_data.mesh_batch(r, self.fed.local_steps,
                                              self.train.global_batch,
                                              self.train.seq_len)
                self._state, met = self._step(
                    self._state, {k: jnp.asarray(v) for k, v in raw.items()},
                    jnp.int32(r))
            record(met, r)
            if (self.train.checkpoint_every
                    and r % self.train.checkpoint_every == 0 and r > 0):
                self.save(f"ckpt_round{r}")
        return self.history

    def save(self, path: str):
        from repro.checkpoint import save_pytree
        save_pytree(path, jax.device_get(self._state._asdict()),
                    {"round": len(self.history), "algo": self.fed.algorithm})
