"""High-level facade: ``FederatedTrainer`` wires data, model/loss, the
paper's algorithm and checkpointing into a train() loop — the 10-line entry
point the examples and external users drive.

Two backends, selected by ``mesh``:
  * ``mesh=None``  — the pure simulation path (FedSim): arbitrary client
    count, exact paper semantics, single device.
  * ``mesh=...``   — the SPMD mesh path (shard_map fed_round): clients are
    mesh-axis indices with TP-sharded replicas.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import FedConfig, TrainConfig
from repro.core.rounds import (FedSim, build_fed_round, fed_batch_defs,
                               fed_state_defs, init_fed_state)
from repro.core.sampling import sample_clients
from repro.models import params as pdefs
from repro.sharding.rules import ParallelContext


@dataclass
class FederatedTrainer:
    fed: FedConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    # simulation backend
    loss_fn: Optional[Callable] = None          # (params, batch) -> (loss, aux)
    init_params: Optional[object] = None
    data: Optional[object] = None                # needs .round_batches(...)
    # mesh backend
    model: Optional[object] = None               # repro.models.Model
    mesh: Optional[object] = None
    lm_data: Optional[object] = None             # needs .mesh_batch(...)
    # wire mode (fed.wire=True): optional custom repro.comm.SimulatedNetwork
    network: Optional[object] = None

    def __post_init__(self):
        self.history: List[Dict] = []
        if self.mesh is None:
            assert self.loss_fn is not None and self.init_params is not None
            self._sim = FedSim(self.loss_fn, self.fed, network=self.network)
            self._state = self._sim.init(self.init_params)
        else:
            if self.network is not None:
                raise ValueError(
                    "network= is a simulation-backend (mesh=None) feature; "
                    "the mesh path reports measured wire_up_bytes but does "
                    "not simulate transport")
            tp = dict(zip(self.mesh.axis_names,
                          self.mesh.devices.shape)).get("model", 1)
            assert self.model is not None and self.model.tp == tp
            sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            hierarchical = "data" not in self.fed.client_axes
            ctx = ParallelContext(
                # name the axis even at size 1: vma tracking needs the psum
                # to prove replication over a mesh axis that exists
                model_axis="model" if "model" in sizes else None, tp=tp,
                data_axis="data" if (hierarchical and "data" in sizes) else None,
                dp=sizes.get("data", 1) if hierarchical else 1,
                client_axes=self.fed.client_axes,
                num_clients=self.fed.num_clients,
                tp_collective=self.train.tp_collective)
            sdefs = fed_state_defs(self.model, self.fed)
            ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
            bdefs = fed_batch_defs(self.model, self.fed, self.train)
            bsp = jax.tree.map(lambda d: d.spec, bdefs, is_leaf=pdefs.is_def)
            rnd = build_fed_round(self.model, self.fed, self.train, ctx)
            self._step = jax.jit(compat.shard_map(
                rnd, mesh=self.mesh, in_specs=(ssp, bsp, P()),
                out_specs=(ssp, {"loss": P(), "wire_up_bytes": P()})))
            self._state = init_fed_state(self.model, self.fed,
                                         jax.random.PRNGKey(self.train.seed))

    @property
    def params(self):
        return self._state.params

    def run(self, rounds: Optional[int] = None, *, batch_size: int = 20,
            log: Optional[Callable[[str], None]] = print):
        rounds = rounds or self.train.rounds
        rng = jax.random.PRNGKey(self.train.seed + 1)
        t0 = time.time()
        for r in range(rounds):
            if self.mesh is None:
                rng, k1, k2 = jax.random.split(rng, 3)
                n = self.fed.participating or self.fed.num_clients
                idx = np.asarray(sample_clients(k1, self.fed.num_clients, n))
                raw = self.data.round_batches(idx, r, self.fed.local_steps,
                                              batch_size)
                self._state, met = self._sim.round(
                    self._state, jax.tree.map(jnp.asarray, raw),
                    jnp.asarray(idx), k2)
            else:
                raw = self.lm_data.mesh_batch(r, self.fed.local_steps,
                                              self.train.global_batch,
                                              self.train.seq_len)
                self._state, met = self._step(
                    self._state, {k: jnp.asarray(v) for k, v in raw.items()},
                    jnp.int32(r))
            rec = {k: float(v) for k, v in met.items()}
            rec["round"] = r
            self.history.append(rec)
            if log and (r % self.train.log_every == 0 or r == rounds - 1):
                log(f"round {r:4d}  loss {rec['loss']:8.4f}  "
                    f"({time.time() - t0:.1f}s)")
            if (self.train.checkpoint_every
                    and r % self.train.checkpoint_every == 0 and r > 0):
                self.save(f"ckpt_round{r}")
        return self.history

    def save(self, path: str):
        from repro.checkpoint import save_pytree
        save_pytree(path, jax.device_get(self._state._asdict()),
                    {"round": len(self.history), "algo": self.fed.algorithm})
