"""The paper-faithful simulation backend (DESIGN.md §1).

``FedSim`` runs the paper's Algorithms 1 & 2 as pure-array simulation:
m clients (default 100), vmapped local updates (core/local.py rules),
*global-vector* compression exactly as the paper evaluates it. Runs on one
CPU device; powers the paper-faithful benchmarks and examples. The mesh
(production SPMD) backend lives in core/mesh.py; both compose the shared
EF/compress/wire stages from core/stages.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.flatten_util import ravel_pytree

from repro.comm.faults import (FaultConfig, FaultInjector, FaultPlan,
                               corrupt_dense, corrupt_selection,
                               validate_dense, validate_selection)
from repro.configs.base import FedConfig
from repro.core.compressors import Compressor, make_compressor
from repro.core.local import (hetero_step_counts, local_lr, make_local_update,
                              run_local_steps)
from repro.core.server_opt import (FUSED_INGEST_GROUPS_DETAIL,
                                   init_server_state, server_ingest,
                                   server_update)
from repro.core.stages import (client_uplink, client_uplink_sparse,
                               ef_update_sparse, gamma_diagnostic,
                               resolve_fused_ingest, server_aggregate_sparse,
                               server_aggregate_sparse_grouped,
                               server_aggregate_sparse_masked,
                               server_aggregate_sparse_weighted,
                               server_downlink)


class SimState(NamedTuple):
    params: object            # pytree
    opt: object               # ServerState over flat vector
    errors: jax.Array         # (m, d) per-client EF errors — or, with
    # fed.ef_store, the (n, d) participating-cohort rows gathered for the
    # current round while the full store lives host-side (DESIGN.md
    # §scale-out)
    server_error: jax.Array   # (d,) server-side EF error (two-way mode)
    x_client: jax.Array       # (d,) model as clients see it (two-way mode)
    # Host-side Python ints, exact at any scale: fp32 accumulation is only
    # exact below 2^24, which a single dense round at d=11.2M blows through
    # (n·32·d ≈ 3.6e8 bits), silently freezing cumulative-bits plots — and
    # keeping them off-device means the round needs no device→host sync.
    bits: int                 # cumulative one-way communicated bits
    round: int


class _CoreState(NamedTuple):
    """The device-resident slice of :class:`SimState` — the jit/scan carry.

    ``bits``/``round`` stay host-side (see SimState); everything here is
    donated to the round executable (``donate_argnums``) so the (m, d)
    error-feedback buffer and the optimizer state update in place instead
    of being copied every round."""
    params: object
    opt: object
    errors: jax.Array
    server_error: jax.Array
    x_client: jax.Array


class FedSim:
    """Federated simulation over an arbitrary ``loss_fn(params, batch)``.

    The local phase runs the configured :class:`~repro.core.local.LocalUpdate`
    rule (``fed.local_opt``: plain SGD, heavy-ball momentum, or proximal
    SGD) under the per-round LR schedule (``fed.eta_l_decay``) and
    heterogeneous per-client step counts (``fed.local_steps_min``).

    With ``fed.wire=True`` every client delta is serialized to packed bytes
    (repro.comm.wire), timed through a simulated network
    (repro.comm.transport — pass ``network`` to customize links), and
    decoded server-side; error feedback tracks the decoded value, so the
    simulation is exact w.r.t. what the wire actually carried. Round
    metrics then include measured ``wire_bytes`` and simulated
    ``round_time_s`` next to the analytic ``bits``.

    For the top-k family the uplink defaults to the select-once sparse
    fast path (``fed.sparse_uplink``, DESIGN.md §3): the compacted
    ``(vals, idx)`` selection flows from compressor to server aggregate —
    in wire mode via the codec's bit-identical ``roundtrip_selection``
    shortcut, so the round never re-runs ``lax.top_k`` or materializes a
    dense per-client hat.
    """

    def __init__(self, loss_fn: Callable, fed: FedConfig,
                 compressor: Optional[Compressor] = None,
                 network: Optional[object] = None):
        self.loss_fn = loss_fn
        self.fed = fed
        self.rule = make_local_update(fed)
        if compressor is None and fed.algorithm == "fedcams":
            compressor = make_compressor(fed.compressor, fed.compress_ratio,
                                         fed.wire_block)
        self.comp = compressor if fed.algorithm == "fedcams" else None
        # select-once sparse uplink (DESIGN.md §3): auto-on whenever the
        # compressor has a compacted (vals, idx) form, forced by the knob
        self.sparse = (self.comp is not None
                       and self.comp.select is not None
                       if fed.sparse_uplink is None
                       else bool(fed.sparse_uplink))
        if self.sparse and (self.comp is None or self.comp.select is None):
            raise ValueError(
                "sparse_uplink=True needs a compressor with a .select "
                "(topk/blocktopk family); this one has none")
        n_round = fed.participating or fed.num_clients
        if fed.client_chunk and 0 < fed.client_chunk < n_round \
                and n_round % fed.client_chunk:
            raise ValueError(
                f"client_chunk={fed.client_chunk} must divide the "
                f"per-round client count n={n_round} — a silent fallback "
                f"to the full (n, d) vmap would defeat the memory bound")
        if fed.agg_groups > 1:
            # two-level aggregation (DESIGN.md §scale-out): groups merge
            # compacted selections, so the flat dense paths can't run it
            if not self.sparse:
                raise ValueError(
                    "FedConfig.agg_groups > 1 needs the select-once sparse "
                    "(vals, idx) uplink — this config resolved the dense "
                    "reference path (no compacted selection to group-merge)")
            if fed.client_chunk and 0 < fed.client_chunk < n_round \
                    and fed.client_chunk != n_round // fed.agg_groups:
                raise ValueError(
                    f"client_chunk={fed.client_chunk} and agg_groups="
                    f"{fed.agg_groups} both set: the chunk must equal the "
                    f"group size n//g={n_round // fed.agg_groups} so each "
                    f"scan step is exactly one group's tier-1 merge")
        # one-pass fused server ingest (DESIGN.md §3): the (vals, idx)
        # selection goes straight into the m/v/v̂/x update — needs the
        # block-grouped selection layout (blocktopk), no dense-aggregate
        # consumer (γ diagnostic), and the unchunked sparse path (the
        # chunked round accumulates a dense running scatter instead)
        chunked = bool(fed.client_chunk) and 0 < fed.client_chunk < n_round
        # fault tolerance (DESIGN.md §robustness): resolve the effective
        # FaultConfig — a bare fed.deadline_s means "deadline cutoff, no
        # injected faults" — and build the host-side deterministic injector
        fcfg = fed.fault
        if fed.deadline_s > 0:
            fcfg = (FaultConfig(deadline_s=fed.deadline_s) if fcfg is None
                    else dataclasses.replace(fcfg, deadline_s=fed.deadline_s))
        self.faults = (FaultInjector(fcfg, fed.num_clients)
                       if fcfg is not None else None)
        eligible = (self.sparse and self.comp is not None
                    and self.comp.name.startswith("blocktopk")
                    and not fed.track_gamma and not chunked
                    and fed.agg_groups <= 1 and self.faults is None)
        from repro.kernels.bitpack import _resolve_interpret
        self._fused = resolve_fused_ingest(
            fed, eligible=eligible, have_kernel=True,
            compiled=not _resolve_interpret(None),
            detail="FedSim fuses only the unchunked sparse blocktopk "
                   "uplink with track_gamma=False (the γ diagnostic and "
                   "the client_chunk scan both consume a dense aggregate) "
                   "and no fault injection (the masked survivor aggregate "
                   "needs the unfused scatter path)"
                   + FUSED_INGEST_GROUPS_DETAIL)
        self._efs = None  # EFStore, created in init() once d is known
        self._round_fn = None
        self._scan_fn = None
        self._async_dispatch_fn = None
        self._async_flush_fn = None
        self._async = None
        self.codec = None
        self.network = None
        if network is not None and not fed.wire:
            raise ValueError(
                "a network was supplied but fed.wire is False — the "
                "transport simulation only runs in wire mode; set "
                "FedConfig(wire=True)")
        if fed.wire:
            from repro.comm import (CommLog, NetworkConfig, SimulatedNetwork,
                                    make_dense32_codec, make_wire_codec)
            name = fed.compressor if self.comp is not None else "dense32"
            self.codec = make_wire_codec(name, fed.compress_ratio,
                                         fed.wire_block, fed.wire_value_dtype,
                                         fed.wire_pack_impl)
            self._down_codec = (self.codec if fed.two_way
                                else make_dense32_codec())
            self.network = network or SimulatedNetwork(
                NetworkConfig(), fed.num_clients)
            self.comm_log = CommLog()
        if fed.async_buffer:
            # event-driven buffered rounds (DESIGN.md §11): the engine owns
            # the host-side event loop and drives the jitted dispatch/flush
            # steps below; config validation already pinned the supported
            # slice (wire + sparse uplink, no deadline/groups/ef_store)
            from repro.comm.async_engine import AsyncRoundEngine
            self._async = AsyncRoundEngine(self)

    def init(self, params) -> SimState:
        flat, self.unravel = ravel_pytree(params)
        d = flat.size
        self._d = d
        # the selection block layout (== the fused ingest / int8 quant
        # layout): block_layout clamps wire_block exactly like the
        # compressor will at select time
        from repro.core.compressors import block_layout
        bs, nb = block_layout(d, self.fed.wire_block)
        self._ingest_block = bs
        # valid index domain for server-side validation: selections carry
        # padded-tail indices in [d, nb*bs) that the scatter drops, so the
        # range check must accept the padded domain, not just [0, d)
        self._sel_domain = bs * nb
        m = self.fed.num_clients
        if self.fed.ef_store:
            # EF shard store (DESIGN.md §scale-out): the device buffer
            # holds only the participating cohort's rows; the full (m, d)
            # store lives host-side in lazily materialized numpy shards
            from repro.checkpoint.store import EFStore
            self._efs = EFStore(m, d)
            err_rows = self.fed.participating or m
        else:
            err_rows = m
        # copy the caller's params ONCE: the first round donates the state's
        # buffers, and consuming arrays the caller still owns would poison
        # any later use of their init pytree
        params = jax.tree.map(jnp.array, params)
        return SimState(
            params=params,
            opt=init_server_state(flat, self.fed.server_state_dtype,
                                  self._ingest_block),
            errors=jnp.zeros((err_rows, d), jnp.float32),
            server_error=jnp.zeros((d,), jnp.float32),
            x_client=flat,
            bits=0,
            round=0,
        )

    def _bits_per_round(self, n: int) -> int:
        """Analytic one-way bits for one round (exact host-side int)."""
        if self.comp is not None:
            return n * int(self.comp.bits_per_message(self._d))
        return n * 32 * self._d

    def _round_timing(self, idx_host, round_idx: int):
        """Simulated-network timing draw for one round (host-side numpy,
        deterministic in (seed, round)). Runs BEFORE the jitted round when
        faults are on — the deadline cutoff needs the per-client times to
        decide who is dead before aggregation."""
        if self.network is None:
            return None
        up = self.codec.nbytes(self._d)
        down = self._down_codec.nbytes(self._d)
        return self.network.round(idx_host, up, down, round_idx)

    def _record_timing(self, timing, finfo) -> dict:
        """Book one round's timing into the CommLog. With hierarchical
        aggregation the uplink is billed per tier: n client messages
        (tier 1, the codec bytes) plus g dense fp32 group partials pushed
        to the root (tier 2). A fault-tolerant round threads the
        injector's deadline-truncated wall-clock into the log (so
        ``sim_time_s == Σ round_time_s``) and bills uplink bytes only for
        the clients whose payload actually arrived — delivered-but-
        rejected clients still count (the wire carried their bytes); the
        full cohort's sends stay visible as the attempted diagnostic."""
        eff_time = delivered = None
        if finfo is not None:
            eff_time = finfo["round_time_s"]
            delivered = int(finfo["survivors"]) * self.codec.nbytes(self._d)
        g = self.fed.agg_groups
        tier2 = g * 4 * self._d if g > 1 else 0
        return self.comm_log.record(timing, tier2_bytes=tier2,
                                    round_time_s=eff_time,
                                    delivered_uplink_bytes=delivered)

    # -- one round ---------------------------------------------------------
    def round(self, state: SimState, client_batches, client_idx, rng, *,
              prefetch_idx=None):
        """client_batches: pytree with leading (n, K, ...); client_idx: (n,).

        The input state's device buffers are DONATED to the round
        executable (the (m, d) EF error buffer updates in place) — keep
        only the returned state.

        With ``fed.ef_store`` the round brackets the jitted body with the
        host-side EF shard store (DESIGN.md §scale-out): gather the
        cohort's rows to a dense (n, d) device block, run the round over
        row *positions*, scatter the updated rows back. ``prefetch_idx``
        (the NEXT round's client ids) starts the background gather for
        round r+1 right after this round is dispatched, so the host
        assembly overlaps the device compute."""
        if self._async is not None:
            raise ValueError(
                "fed.async_buffer routes training through the event-driven "
                "buffered engine, which consumes ALL staged cohorts in one "
                "call — use run_rounds(...) (FederatedTrainer.run stages "
                "this automatically)")
        if self._round_fn is None:
            self._round_fn = jax.jit(self._round_impl, donate_argnums=(0,))
        idx_host = np.asarray(client_idx)
        # transport runs between jitted rounds: byte counts are static per
        # codec, the timing draw is host-side numpy; the round index is the
        # host counter (no device sync). It runs BEFORE the round so the
        # fault injector can turn per-client times into a deadline mask.
        timing = self._round_timing(idx_host, state.round)
        fplan = finfo = None
        if self.faults is not None:
            fplan, finfo = self.faults.plan(idx_host, state.round, timing)
            fplan = FaultPlan(*(jnp.asarray(a) for a in fplan))
        if self._efs is not None:
            rows = self._efs.gather(idx_host)
            core = _CoreState(state.params, state.opt, jnp.asarray(rows),
                              state.server_error, state.x_client)
            # the round body indexes the (n, d) cohort block by position —
            # per-client rng/batches key off position already, so the math
            # per row is bit-identical to the resident (m, d) buffer
            pos_idx = jnp.arange(idx_host.size, dtype=jnp.int32)
            new_core, met = self._round_fn(core, client_batches, pos_idx,
                                           rng, jnp.int32(state.round),
                                           fplan)
            if prefetch_idx is not None:
                self._efs.prefetch(np.asarray(prefetch_idx))
            # np.asarray blocks on the round; the prefetch above overlaps it
            self._efs.scatter(idx_host, np.asarray(new_core.errors))
        else:
            new_core, met = self._round_fn(_CoreState(*state[:5]),
                                           client_batches, client_idx, rng,
                                           jnp.int32(state.round), fplan)
        bits = state.bits + self._bits_per_round(client_idx.shape[0])
        met = dict(met)
        met["bits"] = bits
        if timing is not None:
            met.update(self._record_timing(timing, finfo))
        if finfo is not None:
            met["crashed"] = finfo["crashed"]
            met["deadline_cut"] = finfo["deadline_cut"]
        return SimState(*new_core, bits=bits, round=state.round + 1), met

    # -- many rounds, one device program ------------------------------------
    def run_rounds(self, state: SimState, client_batches, client_idx, rngs):
        """Scan-driven multi-round execution: R rounds in one jitted
        ``lax.scan`` with donated carry — one dispatch and one host sync
        total, instead of R of each.

        ``client_batches``: pytree with leading (R, n, K, ...);
        ``client_idx``: (R, n); ``rngs``: PRNG keys with leading R.
        Returns ``(new_state, mets)`` with the same per-round metric dicts
        the :meth:`round` loop produces, bit-identical.

        With ``fed.ef_store`` the scan is replaced by a per-round loop:
        each round's cohort rows move host↔device around the jitted body,
        which a scan carry cannot express (the row set changes every
        round). The loop prefetches round r+1's rows while round r
        computes; metrics keep the exact :meth:`round` semantics."""
        R, n = int(client_idx.shape[0]), int(client_idx.shape[1])
        if self._async is not None:
            # async buffered engine (DESIGN.md §11): one metric dict per
            # FLUSH — ceil(deliveries / B) of them, not R
            return self._async.run(state, client_batches, client_idx, rngs)
        if self._efs is not None:
            st, mets = state, []
            for r in range(R):
                b_r = jax.tree.map(lambda x: x[r], client_batches)
                nxt = client_idx[r + 1] if r + 1 < R else None
                st, met = self.round(st, b_r, client_idx[r], rngs[r],
                                     prefetch_idx=nxt)
                mets.append(met)
            return st, mets
        if self._scan_fn is None:
            def scan_rounds(core, batches, idx, keys, rounds, fplans):
                def body(c, inp):
                    b, i, k, r, fp = inp
                    return self._round_impl(c, b, i, k, r, fp)
                return lax.scan(body, core,
                                (batches, idx, keys, rounds, fplans))
            self._scan_fn = jax.jit(scan_rounds, donate_argnums=(0,))
        idx_host = np.asarray(client_idx)
        # host-side transport + fault planning for all R rounds up front
        # (network.round is deterministic and idempotent per round index);
        # the plans stack into one (R, n)-leading FaultPlan the scan
        # consumes as xs — faults never force the loop path
        timings = [self._round_timing(idx_host[r], state.round + r)
                   for r in range(R)]
        fplans = None
        finfos = [None] * R
        if self.faults is not None:
            plans = []
            for r in range(R):
                p, finfos[r] = self.faults.plan(idx_host[r], state.round + r,
                                                timings[r])
                plans.append(p)
            fplans = FaultPlan(*(jnp.asarray(np.stack(leaf))
                                 for leaf in zip(*plans)))
        rounds_dev = state.round + jnp.arange(R, dtype=jnp.int32)
        new_core, stacked = self._scan_fn(_CoreState(*state[:5]),
                                          client_batches, client_idx, rngs,
                                          rounds_dev, fplans)
        stacked = jax.device_get(stacked)  # the single host sync
        bpr = self._bits_per_round(n)
        mets = []
        for r in range(R):
            met = {k: v[r] for k, v in stacked.items()}
            met["bits"] = state.bits + bpr * (r + 1)
            if timings[r] is not None:
                met.update(self._record_timing(timings[r], finfos[r]))
            if finfos[r] is not None:
                met["crashed"] = finfos[r]["crashed"]
                met["deadline_cut"] = finfos[r]["deadline_cut"]
            mets.append(met)
        new_state = SimState(*new_core, bits=state.bits + bpr * R,
                             round=state.round + R)
        return new_state, mets

    def _local_train(self, params, batches, eta_l, k_i=None):
        """K local steps of the configured rule for ONE client.
        batches: (K, ...); ``k_i`` (traced scalar) masks steps past this
        client's heterogeneous step count."""

        def grad_fn(p, b):
            (l, _), g = jax.value_and_grad(self.loss_fn, has_aux=True)(p, b)
            return l, g

        # unrolled (capped): K is static, and unrolling lets XLA fuse
        # across local steps instead of paying while-loop overhead — same
        # ops in the same order, numerics unchanged. The cap bounds program
        # size for large-K configs (the body is also nested inside the
        # run_rounds round scan).
        k = jax.tree.leaves(batches)[0].shape[0]
        return run_local_steps(self.rule, grad_fn, params, batches, eta_l,
                               k_i=k_i, unroll=min(k, 8))

    def _train_block(self, start, flat0, batches, rng, eta_l, k_blk=None):
        """Local training for a block of clients → ((c, d) deltas, losses)."""
        if k_blk is None:
            local, losses = jax.vmap(
                lambda b: self._local_train(start, b, eta_l))(batches)
        else:
            local, losses = jax.vmap(
                lambda b, ki: self._local_train(start, b, eta_l, ki))(
                    batches, k_blk)
        delta = jax.vmap(lambda p: ravel_pytree(p)[0])(local) - flat0[None, :]
        return delta, losses

    def _clients_block(self, start, flat0, batches, errs, pos, rng, eta_l,
                       k_blk=None):
        """Local training + uplink compression for a block of clients.

        ``batches``: (c, K, ...) pytree; ``errs``: (c, d) EF errors (ignored
        when no compressor); ``pos``: (c,) global positions in the round
        (the per-client RNG stream); ``k_blk``: (c,) heterogeneous step
        counts or None. Returns (hats, new_errs, delta, losses)."""
        delta, losses = self._train_block(start, flat0, batches, rng, eta_l,
                                          k_blk)
        hats, new_errs = client_uplink(self.comp, self.codec, flat0.size,
                                       rng, delta, errs, pos)
        return hats, new_errs, delta, losses

    def _sparse_uplink_block(self, errors, block_idx, start, flat0, batches,
                             pos, rng, eta_l, k_blk=None):
        """Train + select-once uplink for a block of clients, updating the
        (m, d) EF buffer in place (DESIGN.md §3): the buffer rows gain the
        deltas (they then hold the EF totals), the compacted selection is
        taken from those rows, and only the selected coordinates are
        rewritten with the post-wire residual — no dense per-client hat or
        error rebuild. Returns (errors, rx_vals, idx, tot_rows, delta,
        losses); ``tot_rows`` feeds the γ diagnostic and is dead code
        (eliminated by XLA) when ``track_gamma`` is off."""
        delta, losses = self._train_block(start, flat0, batches, rng, eta_l,
                                          k_blk)
        errors = errors.at[block_idx].add(delta)
        tot_rows = errors[block_idx]
        sel_vals, idx, rx_vals = client_uplink_sparse(
            self.comp, self.codec, flat0.size, rng, tot_rows, pos)
        errors = ef_update_sparse(errors, block_idx, idx, sel_vals, rx_vals)
        return errors, rx_vals, idx, tot_rows, delta, losses

    def _fault_round(self, core: _CoreState, client_batches, client_idx, rng,
                     round_idx, fplan: FaultPlan):
        """Fault-tolerant round (DESIGN.md §robustness): every client
        trains and uplinks as usual — the damage is in transit — then the
        server masks the aggregate down to validated survivors.

        Invariants:
          * the client books its EF residual against the CLEAN decoded
            value it sent; corruption happens after booking, so a client
            whose payload the server rejects (NACK) or who crashed gets
            its EF row rolled back to the stale pre-round value and
            repays the residual on rejoin (core/error_feedback.py);
          * validation runs BEFORE ingest: NaN/Inf and out-of-range
            indices zero the offender's contribution and drop it from
            the survivor count, so one poisoned payload cannot reach the
            FedAMS m/v/v̂ state;
          * with an all-ones survivor mask and corruption off this is
            bit-identical to :meth:`_round_impl` (regression-tested).
        """
        fed = self.fed
        fcfg = self.faults.cfg
        n = client_idx.shape[0]
        start = self.unravel(core.x_client)
        flat0 = core.x_client
        d = flat0.size
        pos = jnp.arange(n)
        eta_l = local_lr(fed, round_idx)
        k_all = hetero_step_counts(fed, rng, n)
        corrupting = fcfg.corrupt_prob > 0
        if self.sparse:
            old_rows = core.errors[client_idx]
            delta, losses = self._train_block(start, flat0, client_batches,
                                              rng, eta_l, k_all)
            tot = old_rows + delta
            sel_vals, sidx, rx_vals = client_uplink_sparse(
                self.comp, self.codec, d, rng, tot, pos)
            # client-side EF books the residual vs the CLEAN decoded value
            new_rows = jax.vmap(lambda t, i, r_: t.at[i].set(r_))(
                tot, sidx, sel_vals - rx_vals)
            rx, ridx = (corrupt_selection(rx_vals, sidx, fplan,
                                          fcfg.corrupt_mode)
                        if corrupting else (rx_vals, sidx))
            vvals, valid = validate_selection(rx, ridx, self._sel_domain,
                                              fcfg.max_update_norm)
            surv = fplan.survivors * valid
            errors = core.errors.at[client_idx].set(
                jnp.where(surv[:, None] > 0, new_rows, old_rows))
            agg = server_aggregate_sparse_masked(vvals, ridx, d, surv)
        else:
            errs = (core.errors[client_idx] if self.comp is not None
                    else jnp.zeros((n, 0), jnp.float32))
            hats, new_errs, delta, losses = self._clients_block(
                start, flat0, client_batches, errs, pos, rng, eta_l, k_all)
            rx = (corrupt_dense(hats, fplan, fcfg.corrupt_mode)
                  if corrupting else hats)
            truncated = (fplan.corrupt
                         if corrupting and fcfg.corrupt_mode == "truncate"
                         else None)
            vhats, valid = validate_dense(rx, fcfg.max_update_norm,
                                          truncated)
            surv = fplan.survivors * valid
            agg = jnp.sum(jnp.where(surv[:, None] > 0, vhats, 0.0),
                          axis=0) / jnp.maximum(jnp.sum(surv), 1.0)
            if self.comp is not None:
                errors = core.errors.at[client_idx].set(
                    jnp.where(surv[:, None] > 0, new_errs, errs))
            else:
                errors = core.errors
        loss = jnp.mean(losses)  # cohort mean — training happened on every
        # client whether or not its uplink survived
        xflat, _ = ravel_pytree(core.params)
        new_flat, opt = server_update(fed, core.opt, xflat, agg)
        x_client, server_error = server_downlink(
            fed, self.comp, self.codec, d, rng, new_flat, core.x_client,
            core.server_error)
        new_core = _CoreState(self.unravel(new_flat), opt, errors,
                              server_error, x_client)
        met = {"loss": loss, "gamma": jnp.zeros(()),
               "survivors": jnp.sum(surv),
               "rejected": jnp.sum(fplan.survivors * (1.0 - valid))}
        return new_core, met

    # -- async buffered engine steps (DESIGN.md §11) -------------------------
    def _ensure_async_fns(self):
        """Build the engine's two jitted steps on first use. Dispatch
        donates the (m, d) EF buffer; flush donates the server tuple —
        each updates in place across the host-side event loop."""
        if self._async_dispatch_fn is None:
            self._async_dispatch_fn = jax.jit(self._async_dispatch_impl,
                                              donate_argnums=(0,))
            self._async_flush_fn = jax.jit(self._async_flush_impl,
                                           donate_argnums=(0,))

    def _async_dispatch_impl(self, errors, x_client, client_batches,
                             client_idx, rng, round_idx,
                             fplan: Optional[FaultPlan] = None):
        """Client side of one async cohort: train + select-once sparse
        uplink against the CURRENT server model, EF booked at dispatch.

        The no-fault path is verbatim :meth:`_sparse_uplink_block` (the
        sync round's client half — bitwise the parity anchor); the fault
        path mirrors :meth:`_fault_round`'s client side: corruption
        happens after the EF books the clean residual, and a client whose
        payload will be rejected (or who crashed) keeps its stale EF row
        to repay on its next dispatch. Returns
        ``(errors, vals, idx, losses)`` — the payload the engine schedules
        for delivery."""
        fed = self.fed
        n = client_idx.shape[0]
        start = self.unravel(x_client)
        flat0 = x_client
        pos = jnp.arange(n)
        eta_l = local_lr(fed, round_idx)
        k_all = hetero_step_counts(fed, rng, n)
        if fplan is None:
            errors, rx_vals, sidx, _tot, _delta, losses = \
                self._sparse_uplink_block(errors, client_idx, start, flat0,
                                          client_batches, pos, rng, eta_l,
                                          k_all)
            return errors, rx_vals, sidx, losses
        fcfg = self.faults.cfg
        old_rows = errors[client_idx]
        delta, losses = self._train_block(start, flat0, client_batches, rng,
                                          eta_l, k_all)
        tot = old_rows + delta
        sel_vals, sidx, rx_vals = client_uplink_sparse(
            self.comp, self.codec, flat0.size, rng, tot, pos)
        new_rows = jax.vmap(lambda t, i, r_: t.at[i].set(r_))(
            tot, sidx, sel_vals - rx_vals)
        rx, ridx = (corrupt_selection(rx_vals, sidx, fplan,
                                      fcfg.corrupt_mode)
                    if fcfg.corrupt_prob > 0 else (rx_vals, sidx))
        # the validation verdict is deterministic in the payload, so the
        # dispatch-time verdict (EF rollback decision) and the flush-time
        # re-validation agree by construction
        _, valid = validate_selection(rx, ridx, self._sel_domain,
                                      fcfg.max_update_norm)
        surv = fplan.survivors * valid
        errors = errors.at[client_idx].set(
            jnp.where(surv[:, None] > 0, new_rows, old_rows))
        return errors, rx, ridx, losses

    def _async_flush_impl(self, core, vals, idx, w, fill, losses):
        """Server side of one buffered flush: ingest a fixed-shape (B, k)
        masked buffer through the validated weighted scatter (or the
        fused FedAMS ingest via an exact pre-scale).

        ``core``: (params, opt, server_error, x_client) — donated.
        ``w``: (B,) staleness weight × fill; ``fill``: (B,) 1.0 for
        occupied slots (a partial final flush leaves zeros). With faults
        armed the buffer re-validates before ingest (NaN/Inf or
        out-of-range payloads zero their weight). The fused path folds
        the weighted mean into the ingest's ``/B`` via
        ``scale = w·B/max(Σw, 1)`` — exactly 1.0 at unit weights, so the
        buffer==cohort anchor stays bitwise on the fused path too."""
        fed = self.fed
        params, opt, server_error, x_client = core
        d = self._d
        rejected = jnp.zeros(())
        if self.faults is not None:
            fcfg = self.faults.cfg
            vals, valid = validate_selection(vals, idx, self._sel_domain,
                                             fcfg.max_update_norm)
            rejected = jnp.sum(jnp.where(w > 0, 1.0 - valid, 0.0))
            w = w * valid
        # loss over ingested entries only (fill-masked mean)
        loss = jnp.sum(losses * fill) / jnp.maximum(jnp.sum(fill), 1.0)
        xflat, _ = ravel_pytree(params)
        if self._fused != "off":
            b = vals.shape[0]
            scale = w * (b / jnp.maximum(jnp.sum(w), 1.0))
            svals = jnp.where(w[:, None] > 0, vals, 0.0) * scale[:, None]
            new_flat, opt = server_ingest(fed, opt, xflat, svals, idx, b,
                                          block=self._ingest_block,
                                          impl=self._fused)
        else:
            agg = server_aggregate_sparse_weighted(vals, idx, d, w)
            new_flat, opt = server_update(fed, opt, xflat, agg)
        # two_way is rejected with async (configs.base), so the downlink
        # is the sync path's passthrough: clients see the exact new model
        met = {"loss": loss, "gamma": jnp.zeros(()), "rejected": rejected,
               "weight_sum": jnp.sum(w)}
        return (self.unravel(new_flat), opt, server_error, new_flat), met

    def _round_impl(self, core: _CoreState, client_batches, client_idx, rng,
                    round_idx, fplan: Optional[FaultPlan] = None):
        if fplan is not None:
            return self._fault_round(core, client_batches, client_idx, rng,
                                     round_idx, fplan)
        fed = self.fed
        n = client_idx.shape[0]
        start = self.unravel(core.x_client)  # what clients see (== params
        # unless two-way compression is on)
        flat0 = core.x_client
        d = flat0.size
        pos = jnp.arange(n)
        eta_l = local_lr(fed, round_idx)
        k_all = hetero_step_counts(fed, rng, n)  # None unless heterogeneous

        cc = fed.client_chunk
        if cc and 0 < cc < n and n % cc:  # trace-time n may differ from
            # the configured count __init__ validated against
            raise ValueError(
                f"client_chunk={cc} does not divide this round's client "
                f"count n={n} — refusing to silently fall back to the "
                f"full (n, d) vmap")
        if cc and 0 < cc < n:
            # client_chunk mode: scan the per-client train/compress/encode
            # pipeline over n/cc chunks, gathering/scattering each chunk's
            # EF slice inside the body and accumulating sums — peak
            # delta/hat/error working memory is (cc, d) instead of (n, d).
            # The sparse fast path accumulates each chunk's (vals, idx)
            # straight into the aggregate scatter, so the chunked round
            # never builds a dense hat either.
            shape_c = lambda x: x.reshape((n // cc, cc) + x.shape[1:])

            def body(carry, inp):
                b_c, i_c, p_c = inp
                errors, s_hat, s_tot, s_delta, s_loss = carry
                k_c = None if k_all is None else k_all[p_c]
                if self.sparse:
                    errors, vals, sidx, tot_c, delta, losses = \
                        self._sparse_uplink_block(
                            errors, i_c, start, flat0, b_c, p_c, rng,
                            eta_l, k_c)
                    if fed.agg_groups > 1:
                        # chunk == group (validated in __init__): merge
                        # this group's selections into a FRESH dense
                        # partial (tier 1) and hand the root the partial
                        # (tier 2 accumulate) — the group-partial
                        # association of the hierarchical mesh collective
                        s_hat = s_hat + jnp.zeros(d, jnp.float32).at[
                            sidx.reshape(-1)].add(vals.reshape(-1))
                    else:
                        s_hat = s_hat.at[sidx.reshape(-1)].add(
                            vals.reshape(-1))
                    s_tot = s_tot + jnp.sum(tot_c, axis=0)
                else:
                    e_c = (errors[i_c] if self.comp is not None
                           else jnp.zeros((cc, 0), jnp.float32))
                    hats, nerrs, delta, losses = self._clients_block(
                        start, flat0, b_c, e_c, p_c, rng, eta_l, k_c)
                    s_hat = s_hat + jnp.sum(hats, axis=0)
                    if self.comp is not None:
                        s_tot = s_tot + jnp.sum(delta + e_c, axis=0)
                        errors = errors.at[i_c].set(nerrs)
                s_delta = s_delta + jnp.sum(delta, axis=0)
                s_loss = s_loss + jnp.sum(losses)
                return (errors, s_hat, s_tot, s_delta, s_loss), None

            carry0 = (core.errors, jnp.zeros(d),
                      jnp.zeros(d if self.comp is not None else 0),
                      jnp.zeros(d), jnp.zeros(()))
            (errors, s_hat, s_tot, s_delta, s_loss), _ = lax.scan(
                body, carry0,
                (jax.tree.map(shape_c, client_batches),
                 shape_c(client_idx), shape_c(pos)))
            hats_mean, loss = s_hat / n, s_loss / n
            mean_tot, mean_delta = s_tot / n, s_delta / n
        elif self.sparse:
            errors, vals, sidx, tot_rows, delta, losses = \
                self._sparse_uplink_block(core.errors, client_idx, start,
                                          flat0, client_batches, pos, rng,
                                          eta_l, k_all)
            loss = jnp.mean(losses)
            if self._fused != "off":
                # one-pass fused ingest (DESIGN.md §3): the received
                # (vals, idx) selections go straight into the m/v/v̂/x
                # read-modify-write — no dense mean delta, no separate
                # server_update pass (bit-identical at fp32 state)
                xflat, _ = ravel_pytree(core.params)
                new_flat, opt = server_ingest(
                    fed, core.opt, xflat, vals, sidx, n,
                    block=self._ingest_block, impl=self._fused)
                x_client, server_error = server_downlink(
                    fed, self.comp, self.codec, d, rng, new_flat,
                    core.x_client, core.server_error)
                new_core = _CoreState(self.unravel(new_flat), opt, errors,
                                      server_error, x_client)
                return new_core, {"loss": loss, "gamma": jnp.zeros(())}
            hats_mean = (
                server_aggregate_sparse_grouped(vals, sidx, d, n,
                                                fed.agg_groups)
                if fed.agg_groups > 1
                else server_aggregate_sparse(vals, sidx, d, n))
            mean_tot = jnp.mean(tot_rows, axis=0)
            mean_delta = jnp.mean(delta, axis=0)
        else:
            errs = (core.errors[client_idx] if self.comp is not None
                    else jnp.zeros((n, 0), jnp.float32))
            hats, new_errs, delta, losses = self._clients_block(
                start, flat0, client_batches, errs, pos, rng, eta_l, k_all)
            hats_mean, loss = jnp.mean(hats, axis=0), jnp.mean(losses)
            if self.comp is not None:
                mean_tot = jnp.mean(delta + errs, axis=0)
                errors = core.errors.at[client_idx].set(new_errs)
            else:
                mean_tot = None
                errors = core.errors
            mean_delta = jnp.mean(delta, axis=0)

        agg = hats_mean
        # mean_tot/mean_delta feed only the diagnostic: with track_gamma
        # off, XLA dead-code-eliminates their (n, d) reductions entirely
        gamma = (gamma_diagnostic(self.comp, rng, mean_tot, agg, mean_delta)
                 if fed.track_gamma else jnp.zeros(()))

        # server update on the flat vector
        xflat, _ = ravel_pytree(core.params)
        new_flat, opt = server_update(fed, core.opt, xflat, agg)

        # beyond-paper: two-way (server->client) EF compression, appendix D
        x_client, server_error = server_downlink(
            fed, self.comp, self.codec, d, rng, new_flat, core.x_client,
            core.server_error)

        new_params = self.unravel(new_flat)
        new_core = _CoreState(new_params, opt, errors, server_error, x_client)
        return new_core, {"loss": loss, "gamma": gamma}
