"""Error-feedback compression (paper Algorithm 2, lines 12 and 14-16).

    Δ̂ = C(Δ + e)            (compress the delta plus the carried error)
    e' = Δ + e − Δ̂           (participating clients)
    e' = e                    (non-participating clients keep stale error)

Works on arbitrary pytrees: compression is applied per-leaf (on the mesh the
leaves are shards, which composes with the blockwise-top-k story — see
DESIGN.md). The telescoping identity  Σ_t Δ̂_t = Σ_t Δ_t + e_1 − e_{T+1}
is property-tested.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor


def zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def ef_compress(comp: Compressor, delta, error, rng: Optional[jax.Array] = None
                ) -> Tuple[object, object]:
    """Returns (delta_hat, new_error), both pytrees like ``delta``."""
    flat, treedef = jax.tree_util.tree_flatten(delta)
    eflat = jax.tree_util.tree_leaves(error)
    hats, errs = [], []
    for i, (d, e) in enumerate(zip(flat, eflat)):
        r = jax.random.fold_in(rng, i) if rng is not None else None
        tot = d + e
        hat = comp.compress(tot, r)
        hats.append(hat)
        errs.append(tot - hat)
    return (jax.tree_util.tree_unflatten(treedef, hats),
            jax.tree_util.tree_unflatten(treedef, errs))


def ef_compress_masked(comp: Compressor, delta, error, participating,
                       rng: Optional[jax.Array] = None):
    """Partial participation: ``participating`` is a scalar bool (0/1).

    Non-participating clients contribute zero to the aggregate and keep
    their stale error (paper lines 14-16)."""
    hat, new_err = ef_compress(comp, delta, error, rng)
    m = participating
    hats = jax.tree.map(lambda h: jnp.where(m, h, jnp.zeros_like(h)), hat)
    errs = jax.tree.map(lambda en, eo: jnp.where(m, en, eo), new_err, error)
    return hats, errs
