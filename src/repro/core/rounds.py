"""The federated round — the paper's Algorithms 1 & 2 — in two executions.

``FedSim``
    Pure-array simulation: m clients (default 100), vmapped local SGD,
    *global-vector* compression exactly as the paper evaluates it. Runs on
    one CPU device; powers the paper-faithful benchmarks and examples.

``build_fed_round``
    Production mesh execution (shard_map): each index of the client axes IS
    one client holding a tensor-parallel model replica; FedCAMS compression
    applies to the client-axis collective (dense psum or the beyond-paper
    sparse/packed aggregation — DESIGN.md §3). Per-client error-feedback
    state lives sharded on the client axes.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.flatten_util import ravel_pytree
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import FedConfig, TrainConfig
from repro.core.compressors import Compressor, make_compressor
from repro.core.error_feedback import ef_compress, ef_compress_masked
from repro.core.sampling import participation_mask
from repro.core.server_opt import ServerState, init_server_state, server_update
from repro.models import params as pdefs
from repro.sharding.rules import ParallelContext


# ===========================================================================
# Simulation path (paper-faithful, single device)
# ===========================================================================


class SimState(NamedTuple):
    params: object            # pytree
    opt: ServerState          # over flat vector
    errors: jax.Array         # (m, d) per-client EF errors
    server_error: jax.Array   # (d,) server-side EF error (two-way mode)
    x_client: jax.Array       # (d,) model as clients see it (two-way mode)
    # Host-side Python ints, exact at any scale: fp32 accumulation is only
    # exact below 2^24, which a single dense round at d=11.2M blows through
    # (n·32·d ≈ 3.6e8 bits), silently freezing cumulative-bits plots — and
    # keeping them off-device means the round needs no device→host sync.
    bits: int                 # cumulative one-way communicated bits
    round: int


class _CoreState(NamedTuple):
    """The device-resident slice of :class:`SimState` — the jit/scan carry.

    ``bits``/``round`` stay host-side (see SimState); everything here is
    donated to the round executable (``donate_argnums``) so the (m, d)
    error-feedback buffer and the optimizer state update in place instead
    of being copied every round."""
    params: object
    opt: ServerState
    errors: jax.Array
    server_error: jax.Array
    x_client: jax.Array


class FedSim:
    """Federated simulation over an arbitrary ``loss_fn(params, batch)``.

    With ``fed.wire=True`` every client delta is serialized to packed bytes
    (repro.comm.wire), timed through a simulated network
    (repro.comm.transport — pass ``network`` to customize links), and
    decoded server-side; error feedback tracks the decoded value, so the
    simulation is exact w.r.t. what the wire actually carried. Round
    metrics then include measured ``wire_bytes`` and simulated
    ``round_time_s`` next to the analytic ``bits``.
    """

    def __init__(self, loss_fn: Callable, fed: FedConfig,
                 compressor: Optional[Compressor] = None,
                 network: Optional[object] = None):
        self.loss_fn = loss_fn
        self.fed = fed
        if compressor is None and fed.algorithm == "fedcams":
            compressor = make_compressor(fed.compressor, fed.compress_ratio,
                                         fed.wire_block)
        self.comp = compressor if fed.algorithm == "fedcams" else None
        n_round = fed.participating or fed.num_clients
        if fed.client_chunk and 0 < fed.client_chunk < n_round \
                and n_round % fed.client_chunk:
            raise ValueError(
                f"client_chunk={fed.client_chunk} must divide the "
                f"per-round client count n={n_round} — a silent fallback "
                f"to the full (n, d) vmap would defeat the memory bound")
        self._round_fn = None
        self._scan_fn = None
        self.codec = None
        self.network = None
        if network is not None and not fed.wire:
            raise ValueError(
                "a network was supplied but fed.wire is False — the "
                "transport simulation only runs in wire mode; set "
                "FedConfig(wire=True)")
        if fed.wire:
            from repro.comm import (CommLog, NetworkConfig, SimulatedNetwork,
                                    make_dense32_codec, make_wire_codec)
            name = fed.compressor if self.comp is not None else "dense32"
            self.codec = make_wire_codec(name, fed.compress_ratio,
                                         fed.wire_block, fed.wire_value_dtype,
                                         fed.wire_pack_impl)
            self._down_codec = (self.codec if fed.two_way
                                else make_dense32_codec())
            self.network = network or SimulatedNetwork(
                NetworkConfig(), fed.num_clients)
            self.comm_log = CommLog()

    def init(self, params) -> SimState:
        flat, self.unravel = ravel_pytree(params)
        d = flat.size
        self._d = d
        m = self.fed.num_clients
        # copy the caller's params ONCE: the first round donates the state's
        # buffers, and consuming arrays the caller still owns would poison
        # any later use of their init pytree
        params = jax.tree.map(jnp.array, params)
        return SimState(
            params=params,
            opt=init_server_state(flat),
            errors=jnp.zeros((m, d), jnp.float32),
            server_error=jnp.zeros((d,), jnp.float32),
            x_client=flat,
            bits=0,
            round=0,
        )

    def _bits_per_round(self, n: int) -> int:
        """Analytic one-way bits for one round (exact host-side int)."""
        if self.comp is not None:
            return n * int(self.comp.bits_per_message(self._d))
        return n * 32 * self._d

    def _transport_met(self, idx_host, round_idx: int) -> dict:
        """Simulated-network timing for one round (host-side numpy)."""
        up = self.codec.nbytes(self._d)
        down = self._down_codec.nbytes(self._d)
        timing = self.network.round(idx_host, up, down, round_idx)
        return self.comm_log.record(timing)

    # -- one round ---------------------------------------------------------
    def round(self, state: SimState, client_batches, client_idx, rng):
        """client_batches: pytree with leading (n, K, ...); client_idx: (n,).

        The input state's device buffers are DONATED to the round
        executable (the (m, d) EF error buffer updates in place) — keep
        only the returned state."""
        if self._round_fn is None:
            self._round_fn = jax.jit(self._round_impl, donate_argnums=(0,))
        new_core, met = self._round_fn(_CoreState(*state[:5]), client_batches,
                                       client_idx, rng)
        bits = state.bits + self._bits_per_round(client_idx.shape[0])
        met = dict(met)
        met["bits"] = bits
        if self.network is not None:
            # transport runs between jitted rounds: byte counts are static
            # per codec, the timing draw is host-side numpy; the round
            # index is the host counter (no device sync)
            met.update(self._transport_met(np.asarray(client_idx),
                                           state.round))
        return SimState(*new_core, bits=bits, round=state.round + 1), met

    # -- many rounds, one device program ------------------------------------
    def run_rounds(self, state: SimState, client_batches, client_idx, rngs):
        """Scan-driven multi-round execution: R rounds in one jitted
        ``lax.scan`` with donated carry — one dispatch and one host sync
        total, instead of R of each.

        ``client_batches``: pytree with leading (R, n, K, ...);
        ``client_idx``: (R, n); ``rngs``: PRNG keys with leading R.
        Returns ``(new_state, mets)`` with the same per-round metric dicts
        the :meth:`round` loop produces, bit-identical."""
        R, n = int(client_idx.shape[0]), int(client_idx.shape[1])
        if self._scan_fn is None:
            def scan_rounds(core, batches, idx, keys):
                def body(c, inp):
                    b, i, k = inp
                    return self._round_impl(c, b, i, k)
                return lax.scan(body, core, (batches, idx, keys))
            self._scan_fn = jax.jit(scan_rounds, donate_argnums=(0,))
        idx_host = np.asarray(client_idx)
        new_core, stacked = self._scan_fn(_CoreState(*state[:5]),
                                          client_batches, client_idx, rngs)
        stacked = jax.device_get(stacked)  # the single host sync
        bpr = self._bits_per_round(n)
        mets = []
        for r in range(R):
            met = {k: v[r] for k, v in stacked.items()}
            met["bits"] = state.bits + bpr * (r + 1)
            if self.network is not None:
                met.update(self._transport_met(idx_host[r], state.round + r))
            mets.append(met)
        new_state = SimState(*new_core, bits=state.bits + bpr * R,
                             round=state.round + R)
        return new_state, mets

    def _local_train(self, params, batches):
        """K local SGD steps for ONE client. batches: (K, ...)."""
        eta_l = self.fed.eta_l

        def step(p, b):
            (l, _), g = jax.value_and_grad(self.loss_fn, has_aux=True)(p, b)
            p = jax.tree.map(lambda x, gg: x - eta_l * gg, p, g)
            return p, l

        # unrolled (capped): K is static, and unrolling lets XLA fuse
        # across local steps instead of paying while-loop overhead — same
        # ops in the same order, numerics unchanged. The cap bounds program
        # size for large-K configs (the body is also nested inside the
        # run_rounds round scan).
        k = jax.tree.leaves(batches)[0].shape[0]
        local, losses = lax.scan(step, params, batches, unroll=min(k, 8))
        return local, jnp.mean(losses)

    def _clients_block(self, start, flat0, batches, errs, pos, rng):
        """Local training + compression for a block of clients.

        ``batches``: (c, K, ...) pytree; ``errs``: (c, d) EF errors (ignored
        when no compressor); ``pos``: (c,) global positions in the round
        (the per-client RNG stream). Returns (hats, new_errs, delta,
        losses)."""
        d = flat0.size
        local, losses = jax.vmap(lambda b: self._local_train(start, b))(batches)
        delta = jax.vmap(lambda p: ravel_pytree(p)[0])(local) - flat0[None, :]
        if self.comp is not None:
            if self.codec is not None:
                # wire mode: the delta really goes through encode->decode;
                # EF tracks the *decoded* value, so narrowed wire value
                # dtypes stay exact in the error-feedback sense
                def one(dd, ee, i):
                    tot = dd + ee
                    hat = self.codec.decode(self.codec.encode(tot), d)
                    return hat, tot - hat
            else:
                def one(dd, ee, i):
                    return ef_compress(self.comp, dd, ee,
                                       jax.random.fold_in(rng, i))
            hats, new_errs = jax.vmap(one)(delta, errs, pos)
        else:
            if self.codec is not None:  # uncompressed algo, dense32 wire
                hats = jax.vmap(
                    lambda t: self.codec.decode(self.codec.encode(t), d)
                )(delta)
            else:
                hats = delta
            new_errs = errs
        return hats, new_errs, delta, losses

    def _round_impl(self, core: _CoreState, client_batches, client_idx, rng):
        fed = self.fed
        n = client_idx.shape[0]
        start = self.unravel(core.x_client)  # what clients see (== params
        # unless two-way compression is on)
        flat0 = core.x_client
        d = flat0.size
        pos = jnp.arange(n)

        cc = fed.client_chunk
        if cc and 0 < cc < n and n % cc:  # trace-time n may differ from
            # the configured count __init__ validated against
            raise ValueError(
                f"client_chunk={cc} does not divide this round's client "
                f"count n={n} — refusing to silently fall back to the "
                f"full (n, d) vmap")
        if cc and 0 < cc < n:
            # client_chunk mode: scan the per-client train/compress/encode
            # pipeline over n/cc chunks, gathering/scattering each chunk's
            # EF slice inside the body and accumulating sums — peak
            # delta/hat/error working memory is (cc, d) instead of (n, d)
            shape_c = lambda x: x.reshape((n // cc, cc) + x.shape[1:])

            def body(carry, inp):
                b_c, i_c, p_c = inp
                errors, s_hat, s_tot, s_delta, s_loss = carry
                e_c = (errors[i_c] if self.comp is not None
                       else jnp.zeros((cc, 0), jnp.float32))
                hats, nerrs, delta, losses = self._clients_block(
                    start, flat0, b_c, e_c, p_c, rng)
                s_hat = s_hat + jnp.sum(hats, axis=0)
                s_delta = s_delta + jnp.sum(delta, axis=0)
                s_loss = s_loss + jnp.sum(losses)
                if self.comp is not None:
                    s_tot = s_tot + jnp.sum(delta + e_c, axis=0)
                    errors = errors.at[i_c].set(nerrs)
                return (errors, s_hat, s_tot, s_delta, s_loss), None

            carry0 = (core.errors, jnp.zeros(d),
                      jnp.zeros(d if self.comp is not None else 0),
                      jnp.zeros(d), jnp.zeros(()))
            (errors, s_hat, s_tot, s_delta, s_loss), _ = lax.scan(
                body, carry0,
                (jax.tree.map(shape_c, client_batches),
                 shape_c(client_idx), shape_c(pos)))
            hats_mean, loss = s_hat / n, s_loss / n
            mean_tot, mean_delta = s_tot / n, s_delta / n
        else:
            errs = (core.errors[client_idx] if self.comp is not None
                    else jnp.zeros((n, 0), jnp.float32))
            hats, new_errs, delta, losses = self._clients_block(
                start, flat0, client_batches, errs, pos, rng)
            hats_mean, loss = jnp.mean(hats, axis=0), jnp.mean(losses)
            if self.comp is not None:
                mean_tot = jnp.mean(delta + errs, axis=0)
                errors = core.errors.at[client_idx].set(new_errs)
            else:
                errors = core.errors
            mean_delta = jnp.mean(delta, axis=0)

        gamma = jnp.zeros(())
        agg = hats_mean
        if self.comp is not None:
            # Assumption 4.17 diagnostic (paper Fig. 6):
            #   gamma = ||C(mean(Δ+e)) − mean(C(Δ+e))|| / ||mean(Δ)||
            c_of_mean = self.comp.compress(mean_tot,
                                           jax.random.fold_in(rng, 999983))
            gamma = (jnp.linalg.norm(c_of_mean - agg)
                     / jnp.maximum(jnp.linalg.norm(mean_delta), 1e-12))

        # server update on the flat vector
        xflat, _ = ravel_pytree(core.params)
        new_flat, opt = server_update(fed, core.opt, xflat, agg)

        # beyond-paper: two-way (server->client) EF compression, appendix D
        if fed.two_way and self.comp is not None:
            upd = new_flat - core.x_client
            tot = upd + core.server_error
            if self.codec is not None:  # downlink exercises the codec too
                hat = self.codec.decode(self.codec.encode(tot), d)
            else:
                hat = self.comp.compress(tot, jax.random.fold_in(rng, 10**6))
            server_error = tot - hat
            x_client = core.x_client + hat
        else:
            server_error = core.server_error
            x_client = new_flat

        new_params = self.unravel(new_flat)
        new_core = _CoreState(new_params, opt, errors, server_error, x_client)
        return new_core, {"loss": loss, "gamma": gamma}


# ===========================================================================
# Mesh path (production)
# ===========================================================================


class FedMeshState(NamedTuple):
    params: object     # pytree, TP-sharded
    m: object          # server momentum    (fp32, like params)
    v: object          # server variance
    vhat: object       # max-stabilized variance
    errors: object     # per-client EF errors: leading client dim
    round: jax.Array


def client_batch_axes(fed: FedConfig) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    axes = tuple(fed.client_axes)
    if "data" not in axes:
        axes = axes + ("data",)
    return axes


def state_shard_axes(fed: FedConfig):
    """Mesh axes the server state shards over (ZeRO mode)."""
    return tuple(fed.client_axes) if fed.client_axes else ("data",)


def state_shard_dim(dref: pdefs.ParamDef, shards: int):
    """First dim of a leaf that can host the server-state shard, or None."""
    if shards <= 1:
        return None
    for i, (size, sp) in enumerate(zip(dref.shape, dref.spec)):
        if sp is None and size % shards == 0 and size >= shards:
            return i
    return None


def fed_state_defs(model, fed: FedConfig):
    """ParamDef tree for the full federated state (GLOBAL shapes)."""
    par = model.defs()

    def fp32(dref: pdefs.ParamDef) -> pdefs.ParamDef:
        import dataclasses
        return dataclasses.replace(dref, dtype="float32")

    def opt_leaf(dref: pdefs.ParamDef) -> pdefs.ParamDef:
        import dataclasses
        dref = fp32(dref)
        if fed.shard_server_state:
            sd = state_shard_dim(dref, fed.state_shards)
            if sd is not None:
                axes = state_shard_axes(fed)
                spec = list(dref.spec)
                spec[sd] = axes[0] if len(axes) == 1 else tuple(axes)
                dref = dataclasses.replace(dref, spec=P(*spec))
        return dref

    def client_stacked(dref: pdefs.ParamDef) -> pdefs.ParamDef:
        import dataclasses
        if not fed.client_axes:
            ax = None
        elif len(fed.client_axes) == 1:
            ax = fed.client_axes[0]
        else:
            ax = tuple(fed.client_axes)
        return dataclasses.replace(
            dref, shape=(fed.num_clients,) + tuple(dref.shape),
            spec=P(ax, *dref.spec), dtype="float32")

    opt = jax.tree.map(opt_leaf, par, is_leaf=pdefs.is_def)
    errors = jax.tree.map(client_stacked, par, is_leaf=pdefs.is_def)
    return FedMeshState(
        params=par, m=opt, v=opt, vhat=opt, errors=errors,
        round=pdefs.ParamDef((), P(), dtype="int32", init="zeros"))


def init_fed_state(model, fed: FedConfig, rng) -> FedMeshState:
    defs = fed_state_defs(model, fed)
    params = pdefs.init_params(defs.params, rng)
    zeros = lambda t: jax.tree.map(
        lambda d: jnp.zeros(d.shape, jnp.dtype(d.dtype)), t, is_leaf=pdefs.is_def)
    return FedMeshState(params=params, m=zeros(defs.m), v=zeros(defs.v),
                        vhat=zeros(defs.vhat), errors=zeros(defs.errors),
                        round=jnp.zeros((), jnp.int32))


# -- aggregation strategies --------------------------------------------------


def _agg_dense(hat_tree, my_mask, n_eff, ctx: ParallelContext,
               wire_dtype: str = "float32"):
    """Paper-faithful: dense psum over the client axes. ``wire_dtype``
    narrows the collective payload (bf16 halves client-axis bytes; the
    caller keeps error feedback exact by tracking the narrowed value)."""
    wd = jnp.dtype(wire_dtype)
    contrib = jax.tree.map(
        lambda h: jnp.where(my_mask > 0, h, 0.0).astype(wd), hat_tree)
    return jax.tree.map(
        lambda c: ctx.psum_clients(c).astype(jnp.float32) / n_eff, contrib)


def _sparse_topk_leaf(tot, ratio, my_mask, n_eff, ctx: ParallelContext,
                      block: int = 2048):
    """Beyond-paper: all_gather (values, indices) of the local blockwise
    top-k and scatter-add — the wire carries ~2k words instead of d, and the
    selection is bit-identical to the dense blocktopk path (same
    ``block_layout``). Returns (aggregated dense leaf, this client's dense
    hat for error feedback)."""
    from repro.core.compressors import block_layout
    flat = tot.reshape(-1)
    d = flat.size
    bs, nb = block_layout(d, block)
    pad = nb * bs - d
    xb = jnp.pad(flat, (0, pad)).reshape(nb, bs)
    k = max(1, int(round(ratio * bs)))
    _, idx = lax.top_k(jnp.abs(xb), k)                       # (nb, k)
    vals = jnp.take_along_axis(xb, idx, axis=1)
    gidx = (idx + (jnp.arange(nb) * bs)[:, None]).reshape(-1)
    kept = vals.reshape(-1)
    hat = jnp.zeros(nb * bs, flat.dtype).at[gidx].set(kept)[:d]
    masked = kept * (my_mask > 0)
    g_vals = ctx.all_gather_clients(masked[None], axis=0).reshape(-1)
    g_idx = ctx.all_gather_clients(gidx[None], axis=0).reshape(-1)
    # NB: fresh zeros (replicated vma) — zeros_like(varying) would taint the
    # aggregate as client-varying.
    zeros = jnp.zeros(nb * bs, flat.dtype)
    agg = (zeros.at[g_idx].add(g_vals) / n_eff)[:d]
    return agg.reshape(tot.shape), hat.reshape(tot.shape)


def _packed_sign_leaf(tot, my_mask, n_eff, ctx: ParallelContext):
    """Beyond-paper: scaled-sign with the sign bits packed 8->1 in uint8 for
    the client-axis all_gather (1 bit/coordinate on the wire)."""
    flat = tot.reshape(-1)
    d = flat.size
    scale = jnp.mean(jnp.abs(flat)) * (my_mask > 0)
    bits = jnp.packbits((flat >= 0).astype(jnp.uint8))
    g_bits = ctx.all_gather_clients(bits[None], axis=0)      # (m, d/8)
    g_scale = ctx.all_gather_clients(scale[None], axis=0)    # (m,)
    signs = jnp.unpackbits(g_bits, axis=1)[:, :d].astype(jnp.float32) * 2.0 - 1.0
    agg = (g_scale[:, None] * signs).sum(0) / n_eff
    # sign(0) := +1 to match the packed bits (error feedback must track the
    # value the wire actually carried)
    hat = jnp.mean(jnp.abs(flat)) * jnp.where(flat >= 0, 1.0, -1.0)
    return agg.reshape(tot.shape), hat.reshape(tot.shape)


def _sharded_server_update(fed: FedConfig, st: ServerState, params, agg,
                           model, ctx: ParallelContext):
    """ZeRO-style server step: each index along the state-shard axes owns a
    slice of (m, v, v̂); it updates its slice of x from its slice of the
    aggregate and the refreshed params are all-gathered back (invariant vma).
    Leaves too small to shard stay replicated and update normally."""
    axes = state_shard_axes(fed)
    shards = fed.state_shards
    # linear index along the shard axes
    idx = 0
    for ax in axes:
        idx = idx * lax.psum(1, ax) + lax.axis_index(ax)

    defs = model.defs()
    dims = jax.tree.map(lambda d: state_shard_dim(d, shards), defs,
                        is_leaf=pdefs.is_def)

    def take(leaf, sd):
        if sd is None:
            return leaf
        chunk = leaf.shape[sd] // shards
        return lax.dynamic_slice_in_dim(leaf, idx * chunk, chunk, axis=sd)

    p_sh = jax.tree.map(take, params, dims)
    agg_sh = jax.tree.map(take, agg, dims)
    st_sh = ServerState(m=st.m, v=st.v, vhat=st.vhat, t=st.t)  # already shards
    newp_sh, new_st = server_update(fed, st_sh, p_sh, agg_sh)

    def gather(newp, oldp, sd):
        if sd is None:
            return newp
        from repro.sharding.rules import ParallelContext as _PC
        x = newp
        for ax in axes:
            try:
                from jax._src.lax.parallel import all_gather_invariant
                x = all_gather_invariant(x, ax, axis=sd, tiled=True)
            except ImportError:  # pragma: no cover
                x = lax.all_gather(x, ax, axis=sd, tiled=True)
        return x.astype(oldp.dtype)

    new_params = jax.tree.map(gather, newp_sh, params, dims)
    return new_params, new_st


# -- the round ---------------------------------------------------------------


def mesh_wire_bytes(fed: FedConfig, delta_tree, block: int = 2048,
                    tp: int = 1) -> int:
    """Measured per-client contribution bytes for one mesh round's
    client-axis collective, sized to what the aggregation paths *actually*
    move per leaf: ``_sparse_topk_leaf`` gathers uint32 global indices +
    fp32 values for the kept coordinates (8 bytes each), ``_packed_sign_leaf``
    gathers the 8→1 packed sign bits + one fp32 scale, and the dense psum
    carries ``delta_dtype`` words. (Collectives carry no per-message header,
    unlike the comm.wire point-to-point codecs.)

    ``delta_tree`` holds this device's *local* shards; every one of the
    client's ``tp`` model-parallel devices pushes its own payload into the
    client-axis collective (model-replicated leaves included — each device
    sends its copy), so the client's wire traffic is the local total × tp.
    """
    from repro.core.compressors import block_layout
    sparse = fed.algorithm == "fedcams" and fed.aggregation == "sparse"
    total = 0
    for leaf in jax.tree.leaves(delta_tree):
        dl = int(np.prod(leaf.shape))
        if sparse and fed.compressor in ("topk", "blocktopk"):
            bs, nb = block_layout(dl, block)
            kb = max(1, int(round(fed.compress_ratio * bs)))
            total += nb * kb * 8          # uint32 index + fp32 value
        elif sparse and fed.compressor == "packedsign":
            total += (dl + 7) // 8 + 4    # 1 bit/coord + fp32 scale
        else:
            total += dl * jnp.dtype(fed.delta_dtype).itemsize
    return total * max(tp, 1)


def build_fed_round(model, fed: FedConfig, train: TrainConfig,
                    ctx: ParallelContext, *, chunk: int = 2048,
                    kernel_impl: Optional[object] = None):
    """Returns fed_round(state, batch, seed) — the per-device SPMD function
    (wrap in shard_map + jit via launch.train / launch.dryrun)."""
    # On the mesh, deltas are per-leaf shards (billions of elements for the
    # large archs): global top-k is ill-defined and lax.top_k overflows int32
    # indices, so "topk" means the blockwise TPU kernel semantics here
    # (DESIGN.md §3; contraction bound unchanged). Exact global top-k lives
    # in the FedSim simulation path.
    comp_name = "blocktopk" if fed.compressor == "topk" else fed.compressor
    comp = (make_compressor(comp_name, fed.compress_ratio)
            if fed.algorithm == "fedcams" else None)
    m_clients = fed.num_clients
    n_part = fed.participating or m_clients
    hierarchical = "data" not in fed.client_axes  # within-client DP on "data"

    def local_loss(p, b):
        return model.loss(p, b, ctx, remat_policy=train.remat_policy,
                          chunk=chunk)

    # TP gradient correctness relies on shard_map's varying-manual-axes
    # tracking (check_vma=True at every launch-site shard_map): jax then
    # transposes the forward psums correctly, so gradients of both sharded
    # and replicated parameters are exact — verified against the tp=1 model
    # in tests/test_sharding.py.

    def fed_round(state: FedMeshState, batch, seed):
        params = state.params

        # Clients must diverge during local training: mark the replicated
        # global params as VARYING over the client axes (lax.pvary — a
        # vma-type cast, no communication) so shard_map's vma autodiff does
        # NOT sum gradients across clients. In hierarchical mode the "data"
        # axis stays replicated, so the automatic gradient psum over "data"
        # implements within-client data parallelism (we rescale sum->mean).
        def _pvary(t):
            if not fed.client_axes:
                return t
            return jax.tree.map(
                lambda x: compat.pvary(x, tuple(fed.client_axes)), t)

        local0 = _pvary(params)

        def step(p, b):
            (l, _), g = jax.value_and_grad(local_loss, has_aux=True)(p, b)
            if hierarchical:
                g = jax.tree.map(lambda x: x / ctx.dp, g)
            p = jax.tree.map(lambda x, gg: x - fed.eta_l * gg.astype(x.dtype),
                             p, g)
            return p, l

        local, losses = lax.scan(step, local0, batch)
        delta = jax.tree.map(lambda a, b_: (a - b_).astype(jnp.float32),
                             local, local0)

        # participation (shared randomness -> identical mask on every device)
        rng = jax.random.fold_in(jax.random.PRNGKey(0), seed)
        mask = participation_mask(jax.random.fold_in(rng, 1), m_clients, n_part)
        my_mask = mask[ctx.client_index()]
        n_eff = float(n_part)

        my_err = jax.tree.map(lambda e: e[0], state.errors)  # local client slice
        if comp is not None:
            if fed.aggregation == "sparse" and fed.compressor in ("topk", "blocktopk"):
                tot = jax.tree.map(lambda dd, ee: dd + ee, delta, my_err)
                pairs = jax.tree.map(
                    lambda t: _sparse_topk_leaf(t, fed.compress_ratio, my_mask,
                                                n_eff, ctx), tot)
                agg = jax.tree.map(lambda pr: pr[0], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
                hat = jax.tree.map(lambda pr: pr[1], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
                new_err = jax.tree.map(
                    lambda t, h, eo: jnp.where(my_mask > 0, t - h, eo),
                    tot, hat, my_err)
            elif fed.aggregation == "sparse" and fed.compressor == "packedsign":
                tot = jax.tree.map(lambda dd, ee: dd + ee, delta, my_err)
                pairs = jax.tree.map(
                    lambda t: _packed_sign_leaf(t, my_mask, n_eff, ctx), tot)
                agg = jax.tree.map(lambda pr: pr[0], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
                hat = jax.tree.map(lambda pr: pr[1], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
                new_err = jax.tree.map(
                    lambda t, h, eo: jnp.where(my_mask > 0, t - h, eo),
                    tot, hat, my_err)
            else:
                if kernel_impl is not None:
                    hat, new_err = kernel_impl.ef_compress_tree(
                        comp, delta, my_err, my_mask)
                else:
                    hat, new_err = ef_compress_masked(
                        comp, delta, my_err, my_mask,
                        jax.random.fold_in(rng, 2))
                if fed.delta_dtype != "float32":
                    # error feedback must track the value actually sent
                    wd = jnp.dtype(fed.delta_dtype)
                    hat_tx = jax.tree.map(
                        lambda h: h.astype(wd).astype(jnp.float32), hat)
                    new_err = jax.tree.map(
                        lambda d, e, h: jnp.where(my_mask > 0, d + e - h, e),
                        delta, my_err, hat_tx)
                    hat = hat_tx
                agg = _agg_dense(hat, my_mask, n_eff, ctx, fed.delta_dtype)
        else:
            new_err = my_err
            agg = _agg_dense(delta, my_mask, n_eff, ctx, fed.delta_dtype)

        # server update (replicated elementwise math on sharded leaves)
        st = ServerState(m=state.m, v=state.v, vhat=state.vhat, t=state.round)
        if kernel_impl is not None and fed.algorithm in ("fedams", "fedcams"):
            new_params, new_st = kernel_impl.fedams_update_tree(fed, st, params, agg)
        elif fed.shard_server_state and fed.state_shards > 1:
            new_params, new_st = _sharded_server_update(fed, st, params, agg,
                                                        model, ctx)
        else:
            new_params, new_st = server_update(fed, st, params, agg)

        errors = jax.tree.map(lambda e, ne: e.at[0].set(ne),
                              state.errors, new_err)
        loss = ctx.pmean_clients(jnp.mean(losses))
        if hierarchical:
            loss = ctx.pmean_data(loss)
        new_state = FedMeshState(params=new_params, m=new_st.m, v=new_st.v,
                                 vhat=new_st.vhat, errors=errors,
                                 round=new_st.t)
        # measured uplink bytes this round (trace-time constant, replicated);
        # same key/semantics as FedSim wire mode's per-round uplink metric.
        # All m client-axis devices feed the collective — non-participants
        # contribute masked zeros that still occupy wire — so the factor is
        # m, not n_part.
        wire = jnp.float32(
            m_clients * mesh_wire_bytes(fed, delta, tp=ctx.tp))
        return new_state, {"loss": loss, "wire_up_bytes": wire}

    return fed_round


def build_fed_rounds_scan(fed_round):
    """Lift a per-round mesh body to the scan-driven multi-round body:
    ``(state, batches[R], seeds[R]) -> (state, stacked metrics)``. Shared by
    core.api.FederatedTrainer and launch.train so the scan step exists in
    exactly one place (wrap in shard_map + jit with ``donate_argnums=(0,)``
    at the call site)."""

    def rounds_fn(state, batches, seeds):
        def body(st, inp):
            b, s = inp
            return fed_round(st, b, s)
        return lax.scan(body, state, (batches, seeds))

    return rounds_fn


def scan_batch_specs(batch_specs):
    """Per-round batch PartitionSpecs -> stacked (R, ...) specs."""
    return jax.tree.map(lambda s: P(None, *tuple(s)), batch_specs)


def stage_mesh_rounds(lm_data, r0: int, count: int, local_steps: int,
                      global_batch: int, seq_len: int):
    """Host-side staging for ``count`` mesh rounds: stacked (R, ...) batch
    dict + (R,) int32 seeds for :func:`build_fed_rounds_scan` (shared by
    core.api and launch.train)."""
    raws = [lm_data.mesh_batch(r, local_steps, global_batch, seq_len)
            for r in range(r0, r0 + count)]
    batch = {k: jnp.asarray(np.stack([b[k] for b in raws]))
             for k in raws[0]}
    return batch, jnp.arange(r0, r0 + count, dtype=jnp.int32)


def fed_batch_defs(model, fed: FedConfig, train: TrainConfig):
    """GLOBAL batch defs with client-axis sharding, leading K dim."""
    b = model.train_batch_defs(train.global_batch, train.seq_len)
    axes = client_batch_axes(fed)
    ax = axes[0] if len(axes) == 1 else tuple(axes)

    def stack_k(d: pdefs.ParamDef):
        import dataclasses
        spec = list(d.spec)
        spec[0] = ax  # batch dim over client (+data) axes
        return dataclasses.replace(
            d, shape=(fed.local_steps,) + tuple(d.shape), spec=P(None, *spec))

    return jax.tree.map(stack_k, b, is_leaf=pdefs.is_def)
