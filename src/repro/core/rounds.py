"""Compatibility façade over the layered round engine (DESIGN.md §1, §8).

The former rounds monolith is split into four layers; this module re-exports
the public (and historically-imported) names so existing imports keep
working. New code should import from the layer modules directly:

* ``core/local.py``  — pluggable local-update rules (sgd/sgdm/prox), the
  per-round local LR schedule, heterogeneous per-client step counts.
* ``core/stages.py`` — the shared EF→compress→wire stages and the mesh
  aggregation strategies.
* ``core/sim.py``    — ``FedSim``, the paper-faithful simulation backend.
* ``core/mesh.py``   — ``build_fed_round`` and the production SPMD backend.
"""
from repro.core.local import (LocalUpdate, hetero_step_counts,  # noqa: F401
                              local_lr, make_local_update, run_local_steps)
from repro.core.mesh import (FedMeshState, _sharded_server_update,  # noqa: F401
                             build_fed_round, build_fed_rounds_scan,
                             client_batch_axes, fed_batch_defs,
                             fed_state_defs, init_fed_state, leaf_tier2_bytes,
                             leaf_wire_bytes, mesh_wire_bytes,
                             mesh_wire_bytes_tiers, scan_batch_specs,
                             stage_mesh_rounds, state_shard_axes,
                             state_shard_dim)
from repro.core.sim import FedSim, SimState, _CoreState  # noqa: F401
from repro.core.stages import (agg_dense, client_uplink,  # noqa: F401
                               client_uplink_sparse, gamma_diagnostic,
                               mesh_agg_strategy, mesh_uplink,
                               packed_sign_leaf, resolve_mesh_sparse_impl,
                               select_tree, server_aggregate_sparse,
                               server_aggregate_sparse_grouped,
                               server_downlink, sparse_topk_hier_leaf,
                               sparse_topk_leaf, topk_select_tree)

# pre-split private aliases, kept for callers that reached into the monolith
_agg_dense = agg_dense
_sparse_topk_leaf = sparse_topk_leaf
_packed_sign_leaf = packed_sign_leaf
