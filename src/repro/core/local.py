"""Pluggable local-update rules — how a client turns K gradients into its
delta (DESIGN.md §8).

The paper's convergence story (Theorems 4.19/4.22) only needs the local
phase to produce a bounded-drift delta; the *rule* that produces it is a
first-class axis (Reddi et al. 2020 vary server adaptivity against plain
local SGD; Wu et al. 2023 add local momentum/variance reduction). Both
round backends (core/sim.py, core/mesh.py) consume the same abstraction:

    rule = make_local_update(fed)
    carry = rule.init_carry(params)                    # per-client, per-round
    params, carry = rule.step(params, carry, grads, eta_l, anchor)

``anchor`` is the round-start model (the proximal reference point). Rules:

    sgd   : x ← x − η_l·g                  (paper Algorithm 1; bit-identical
                                            to the pre-split hardcoded step)
    sgdm  : u ← β·u + g;  x ← x − η_l·u    (heavy-ball local momentum)
    prox  : x ← x − η_l·(g + μ·(x − x₀))   (FedProx proximal regularization)

Scenario knobs that modulate the local phase live here too:

* :func:`local_lr` — the per-round local LR schedule
  (``FedConfig.eta_l_decay``); returns the plain Python float when the
  schedule is off so the unscheduled round stays bit-identical.
* :func:`hetero_step_counts` — heterogeneous per-client step counts
  (``FedConfig.local_steps_min``): client i runs K_i ~ U{min..K} steps,
  realized by masking inside the scanned step (static trace shape).
* :func:`run_local_steps` — the shared K-step ``lax.scan`` driver over a
  backend-specific ``grad_fn``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import FedConfig

#: rng salt for the heterogeneous-K draw — distinct from the compression
#: (per-client index), gamma (999983) and two-way (10**6) folds of the
#: shared round rng.
_HETERO_SALT = 7321991


class LocalUpdate(NamedTuple):
    """A local optimizer rule: per-client state plus one-step transition.

    ``init_carry(params) -> carry`` allocates the rule's per-client state at
    round start (``()`` for stateless rules — adds nothing to the scan
    carry). ``step(params, carry, grads, eta_l, anchor) -> (params, carry)``
    applies one local step; ``grads`` arrive pre-scaled/pre-cast by the
    backend so the rule is pure pytree math shared by sim and mesh."""
    name: str
    init_carry: Callable
    step: Callable


def make_local_update(fed: FedConfig) -> LocalUpdate:
    """Build the configured local rule (``FedConfig.local_opt``)."""
    if fed.local_opt == "sgd":

        def init_carry(params):
            return ()

        def step(p, c, g, eta_l, anchor):
            return jax.tree.map(lambda x, gg: x - eta_l * gg, p, g), ()

    elif fed.local_opt == "sgdm":
        beta = fed.local_momentum

        def init_carry(params):
            return jax.tree.map(jnp.zeros_like, params)

        def step(p, u, g, eta_l, anchor):
            u = jax.tree.map(lambda uu, gg: beta * uu + gg, u, g)
            return jax.tree.map(lambda x, uu: x - eta_l * uu, p, u), u

    elif fed.local_opt == "prox":
        mu = fed.prox_mu

        def init_carry(params):
            return ()

        def step(p, c, g, eta_l, anchor):
            new = jax.tree.map(
                lambda x, gg, x0: x - eta_l * (gg + mu * (x - x0)),
                p, g, anchor)
            return new, ()

    else:  # unreachable: FedConfig validates local_opt at construction
        raise ValueError(f"unknown local_opt {fed.local_opt!r}")
    return LocalUpdate(fed.local_opt, init_carry, step)


def local_lr(fed: FedConfig, round_idx):
    """η_l for this round: ``eta_l · eta_l_decay^t``.

    Returns the plain Python float when the schedule is off
    (``eta_l_decay == 1.0``) — the multiply then stays weak-typed exactly
    as the pre-schedule round traced it, preserving bit-identity."""
    if fed.eta_l_decay == 1.0:
        return fed.eta_l
    decay = jnp.float32(fed.eta_l_decay)
    return fed.eta_l * jnp.power(decay, jnp.asarray(round_idx, jnp.float32))


def hetero_step_counts(fed: FedConfig, rng, count: int):
    """(count,) int32 per-client step counts K_i ~ U{local_steps_min..K},
    or ``None`` when heterogeneity is off (``local_steps_min == 0``).

    Drawn from the shared round rng, so every device (mesh) / the loop and
    scan drivers (sim) agree on each client's K_i."""
    if not fed.local_steps_min:
        return None
    return jax.random.randint(
        jax.random.fold_in(rng, _HETERO_SALT), (count,),
        fed.local_steps_min, fed.local_steps + 1, dtype=jnp.int32)


def run_local_steps(rule: LocalUpdate, grad_fn: Callable, params, batches,
                    eta_l, k_i: Optional[jax.Array] = None,
                    unroll: int = 1):
    """Scan K local steps of ``rule`` over ``batches`` (leading dim K).

    ``grad_fn(params, batch) -> (loss, grads)`` is the backend-specific
    gradient: the sim passes plain ``value_and_grad``; the mesh folds in
    its hierarchical data-parallel rescale and param-dtype cast. ``k_i``
    (traced scalar) masks steps ``t >= k_i`` to no-ops — params, carry and
    loss freeze — so heterogeneous per-client step counts keep a static
    trace shape. Returns ``(local_params, mean_loss)`` where the mean is
    over the steps actually executed."""
    anchor = params
    carry0 = rule.init_carry(params)
    if k_i is None:

        def step(pc, b):
            p, c = pc
            l, g = grad_fn(p, b)
            p, c = rule.step(p, c, g, eta_l, anchor)
            return (p, c), l

        (local, _), losses = lax.scan(step, (params, carry0), batches,
                                      unroll=unroll)
        return local, jnp.mean(losses)

    k = jax.tree.leaves(batches)[0].shape[0]

    def step(pc, inp):
        t, b = inp
        p, c = pc
        l, g = grad_fn(p, b)
        pn, cn = rule.step(p, c, g, eta_l, anchor)
        active = t < k_i
        p = jax.tree.map(lambda old, new: jnp.where(active, new, old), p, pn)
        c = jax.tree.map(lambda old, new: jnp.where(active, new, old), c, cn)
        return (p, c), jnp.where(active, l, 0.0)

    (local, _), losses = lax.scan(
        step, (params, carry0), (jnp.arange(k), batches), unroll=unroll)
    return local, jnp.sum(losses) / jnp.maximum(k_i, 1).astype(losses.dtype)
