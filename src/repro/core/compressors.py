"""Biased compressors C: R^d -> R^d (paper §4.2, Assumption 4.14).

All compressors return a *dense* tensor of the same shape (the compressed
message is a sparse/low-bit encoding of it; ``bits_per_message`` accounts for
the wire format, matching the paper's Table 1). ``q_bound`` gives the
contraction constant of Assumption 4.14, property-tested in
``tests/test_compressors.py``.

``blocktopk`` is the TPU-native variant (DESIGN.md §3): exact top-k' inside
fixed-size blocks. Per block ‖C(x_b)−x_b‖² ≤ (1−k'/B)‖x_b‖², so the global
contraction bound q = sqrt(1−r) is preserved.

Sparse-friendly compressors (the top-k family) additionally expose
``select(x) -> Selection``: the same selection as ``compress`` but as a
compacted ``(vals, idx)`` pair instead of a dense scatter — the
representation the sparse uplink fast path (DESIGN.md §3) keeps alive from
client to server aggregate. ``selection_to_dense(select(x), d) ==
compress(x)`` bit-for-bit is property-tested.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax


class Selection(NamedTuple):
    """A compacted top-k selection of a flat length-d vector.

    ``vals[j]`` is the kept value at flat position ``idx[j]``. For blockwise
    compressors the pairs are grouped per block in block order (``(nb, kb)``
    flattened row-major) and ``idx`` may point into the zero-padded tail of
    the last block (``idx >= d``); those entries carry value 0.0 and are
    dropped by :func:`selection_to_dense` (JAX scatter drops out-of-bounds
    updates), mirroring the dense path's pad-and-slice."""

    vals: jax.Array   # (k,) float32 kept values
    idx: jax.Array    # (k,) int32 flat positions (padded domain for blocks)


def selection_to_dense(sel: Selection, d: int) -> jnp.ndarray:
    """Dense length-``d`` vector carrying the selection (the compressor's
    ``compress`` output, reconstructed from the sparse representation)."""
    return jnp.zeros(d, jnp.float32).at[sel.idx].set(sel.vals)


@dataclass(frozen=True)
class Compressor:
    name: str
    compress: Callable                      # (x, rng=None) -> x_hat (dense)
    bits_per_message: Callable              # d -> wire bits
    q_bound: Callable                       # (x,) -> q (Assumption 4.14)
    ratio: float = 1.0
    # (x, rng=None) -> Selection; None for compressors whose messages are
    # not (value, index) pairs (sign/int8/identity)
    select: Optional[Callable] = None


def _topk_flat(x, k):
    flat = x.reshape(-1)
    vals, idx = lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(x.shape)


def _argmax_select(xb):
    """Exact top-1 per row of ``xb`` as (vals, idx) — bit-identical to
    ``lax.top_k(|xb|, 1)`` (both keep the lowest index on ties) but a plain
    reduction instead of a sort-based top-k, which is the difference between
    the sparse fast path and the dense baseline at extreme ratios."""
    iidx = jnp.argmax(jnp.abs(xb), axis=-1)
    vals = jnp.take_along_axis(xb, iidx[..., None], axis=-1)[..., 0]
    return vals, iidx.astype(jnp.int32)


def make_topk(ratio: float) -> Compressor:
    def k_of(d: int) -> int:
        return max(1, int(round(ratio * d)))

    def compress(x, rng=None):
        return _topk_flat(x, k_of(x.size))

    def select(x, rng=None):
        flat = x.reshape(-1)
        k = k_of(flat.size)
        if k == 1:
            vals, idx = _argmax_select(flat[None])
            return Selection(vals=vals, idx=idx)
        _, idx = lax.top_k(jnp.abs(flat), k)
        return Selection(vals=flat[idx], idx=idx.astype(jnp.int32))

    return Compressor(
        name=f"topk_{ratio:g}",
        compress=compress,
        # value + index per kept coordinate (paper footnote 8: "roughly double")
        bits_per_message=lambda d: 64 * max(1, int(round(ratio * d))),
        q_bound=lambda x: math.sqrt(max(1.0 - ratio, 0.0)),
        ratio=ratio,
        select=select,
    )


def block_layout(d: int, block: int):
    """Shared block layout for the jnp and Pallas blockwise top-k paths:
    block size is a multiple of 128 (TPU lane width), capped at ``block``."""
    bs = min(block, ((d + 127) // 128) * 128)
    nb = -(-d // bs)
    return bs, nb


def make_blocktopk(ratio: float, block: int = 2048) -> Compressor:
    def compress(x, rng=None):
        flat = x.reshape(-1)
        d = flat.size
        bs, nb = block_layout(d, block)
        pad = nb * bs - d
        xb = jnp.pad(flat, (0, pad)).reshape(nb, bs)
        k = max(1, int(round(ratio * bs)))
        vals, idx = lax.top_k(jnp.abs(xb), k)
        kept = jnp.take_along_axis(xb, idx, axis=1)
        out = jnp.zeros_like(xb).at[
            jnp.arange(nb)[:, None], idx].set(kept)
        return out.reshape(-1)[:d].reshape(x.shape)

    def select(x, rng=None):
        flat = x.reshape(-1)
        d = flat.size
        bs, nb = block_layout(d, block)
        xb = jnp.pad(flat, (0, nb * bs - d)).reshape(nb, bs)
        k = max(1, int(round(ratio * bs)))
        if k == 1:
            kept, idx = _argmax_select(xb)           # (nb,), (nb,)
            kept, idx = kept[:, None], idx[:, None]
        else:
            _, idx = lax.top_k(jnp.abs(xb), k)       # (nb, k)
            kept = jnp.take_along_axis(xb, idx, axis=1)
        gidx = idx.astype(jnp.int32) + (jnp.arange(nb, dtype=jnp.int32)
                                        * bs)[:, None]
        return Selection(vals=kept.reshape(-1), idx=gidx.reshape(-1))

    return Compressor(
        name=f"blocktopk_{ratio:g}",
        compress=compress,
        bits_per_message=lambda d: 64 * max(1, int(round(ratio * d))),
        q_bound=lambda x: math.sqrt(max(1.0 - ratio, 0.0)),
        ratio=ratio,
        select=select,
    )


def make_sign() -> Compressor:
    def compress(x, rng=None):
        scale = jnp.mean(jnp.abs(x))            # ||x||_1 / d
        # sign(0) := +1 — the convention a 1-bit wire format can actually
        # carry (comm.wire sign codec, rounds._packed_sign_leaf); keeps
        # decode(encode(x)) == compress(x) bit-exact including exact zeros.
        return scale * jnp.where(x >= 0, 1.0, -1.0)

    def q_bound(x):
        x = jnp.asarray(x, jnp.float32).reshape(-1)
        l1 = jnp.sum(jnp.abs(x))
        l2sq = jnp.sum(x * x)
        d = x.size
        return float(jnp.sqrt(jnp.maximum(1.0 - l1 * l1 / (d * jnp.maximum(l2sq, 1e-30)), 0.0)))

    return Compressor(
        name="sign",
        compress=compress,
        bits_per_message=lambda d: 32 + d,       # Table 1
        q_bound=q_bound,
    )


def make_randk(ratio: float) -> Compressor:
    def compress(x, rng=None):
        assert rng is not None, "randk needs an rng"
        flat = x.reshape(-1)
        k = max(1, int(round(ratio * flat.size)))
        idx = jax.random.permutation(rng, flat.size)[:k]
        out = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return out.reshape(x.shape)

    return Compressor(
        name=f"randk_{ratio:g}",
        compress=compress,
        bits_per_message=lambda d: 64 * max(1, int(round(ratio * d))),
        q_bound=lambda x: 1.0,   # only contractive in expectation
        ratio=ratio,
    )


def make_int8() -> Compressor:
    def compress(x, rng=None):
        scale = jnp.max(jnp.abs(x)) / 127.0
        scale = jnp.maximum(scale, 1e-30)
        return jnp.round(x / scale) * scale

    return Compressor(
        name="int8",
        compress=compress,
        bits_per_message=lambda d: 32 + 8 * d,
        q_bound=lambda x: 1.0 / 127.0 * math.sqrt(1.0),  # loose: q <= dmax/127·√d/‖x‖
    )


def make_identity() -> Compressor:
    return Compressor(
        name="none",
        compress=lambda x, rng=None: x,
        bits_per_message=lambda d: 32 * d,
        q_bound=lambda x: 0.0,
    )


def make_compressor(name: str, ratio: float = 1 / 64, block: int = 2048) -> Compressor:
    if name in ("none", "identity"):
        return make_identity()
    if name == "topk":
        return make_topk(ratio)
    if name == "blocktopk":
        return make_blocktopk(ratio, block)
    if name in ("sign", "packedsign"):
        c = make_sign()
        if name == "packedsign":
            # identical numerics; packed int8 wire format (DESIGN.md §3)
            return Compressor(name="packedsign", compress=c.compress,
                              bits_per_message=c.bits_per_message,
                              q_bound=c.q_bound)
        return c
    if name == "randk":
        return make_randk(ratio)
    if name == "int8":
        return make_int8()
    raise ValueError(f"unknown compressor {name!r}")
