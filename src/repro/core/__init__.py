"""The paper's contribution: FedAMS / FedCAMS and their substrate.

Public surface: compressors, error feedback, local-update rules, server
optimizers, the shared round stages, and the two round backends (core/sim.py
FedSim simulation + core/mesh.py build_fed_round mesh SPMD)."""
from repro.core.api import FederatedTrainer  # noqa: F401
from repro.core.compressors import (Compressor, Selection,  # noqa: F401
                                    make_compressor, selection_to_dense)
from repro.core.error_feedback import ef_compress, ef_compress_masked  # noqa: F401
from repro.core.local import LocalUpdate, make_local_update  # noqa: F401
from repro.core.mesh import (FedMeshState, build_fed_round,  # noqa: F401
                             fed_batch_defs, fed_state_defs, init_fed_state,
                             mesh_wire_bytes)
from repro.core.sampling import participation_mask, sample_clients  # noqa: F401
from repro.core.server_opt import (ServerState, init_server_state,  # noqa: F401
                                   server_update)
from repro.core.sim import FedSim, SimState  # noqa: F401
