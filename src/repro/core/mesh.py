"""The production mesh backend (DESIGN.md §1, §3, §5).

``build_fed_round`` returns the per-device SPMD round body (shard_map):
each index of the client axes IS one client holding a tensor-parallel
model replica; FedCAMS compression applies to the client-axis collective
(dense psum or the beyond-paper sparse/packed aggregation — DESIGN.md §3).
Per-client error-feedback state lives sharded on the client axes. The
local phase runs the configured core/local.py rule; the uplink composes
the shared core/stages.py mesh stages. The paper-faithful simulation
backend lives in core/sim.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm.faults import (FaultPlan, corrupt_selection,
                               mesh_corruption_plan, mesh_fault_mask)
from repro.configs.base import FedConfig, TrainConfig
from repro.core.compressors import Selection, block_layout, make_compressor
from repro.core.local import (hetero_step_counts, local_lr, make_local_update,
                              run_local_steps)
from repro.core.sampling import participation_mask
from repro.core.server_opt import (ServerState, server_ingest_tree,
                                   server_update)
from repro.core.stages import (mesh_agg_strategy, mesh_uplink,
                               resolve_fused_ingest,
                               resolve_mesh_sparse_impl,
                               sparse_topk_leaf_validated, topk_select_tree)
from repro.models import params as pdefs
from repro.sharding.rules import ParallelContext


class FedMeshState(NamedTuple):
    params: object     # pytree, TP-sharded
    m: object          # server momentum    (fp32, like params)
    v: object          # server variance
    vhat: object       # max-stabilized variance
    errors: object     # per-client EF errors: leading client dim
    round: jax.Array


def client_batch_axes(fed: FedConfig) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    axes = tuple(fed.client_axes)
    if "data" not in axes:
        axes = axes + ("data",)
    return axes


def state_shard_axes(fed: FedConfig):
    """Mesh axes the server state shards over (ZeRO mode)."""
    return tuple(fed.client_axes) if fed.client_axes else ("data",)


def state_shard_dim(dref: pdefs.ParamDef, shards: int):
    """First dim of a leaf that can host the server-state shard, or None."""
    if shards <= 1:
        return None
    for i, (size, sp) in enumerate(zip(dref.shape, dref.spec)):
        if sp is None and size % shards == 0 and size >= shards:
            return i
    return None


def fed_state_defs(model, fed: FedConfig):
    """ParamDef tree for the full federated state (GLOBAL shapes)."""
    par = model.defs()

    def fp32(dref: pdefs.ParamDef) -> pdefs.ParamDef:
        import dataclasses
        return dataclasses.replace(dref, dtype="float32")

    def opt_leaf(dref: pdefs.ParamDef) -> pdefs.ParamDef:
        import dataclasses
        dref = fp32(dref)
        if fed.shard_server_state:
            sd = state_shard_dim(dref, fed.state_shards)
            if sd is not None:
                axes = state_shard_axes(fed)
                spec = list(dref.spec)
                spec[sd] = axes[0] if len(axes) == 1 else tuple(axes)
                dref = dataclasses.replace(dref, spec=P(*spec))
        return dref

    def client_stacked(dref: pdefs.ParamDef) -> pdefs.ParamDef:
        import dataclasses
        if not fed.client_axes:
            ax = None
        elif len(fed.client_axes) == 1:
            ax = fed.client_axes[0]
        else:
            ax = tuple(fed.client_axes)
        return dataclasses.replace(
            dref, shape=(fed.num_clients,) + tuple(dref.shape),
            spec=P(ax, *dref.spec), dtype="float32")

    opt = jax.tree.map(opt_leaf, par, is_leaf=pdefs.is_def)
    # second-moment storage dtype (m always stays fp32): bf16 halves the
    # v/v̂ HBM residency; int8-blockscale has no mesh ParamDef form
    if fed.server_state_dtype == "int8":
        raise ValueError(
            "FedConfig.server_state_dtype='int8' is simulation-only — the "
            "blockscale QuantState layout has no mesh ParamDef form; use "
            "'bfloat16' on the mesh backend")
    if fed.server_state_dtype == "bfloat16":
        import dataclasses
        second = jax.tree.map(
            lambda dref: dataclasses.replace(dref, dtype="bfloat16"),
            opt, is_leaf=pdefs.is_def)
    else:
        second = opt
    errors = jax.tree.map(client_stacked, par, is_leaf=pdefs.is_def)
    return FedMeshState(
        params=par, m=opt, v=second, vhat=second, errors=errors,
        round=pdefs.ParamDef((), P(), dtype="int32", init="zeros"))


def init_fed_state(model, fed: FedConfig, rng) -> FedMeshState:
    defs = fed_state_defs(model, fed)
    params = pdefs.init_params(defs.params, rng)
    zeros = lambda t: jax.tree.map(
        lambda d: jnp.zeros(d.shape, jnp.dtype(d.dtype)), t, is_leaf=pdefs.is_def)
    return FedMeshState(params=params, m=zeros(defs.m), v=zeros(defs.v),
                        vhat=zeros(defs.vhat), errors=zeros(defs.errors),
                        round=jnp.zeros((), jnp.int32))


def _sharded_server_update(fed: FedConfig, st: ServerState, params, agg,
                           model, ctx: ParallelContext):
    """ZeRO-style server step: each index along the state-shard axes owns a
    slice of (m, v, v̂); it updates its slice of x from its slice of the
    aggregate and the refreshed params are all-gathered back (invariant vma).
    Leaves too small to shard stay replicated and update normally."""
    axes = state_shard_axes(fed)
    shards = fed.state_shards
    # linear index along the shard axes
    idx = 0
    for ax in axes:
        idx = idx * lax.psum(1, ax) + lax.axis_index(ax)

    defs = model.defs()
    dims = jax.tree.map(lambda d: state_shard_dim(d, shards), defs,
                        is_leaf=pdefs.is_def)

    def take(leaf, sd):
        if sd is None:
            return leaf
        chunk = leaf.shape[sd] // shards
        return lax.dynamic_slice_in_dim(leaf, idx * chunk, chunk, axis=sd)

    p_sh = jax.tree.map(take, params, dims)
    agg_sh = jax.tree.map(take, agg, dims)
    st_sh = ServerState(m=st.m, v=st.v, vhat=st.vhat, t=st.t)  # already shards
    newp_sh, new_st = server_update(fed, st_sh, p_sh, agg_sh)

    def gather(newp, oldp, sd):
        if sd is None:
            return newp
        x = newp
        for ax in axes:
            try:
                from jax._src.lax.parallel import all_gather_invariant
                x = all_gather_invariant(x, ax, axis=sd, tiled=True)
            except ImportError:  # pragma: no cover
                x = lax.all_gather(x, ax, axis=sd, tiled=True)
        return x.astype(oldp.dtype)

    new_params = jax.tree.map(gather, newp_sh, params, dims)
    return new_params, new_st


# -- the round ---------------------------------------------------------------


def leaf_wire_bytes(fed: FedConfig, dl: int, block: int = 2048) -> int:
    """Per-client collective payload bytes for ONE leaf of ``dl`` local
    elements, resolved through the same :func:`~repro.core.stages.
    mesh_agg_strategy` the round executes — so every fallback (non-fedcams,
    sparse aggregation with a compressor that has no compacted form) is
    billed as the dense psum it actually runs:

    * ``sparse_topk`` / ``sparse_topk_hier`` — the gathered Selection: an
      int32 global index + fp32 value per kept coordinate (8 bytes each),
      ``nb·kb`` entries in the leaf's padded block layout — exactly the
      two arrays ``stages.sparse_topk_leaf`` /
      ``stages.sparse_topk_hier_leaf`` all_gather (regression-tested
      against the traced collective operands in tests/test_mesh_parity.py).
      On the hierarchical strategy this is the TIER-1 (client → group)
      payload; the tier-2 group partial is :func:`leaf_tier2_bytes`.
    * ``packed_sign``  — the 8→1 packed sign bits + one fp32 scale.
    * ``dense``        — ``delta_dtype`` words for every element.
    """
    from repro.core.compressors import block_layout
    strategy = mesh_agg_strategy(fed)
    if strategy in ("sparse_topk", "sparse_topk_hier"):
        bs, nb = block_layout(dl, block)
        kb = max(1, int(round(fed.compress_ratio * bs)))
        return nb * kb * 8                # int32 index + fp32 value
    if strategy == "packed_sign":
        return (dl + 7) // 8 + 4          # 1 bit/coord + fp32 scale
    return dl * jnp.dtype(fed.delta_dtype).itemsize


def leaf_tier2_bytes(fed: FedConfig, dl: int) -> int:
    """Per-GROUP root-collective payload bytes for one leaf of ``dl`` local
    elements: the dense fp32 group partial ``sparse_topk_hier_leaf``
    gathers over the group axis. Zero on every flat strategy — the root
    tier only exists when the hierarchical collective actually runs."""
    if mesh_agg_strategy(fed) == "sparse_topk_hier":
        return dl * 4                     # fp32 partial, independent of n
    return 0


def mesh_wire_bytes(fed: FedConfig, delta_tree, block: int = 2048,
                    tp: int = 1) -> int:
    """Measured per-client contribution bytes for one mesh round's
    client-axis collective: the sum of :func:`leaf_wire_bytes` over the
    local shard tree. (Collectives carry no per-message header, unlike the
    comm.wire point-to-point codecs.)

    ``delta_tree`` holds this device's *local* shards; every one of the
    client's ``tp`` model-parallel devices pushes its own payload into the
    client-axis collective (model-replicated leaves included — each device
    sends its copy), so the client's wire traffic is the local total × tp.

    On the hierarchical strategy this is the TIER-1 (client → group)
    contribution; :func:`mesh_wire_bytes_tiers` gives both tiers.
    """
    total = sum(leaf_wire_bytes(fed, int(np.prod(leaf.shape)), block)
                for leaf in jax.tree.leaves(delta_tree))
    return total * max(tp, 1)


def mesh_wire_bytes_tiers(fed: FedConfig, delta_tree, block: int = 2048,
                          tp: int = 1) -> dict:
    """Per-tier uplink bytes for one mesh round, resolved through the
    executed :func:`~repro.core.stages.mesh_agg_strategy` like everything
    else: ``tier1`` is the per-CLIENT selection payload
    (:func:`mesh_wire_bytes` — every one of m clients pushes it), ``tier2``
    the per-GROUP dense partial the root consumes (g pushes, independent
    of the member count; 0 on flat strategies). The round's
    ``wire_up_bytes`` metric is ``m·tier1 + g·tier2`` — billing the tiers
    that actually run."""
    tier2 = sum(leaf_tier2_bytes(fed, int(np.prod(leaf.shape)))
                for leaf in jax.tree.leaves(delta_tree))
    return {"tier1": mesh_wire_bytes(fed, delta_tree, block, tp),
            "tier2": tier2 * max(tp, 1)}


def build_fed_round(model, fed: FedConfig, train: TrainConfig,
                    ctx: ParallelContext, *, chunk: int = 2048,
                    kernel_impl: Optional[object] = None):
    """Returns fed_round(state, batch, seed) — the per-device SPMD function
    (wrap in shard_map + jit via launch.train / launch.dryrun)."""
    # On the mesh, deltas are per-leaf shards (billions of elements for the
    # large archs): global top-k is ill-defined and lax.top_k overflows int32
    # indices, so "topk" means the blockwise TPU kernel semantics here
    # (DESIGN.md §3; contraction bound unchanged). Exact global top-k lives
    # in the FedSim simulation path.
    comp_name = "blocktopk" if fed.compressor == "topk" else fed.compressor
    if fed.ef_store:
        raise ValueError(
            "FedConfig.ef_store is FedSim-only — the mesh backend already "
            "shards per-client EF state over the client axes (one row per "
            "client-axis device); there is no resident (m, d) buffer to "
            "stream")
    strategy = mesh_agg_strategy(fed)
    # fault tolerance (DESIGN.md §robustness): the mesh draws its crash
    # mask in-trace from the shared round rng — every device must agree on
    # who died without host round-trips — and damages/validates payloads
    # around the gathered Selection collective
    fcfg = fed.fault
    if fed.deadline_s > 0 or (fcfg is not None and fcfg.deadline_s > 0):
        raise ValueError(
            "deadline_s is FedSim wire-mode only — the mesh backend has "
            "no transport clock to cut against; model stragglers as "
            "crashes (FaultConfig.crash_prob / crash_trace) on the mesh")
    validating = fcfg is not None and (fcfg.corrupt_prob > 0
                                       or fcfg.max_update_norm > 0)
    if validating and strategy != "sparse_topk":
        raise ValueError(
            f"FaultConfig corruption/validation on the mesh needs the "
            f"flat compacted-Selection collective (strategy "
            f"'sparse_topk'), but this config resolves {strategy!r} — "
            f"the validation-before-ingest gate inspects gathered "
            f"(vals, idx) payloads, which the dense psum / hierarchical "
            f"partials never materialize per client")
    if fed.agg_groups > 1 and strategy != "sparse_topk_hier":
        raise ValueError(
            f"FedConfig.agg_groups={fed.agg_groups} but this config "
            f"resolves the {strategy!r} aggregation strategy — the two-"
            f"level collective only exists for the compacted-Selection "
            f"path (fedcams + aggregation='sparse' + topk/blocktopk)")
    if strategy == "sparse_topk_hier":
        # the FIRST client axis is the group axis (sharding.rules); the
        # launch site sizes it to agg_groups when building the mesh
        if len(fed.client_axes) < 2:
            raise ValueError(
                f"agg_groups={fed.agg_groups} needs >= 2 client axes — "
                f"the first enumerates the groups, the rest the members "
                f"(e.g. client_axes=('cgroup', 'data')); got "
                f"{fed.client_axes!r}")
        if fed.num_clients % fed.agg_groups:
            raise ValueError(
                f"agg_groups={fed.agg_groups} must divide the client-axis "
                f"size m={fed.num_clients} (the mesh reshapes the client "
                f"axis into (groups, members))")
    # One-pass fused ingest (DESIGN.md §3): resolved at build time like the
    # selection provider. Eligible only on the FLAT compacted-Selection
    # strategy (the gathered (vals, idx) feed the ingest directly) without
    # state sharding (the fused pass owns the whole replicated update).
    from repro.core.server_opt import FUSED_INGEST_GROUPS_DETAIL
    fused = resolve_fused_ingest(
        fed,
        eligible=(strategy == "sparse_topk"
                  and not (fed.shard_server_state and fed.state_shards > 1)
                  and fcfg is None),
        have_kernel=kernel_impl is not None,
        compiled=kernel_impl is not None and kernel_impl.compiled,
        detail="the mesh fuses only the sparse_topk aggregation strategy "
               "(fedcams + aggregation='sparse' + topk/blocktopk) without "
               "shard_server_state or fault injection (the masked "
               "survivor aggregate needs the unfused gather path)"
               + FUSED_INGEST_GROUPS_DETAIL)
    # One block layout for the whole sparse path: when the kernel provider
    # will select OR the kernel ingest will consume, the jnp compressor,
    # the kernels, and the wire metric all use the kernel's block — layout
    # mismatches would silently break the kernel/jnp bit-identity and the
    # metric==payload invariant.
    sparse_block = 2048
    if strategy in ("sparse_topk", "sparse_topk_hier"):
        # resolve at build time, not inside the traced round: 'kernel'
        # without a KernelImpl has nothing to select with
        if (resolve_mesh_sparse_impl(fed, kernel_impl) == "kernel"
                or fused == "kernel"):
            sparse_block = kernel_impl.block
    comp = (make_compressor(comp_name, fed.compress_ratio, sparse_block)
            if fed.algorithm == "fedcams" else None)
    rule = make_local_update(fed)
    m_clients = fed.num_clients
    n_part = fed.participating or m_clients
    hierarchical = "data" not in fed.client_axes  # within-client DP on "data"

    def local_loss(p, b):
        return model.loss(p, b, ctx, remat_policy=train.remat_policy,
                          chunk=chunk)

    # TP gradient correctness relies on shard_map's varying-manual-axes
    # tracking (check_vma=True at every launch-site shard_map): jax then
    # transposes the forward psums correctly, so gradients of both sharded
    # and replicated parameters are exact — verified against the tp=1 model
    # in tests/test_sharding.py.

    def fed_round(state: FedMeshState, batch, seed):
        params = state.params

        # Clients must diverge during local training: mark the replicated
        # global params as VARYING over the client axes (lax.pvary — a
        # vma-type cast, no communication) so shard_map's vma autodiff does
        # NOT sum gradients across clients. In hierarchical mode the "data"
        # axis stays replicated, so the automatic gradient psum over "data"
        # implements within-client data parallelism (we rescale sum->mean).
        def _pvary(t):
            if not fed.client_axes:
                return t
            return jax.tree.map(
                lambda x: compat.pvary(x, tuple(fed.client_axes)), t)

        local0 = _pvary(params)

        # shared randomness -> identical draws on every device; also feeds
        # participation and the heterogeneous-K draw below
        rng = jax.random.fold_in(jax.random.PRNGKey(0), seed)

        def grad_fn(p, b):
            (l, _), g = jax.value_and_grad(local_loss, has_aux=True)(p, b)
            if hierarchical:
                g = jax.tree.map(lambda x: x / ctx.dp, g)
            # pre-cast to param dtype so the rule's update math runs in the
            # param dtype exactly as the pre-split step did
            g = jax.tree.map(lambda x, gg: gg.astype(x.dtype), p, g)
            return l, g

        eta_l = local_lr(fed, state.round)
        k_all = hetero_step_counts(fed, rng, m_clients)
        k_i = None if k_all is None else k_all[ctx.client_index()]
        local, loss_local = run_local_steps(rule, grad_fn, local0, batch,
                                            eta_l, k_i=k_i)
        delta = jax.tree.map(lambda a, b_: (a - b_).astype(jnp.float32),
                             local, local0)

        # participation (same mask on every device via the shared rng)
        mask = participation_mask(jax.random.fold_in(rng, 1), m_clients, n_part)
        if fcfg is not None:
            # crashed clients drop out of the round exactly like non-
            # participants: zero contribution, stale EF row (the drop
            # semantics core/error_feedback.py documents). n_eff becomes
            # the traced survivor count — with no faults drawn it equals
            # n_part bit-exactly, so the disabled path stays bit-identical.
            mask = mask * mesh_fault_mask(fcfg, rng, m_clients, state.round)
            n_eff = jnp.maximum(jnp.sum(mask), 1.0)
        else:
            n_eff = float(n_part)
        my_mask = mask[ctx.client_index()]

        my_err = jax.tree.map(lambda e: e[0], state.errors)  # local client slice
        st = ServerState(m=state.m, v=state.v, vhat=state.vhat, t=state.round)

        def _server_step(agg):
            # server update (replicated elementwise math on sharded leaves)
            if kernel_impl is not None and fed.algorithm in (
                    "fedams", "fedcams", "fedamsgrad"):
                return kernel_impl.fedams_update_tree(fed, st, params, agg)
            if fed.shard_server_state and fed.state_shards > 1:
                return _sharded_server_update(fed, st, params, agg, model,
                                              ctx)
            return server_update(fed, st, params, agg)

        rejected = jnp.zeros(())
        if fused != "off":
            # one-pass fused ingest: select once (same provider resolution
            # as mesh_uplink's sparse branch), all_gather the compacted
            # Selections (identical collective + payload to
            # sparse_topk_leaf), and run scatter-mean + FedAMS update in a
            # single read-modify-write over the optimizer state — no dense
            # mean delta is materialized (bit-identical at fp32 state)
            if resolve_mesh_sparse_impl(fed, kernel_impl) == "kernel":
                sels, new_err = kernel_impl.topk_select_tree(
                    comp.ratio, delta, my_err, my_mask)
            else:
                sels, new_err = topk_select_tree(comp, delta, my_err,
                                                 my_mask)
            gather = lambda a: ctx.all_gather_clients(a[None], axis=0)
            if fused == "kernel":
                new_params, new_st = kernel_impl.fedams_ingest_tree(
                    fed, st, params, sels, n_eff, gather)
            else:
                new_params, new_st = server_ingest_tree(
                    fed, st, params, sels, n_eff, gather,
                    block=sparse_block, impl="jnp")
        elif validating:
            # fault-tolerant sparse round: select once (same provider as
            # mesh_uplink's sparse branch), damage this device's OWN
            # payload in transit (every device computes the same shared
            # corruption plan, so the gathered copies — including the
            # sender's — all show the damage), validate server-side, and
            # aggregate over alive ∧ valid. A rejected client is NACKed:
            # its EF row rolls back to the stale pre-round value, the
            # same drop semantics core/error_feedback.py documents.
            if resolve_mesh_sparse_impl(fed, kernel_impl) == "kernel":
                sels, new_err = kernel_impl.topk_select_tree(
                    comp.ratio, delta, my_err, my_mask)
            else:
                sels, new_err = topk_select_tree(comp, delta, my_err,
                                                 my_mask)
            plan = mesh_corruption_plan(fcfg, rng, m_clients)
            ci = ctx.client_index()
            myplan = jax.tree.map(lambda a: a[ci][None], plan)

            def leaf_fault(s, lf):
                cv, cidx = corrupt_selection(s.vals[None], s.idx[None],
                                             myplan, fcfg.corrupt_mode)
                bs, nb = block_layout(lf.size, sparse_block)
                return sparse_topk_leaf_validated(
                    Selection(vals=cv[0], idx=cidx[0]), lf, mask, ctx,
                    bs * nb, fcfg.max_update_norm)

            is_sel = lambda x: isinstance(x, Selection)
            outs = jax.tree.map(leaf_fault, sels, delta, is_leaf=is_sel)
            is_t = lambda x: isinstance(x, tuple)
            agg = jax.tree.map(lambda t: t[0], outs, is_leaf=is_t)
            valid_leaves = [t[1] for t in
                            jax.tree.leaves(outs, is_leaf=is_t)]
            rejected = jax.tree.leaves(outs, is_leaf=is_t)[0][2]
            # a client survives only if EVERY leaf validated — one damaged
            # leaf NACKs the whole client update (EF rolls back, so the
            # full residual repays on the next clean round)
            my_valid = valid_leaves[0]
            for v in valid_leaves[1:]:
                my_valid = my_valid * v
            new_err = jax.tree.map(
                lambda ne, eo: jnp.where(my_valid > 0, ne, eo),
                new_err, my_err)
            new_params, new_st = _server_step(agg)
        else:
            agg, new_err = mesh_uplink(fed, comp, ctx, kernel_impl, rng,
                                       delta, my_err, my_mask, n_eff)
            new_params, new_st = _server_step(agg)

        errors = jax.tree.map(lambda e, ne: e.at[0].set(ne),
                              state.errors, new_err)
        loss = ctx.pmean_clients(loss_local)
        if hierarchical:
            loss = ctx.pmean_data(loss)
        new_state = FedMeshState(params=new_params, m=new_st.m, v=new_st.v,
                                 vhat=new_st.vhat, errors=errors,
                                 round=new_st.t)
        # measured uplink bytes this round (trace-time constant, replicated);
        # same key/semantics as FedSim wire mode's per-round uplink metric.
        # All m client-axis devices feed the tier-1 collective — non-
        # participants contribute masked zeros that still occupy wire — so
        # that factor is m, not n_part; on the hierarchical strategy the
        # root tier adds one dense partial per GROUP (g pushes, not m: the
        # SPMD emulation replicates the partial across a group's members,
        # but the logical two-tier topology the metric bills transmits it
        # once per group — tests/test_mesh_parity.py checks both tiers
        # against the traced collective operands).
        tiers = mesh_wire_bytes_tiers(fed, delta, block=sparse_block,
                                      tp=ctx.tp)
        wire = jnp.float32(m_clients * tiers["tier1"]
                           + fed.agg_groups * tiers["tier2"])
        met = {"loss": loss, "wire_up_bytes": wire}
        if fcfg is not None:
            # replicated scalars (mask/validation are shared draws):
            # delivered survivor count and validation-rejected count —
            # the mesh siblings of FedSim's fault metrics
            met["survivors"] = jnp.sum(mask)
            met["rejected"] = rejected
        return new_state, met

    return fed_round


def mesh_metric_specs(fed: FedConfig, *, scan: bool = False):
    """PartitionSpecs for the metrics dict ``build_fed_round`` emits —
    launch sites and core.api build their shard_map ``out_specs`` through
    here so the fault-metric keys cannot drift out of sync with the round
    body. ``scan=True`` gives the stacked (R,)-leading variant."""
    sp = P(None) if scan else P()
    specs = {"loss": sp, "wire_up_bytes": sp}
    if fed.fault is not None:
        specs["survivors"] = sp
        specs["rejected"] = sp
    return specs


def build_fed_rounds_scan(fed_round):
    """Lift a per-round mesh body to the scan-driven multi-round body:
    ``(state, batches[R], seeds[R]) -> (state, stacked metrics)``. Shared by
    core.api.FederatedTrainer and launch.train so the scan step exists in
    exactly one place (wrap in shard_map + jit with ``donate_argnums=(0,)``
    at the call site)."""

    def rounds_fn(state, batches, seeds):
        def body(st, inp):
            b, s = inp
            return fed_round(st, b, s)
        return lax.scan(body, state, (batches, seeds))

    return rounds_fn


def scan_batch_specs(batch_specs):
    """Per-round batch PartitionSpecs -> stacked (R, ...) specs."""
    return jax.tree.map(lambda s: P(None, *tuple(s)), batch_specs)


def stage_mesh_rounds(lm_data, r0: int, count: int, local_steps: int,
                      global_batch: int, seq_len: int):
    """Host-side staging for ``count`` mesh rounds: stacked (R, ...) batch
    dict + (R,) int32 seeds for :func:`build_fed_rounds_scan` (shared by
    core.api and launch.train)."""
    raws = [lm_data.mesh_batch(r, local_steps, global_batch, seq_len)
            for r in range(r0, r0 + count)]
    batch = {k: jnp.asarray(np.stack([b[k] for b in raws]))
             for k in raws[0]}
    return batch, jnp.arange(r0, r0 + count, dtype=jnp.int32)


def fed_batch_defs(model, fed: FedConfig, train: TrainConfig):
    """GLOBAL batch defs with client-axis sharding, leading K dim."""
    b = model.train_batch_defs(train.global_batch, train.seq_len)
    axes = client_batch_axes(fed)
    ax = axes[0] if len(axes) == 1 else tuple(axes)

    def stack_k(d: pdefs.ParamDef):
        import dataclasses
        spec = list(d.spec)
        spec[0] = ax  # batch dim over client (+data) axes
        return dataclasses.replace(
            d, shape=(fed.local_steps,) + tuple(d.shape), spec=P(None, *spec))

    return jax.tree.map(stack_k, b, is_leaf=pdefs.is_def)
