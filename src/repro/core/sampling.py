"""Client sampling (paper §3.2: random without replacement, P{i∈S_t}=n/m).

SPMD-friendly: from a shared per-round rng every client derives the same
permutation of [0, m) and checks whether its own index lands in the first n
slots. Weighted sampling uses Gumbel top-n over log-weights."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_clients(rng, m: int, n: int):
    """Returns int32 indices (n,) of the participating clients."""
    if n <= 0 or n >= m:
        return jnp.arange(m, dtype=jnp.int32)
    return jax.random.permutation(rng, m)[:n].astype(jnp.int32)


def participation_mask(rng, m: int, n: int):
    """(m,) float mask: 1.0 for sampled clients. Full participation if n in
    {0, m}."""
    if n <= 0 or n >= m:
        return jnp.ones((m,), jnp.float32)
    idx = sample_clients(rng, m, n)
    return jnp.zeros((m,), jnp.float32).at[idx].set(1.0)


def weighted_participation_mask(rng, weights, n: int):
    """Gumbel top-n sampling without replacement with probability ∝ weights."""
    m = weights.shape[0]
    if n <= 0 or n >= m:
        return jnp.ones((m,), jnp.float32)
    g = jax.random.gumbel(rng, (m,)) + jnp.log(jnp.maximum(weights, 1e-30))
    _, idx = jax.lax.top_k(g, n)
    return jnp.zeros((m,), jnp.float32).at[idx].set(1.0)
