"""hubert-xlarge [audio]: encoder-only transformer, wav2vec2 arch
[arXiv:2106.07447]. The mel/conv feature extractor is a stub — inputs are
precomputed frame embeddings (B, S, d). Targets are the 504-way cluster
codebook (masked-prediction reduced to full-position CE on synthetic
targets). Encoder-only => NO decode shapes (decode_32k, long_500k skipped,
DESIGN.md §4)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    is_encoder=True,
    frontend="audio",
    act="gelu",
    gated_ffn=False,
    source="arXiv:2106.07447",
)

smoke = ModelConfig(
    name="hubert-xlarge-smoke",
    family="audio",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=64,
    is_encoder=True,
    frontend="audio",
    act="gelu",
    gated_ffn=False,
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="skip", has_decode=False,
                notes="encoder-only: decode shapes skipped")
