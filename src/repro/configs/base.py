"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; the federated
algorithm (the paper's contribution) as a ``FedConfig``; meshes/shapes as
``MeshConfig`` / ``ShapeConfig``. Configs are plain frozen dataclasses so they
hash, compare and print cleanly, and are safe to close over in jit.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional, Tuple

if TYPE_CHECKING:  # runtime import is lazy (see FedConfig.__post_init__):
    # configs must stay importable before repro.comm/repro.core finish
    # initializing (comm.wire pulls in core.api pulls in this module)
    from repro.comm.faults import FaultConfig


# ---------------------------------------------------------------------------
# Model sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (DeepSeek-V3 / Qwen-MoE style)."""

    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0          # per-expert FFN hidden size
    d_ff_shared: int = 0          # total shared-expert FFN hidden size
    capacity_factor: float = 1.25
    router_softcap: Optional[float] = None
    aux_loss_weight: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention dims."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class RGLRUConfig:
    """Griffin RG-LRU recurrent block."""

    lru_width: int = 0            # 0 => d_model
    conv_width: int = 4           # temporal conv in the recurrent block
    c_constant: float = 8.0       # a = exp(-c * softplus(Λ) * sigmoid(gate))


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack (mLSTM + sLSTM alternating)."""

    pattern: Tuple[str, ...] = ("mlstm", "slstm")
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_width: int = 4
    # chunkwise-recurrent mLSTM (the xLSTM paper's O(S·c) form): replaces
    # the O(S²) parallel decay matrices; §Perf optimization, numerics equal.
    chunkwise: bool = False
    chunk_size: int = 256


@dataclass(frozen=True)
class MTPConfig:
    """DeepSeek-V3 multi-token-prediction auxiliary head."""

    depth: int = 1
    loss_weight: float = 0.3


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 => d_model // num_heads
    source: str = ""                    # citation (arXiv / hf card)

    # --- block pattern -----------------------------------------------------
    # Per-layer block kinds, repeated/truncated to num_layers. Kinds:
    #   "attn"  : softmax attention (window controlled by attn_pattern)
    #   "rglru" : Griffin recurrent block
    #   "mlstm" / "slstm" : xLSTM blocks
    block_pattern: Tuple[str, ...] = ("attn",)
    # Per-attention-layer window pattern: entries are window sizes; 0 = global.
    attn_pattern: Tuple[int, ...] = (0,)

    sliding_window: int = 4096          # window used by "local" attention entries
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"                   # silu | gelu
    gated_ffn: bool = True              # gated (xGLU) FFN; False = classic MLP
    tie_embeddings: bool = False
    is_encoder: bool = False            # encoder-only (no causal mask, no decode)
    frontend: Optional[str] = None      # None | "audio" | "vision"
    # For long_500k on otherwise-full-attention archs: run a sliding-window
    # VARIANT (flagged deviation, see DESIGN.md).
    long_context_variant_window: int = 0   # 0 = arch cannot run long_500k

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    mtp: Optional[MTPConfig] = None

    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_pattern:
            raise ValueError("block_pattern must be non-empty")

    # -- derived -------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind for each of the num_layers layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def layer_windows(self) -> Tuple[int, ...]:
        """Attention window per layer (0 = global); meaningless for non-attn."""
        p = self.attn_pattern
        out = []
        ai = 0
        for kind in self.layer_kinds:
            if kind == "attn":
                out.append(p[ai % len(p)])
                ai += 1
            else:
                out.append(0)
        return tuple(out)

    def num_params(self) -> int:
        """Approximate true (unpadded) parameter count."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        for kind in self.layer_kinds:
            if kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    qh = m.nope_head_dim + m.rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qh
                    total += d * (m.kv_lora_rank + m.rope_head_dim)
                    total += m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
                    total += self.num_heads * m.v_head_dim * d
                else:
                    total += d * self.num_heads * hd          # q
                    total += 2 * d * self.num_kv_heads * hd   # k, v
                    total += self.num_heads * hd * d          # o
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                total += 2 * d * w + w * d + 3 * w + self.rglru.conv_width * w
            elif kind == "mlstm":
                pf = self.xlstm.mlstm_proj_factor
                di = int(d * pf)
                total += 2 * d * di + 3 * di * di // max(self.num_heads, 1) + di * d
            elif kind == "slstm":
                pf = self.xlstm.slstm_proj_factor
                total += 4 * d * d + 4 * d * d // max(self.num_heads, 1)
                total += int(2 * d * d * pf)
            # FFN
            if self.moe is not None and kind == "attn":
                mo = self.moe
                total += d * mo.num_experts                    # router
                total += mo.num_experts * 3 * d * mo.d_ff_expert
                total += mo.num_shared_experts * 3 * d * max(mo.d_ff_shared, mo.d_ff_expert)
            elif kind in ("attn", "rglru") and ff > 0:
                total += 3 * d * ff if self.gated_ffn else 2 * d * ff
        return total

    def num_active_params(self) -> int:
        """Active params per token (= num_params for dense)."""
        if self.moe is None:
            return self.num_params()
        mo = self.moe
        d = self.d_model
        full = self.num_params()
        all_expert = self.num_layers * mo.num_experts * 3 * d * mo.d_ff_expert
        active_expert = self.num_layers * mo.top_k * 3 * d * mo.d_ff_expert
        return full - all_expert + active_expert


# ---------------------------------------------------------------------------
# Federated / training / mesh configs
# ---------------------------------------------------------------------------


#: Known values for the validated FedConfig string fields (a typo should
#: fail at construction, not deep inside the traced server_update).
FED_ALGORITHMS = ("fedavg", "fedadagrad", "fedadam", "fedyogi",
                  "fedamsgrad", "fedams", "fedcams")
FED_COMPRESSORS = ("topk", "blocktopk", "sign", "packedsign", "randk",
                   "int8", "none", "identity")
FED_AGGREGATIONS = ("dense", "sparse")
FED_MESH_SPARSE_IMPLS = ("auto", "kernel", "jnp")
FED_FUSED_INGEST = ("auto", "kernel", "jnp", "off")
FED_SERVER_STATE_DTYPES = ("float32", "bfloat16", "int8")
FED_LOCAL_OPTS = ("sgd", "sgdm", "prox")
#: Staleness-weight rules for the async buffered engine
#: (comm/async_engine.py): w(τ) applied to a delivery that trained on a
#: model τ server versions old. "inv_sqrt" is FedBuff's 1/sqrt(1+τ).
FED_STALENESS_WEIGHTS = ("inv_sqrt", "uniform", "inv_linear", "exp")


@dataclass(frozen=True)
class FedConfig:
    """The paper's algorithm family, selectable per-experiment."""

    algorithm: str = "fedcams"     # fedavg|fedadam|fedyogi|fedamsgrad|fedams|fedcams
    option: int = 1                # FedAMS max-stabilization Option 1 or 2
    eta: float = 1.0               # global (server) lr
    eta_l: float = 0.01            # local lr
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-3              # max-stabilization epsilon
    local_steps: int = 4           # K
    # -- local-update rule (core/local.py, DESIGN.md §8): how a client turns
    # K gradients into its delta. The convergence theory is agnostic to it;
    # "sgd" is the paper's plain local SGD (bit-identical default).
    local_opt: str = "sgd"         # sgd | sgdm | prox
    local_momentum: float = 0.9    # heavy-ball beta for local_opt="sgdm"
    prox_mu: float = 0.01          # proximal strength for local_opt="prox"
    # Per-round local LR schedule: round t trains at eta_l * eta_l_decay^t.
    # 1.0 = constant (bit-identical to the unscheduled round).
    eta_l_decay: float = 1.0
    # Heterogeneous per-client local work: when > 0, client i runs
    # K_i ~ Uniform{local_steps_min..local_steps} steps this round (masked
    # inside the scanned local step, so the trace stays static-shaped).
    # 0 = every client runs the full local_steps.
    local_steps_min: int = 0
    num_clients: int = 16          # m
    participating: int = 0         # n; 0 => full participation
    compressor: str = "topk"       # topk|blocktopk|sign|packedsign|randk|int8|none
    compress_ratio: float = 1.0 / 64.0   # r = k/d for top-k family
    # FedSim select-once sparse uplink (DESIGN.md §3): the top-k selection
    # runs once per client and the (vals, idx) pair flows end-to-end into an
    # O(n·k + d) server scatter — no dense per-client hat, no dense (n, d)
    # mean. None = auto (on for the topk/blocktopk family), False = force
    # the dense reference path, True = require it (rejects compressors with
    # no compacted form). Selection and error feedback are bit-identical to
    # the dense path; the aggregate matches up to scatter-vs-reduce
    # float reassociation on coordinates several clients selected.
    sparse_uplink: Optional[bool] = None
    aggregation: str = "dense"     # dense | sparse  (see DESIGN.md §3)
    # Mesh sparse aggregation: who computes the per-leaf blockwise top-k
    # selection the client-axis all_gather carries (DESIGN.md §3).
    # "auto" = the fused Pallas kernel (kernels/topk_ef.py::topk_ef_sparse,
    # one HBM pass emitting the compacted (vals, idx) block + the EF
    # residual) when a KernelImpl is supplied and compiles for the backend
    # (TPU), the jnp Compressor.select path otherwise; "kernel"/"jnp" force
    # one side (forcing "kernel" off-TPU runs the Pallas interpreter —
    # bit-identical, test-only speed). Selection and EF are bit-identical
    # across impls (tests/test_kernels.py, tests/test_mesh_parity.py).
    mesh_sparse_impl: str = "auto"  # auto | kernel | jnp
    # One-pass fused server ingest (DESIGN.md §3): scatter-mean + the full
    # FedAMS m/v/v̂/x update in a single read-modify-write over optimizer
    # state — the dense mean delta is never materialized. "auto" = fuse
    # whenever the round is eligible (sparse blocktopk uplink, no gamma
    # diagnostic / client chunking / state sharding), picking the Pallas
    # kernel (kernels/fedams_ingest.py) where it compiles (TPU) and the
    # blocked-scatter jnp path elsewhere; "kernel"/"jnp" force one side
    # (build-time error when the round cannot fuse); "off" = the two-pass
    # baseline (server_aggregate_sparse + server_update). Bit-identical to
    # the two-pass path at float32 state (tests/test_fused_ingest.py).
    fused_ingest: str = "auto"      # auto | kernel | jnp | off
    # Server second-moment (v, v̂) storage dtype: bf16 halves and
    # int8-blockscale (one fp32 absmax scale per wire_block) quarters the
    # optimizer-state HBM residency; the update math always runs in fp32,
    # dequant/requant fused into the ingest pass. Non-fp32 requires an
    # algorithm that overwrites v/v̂ every round (fedams family) — a
    # passthrough state would drift under requantization. int8 is
    # simulation-only (the blockscale layout has no mesh ParamDef form).
    server_state_dtype: str = "float32"  # float32 | bfloat16 | int8
    # Compute the per-round Assumption 4.17 γ diagnostic (paper Fig. 6).
    # It costs an extra dense compression of the mean total per round;
    # production-style perf runs turn it off and the history reports
    # gamma=0.0 (metric keys unchanged).
    track_gamma: bool = True
    delta_dtype: str = "float32"   # wire dtype for the dense client collective
    two_way: bool = False          # beyond-paper: compress server->client too
    # -- wire mode (repro.comm): encode every delta to packed bytes, move
    # it through the simulated network, decode server-side; history gains
    # measured wire_bytes / round_time_s next to the analytic bits.
    wire: bool = False
    wire_value_dtype: str = "float32"  # float32 = bit-exact vs the dense path
    wire_block: int = 2048         # codec block size (blocktopk/bitpack)
    wire_pack_impl: str = "jnp"    # jnp | pallas — sub-word bit packing path
    # FedSim: process the per-client train/compress/encode pipeline in
    # chunks of this many clients (lax.scan over n/client_chunk chunks), so
    # peak delta memory is (client_chunk, d) instead of (n, d). 0 = off.
    client_chunk: int = 0
    # -- million-client scale-out (DESIGN.md §scale-out) -------------------
    # FedSim: hold the per-client EF error rows host-side in a lazily
    # materialized shard store (checkpoint.store.EFStore) instead of the
    # device-resident (m, d) buffer. Each round gathers only the
    # participating cohort's rows to device and scatters them back after
    # the uplink, so peak device memory is (participating, d) not (m, d) —
    # the enabler for m = 10^6. Loss is bit-identical to the resident
    # buffer (same rows, same math; tests/test_scale_out.py). FedSim-only:
    # the mesh backend already shards EF over the client axes.
    ef_store: bool = False
    # Two-level hierarchical sparse aggregation: clients are partitioned
    # into this many groups; each group pre-merges its members' compacted
    # (vals, idx) selections into a dense partial (tier 1, the existing
    # blocked scatter) and the root consumes the g group partials (tier 2)
    # instead of n client messages. 1 = flat (bit-identical to before).
    # Requires the sparse (vals, idx) pipeline (topk/blocktopk family); on
    # the mesh the FIRST client axis is the group axis. The aggregate
    # matches flat up to ≤1-ulp reassociation on coordinates selected by
    # clients in several groups (the PR-4 collision analysis, now across
    # group partials — tests/test_mesh_parity.py).
    agg_groups: int = 1
    # -- fault-tolerant rounds (DESIGN.md §robustness, comm/faults.py) -----
    # Server round deadline in simulated seconds: clients whose simulated
    # finish time exceeds it are cut from the round (their EF residual
    # stays stale and repays on rejoin). Turns the straggler max in
    # T_round into a quantile. FedSim wire mode only (needs the transport
    # clock); 0 = wait for every survivor. Shorthand for a deadline-only
    # FaultConfig — set either this or fault.deadline_s, not both.
    deadline_s: float = 0.0
    # -- event-driven async buffered rounds (DESIGN.md §11,
    # comm/async_engine.py) -----------------------------------------------
    # FedSim wire mode: instead of the server waiting for the whole cohort
    # (T_round = straggler max), a host-side event clock orders per-client
    # delivery times and the server fires one buffered aggregation every
    # time this many deliveries accumulate, weighting each entry by its
    # staleness (FedBuff-style). 0 = synchronous rounds. Must be in
    # [1, cohort]; with async_buffer == cohort and "uniform" weights the
    # engine is bit-identical to the sync round (the parity anchor).
    # Requires the sparse (vals, idx) pipeline — the flush consumes a
    # fixed-shape (buffer, k) Selection batch through the validated
    # weighted scatter. Deadline cutoffs are the competing strategy
    # (drop late work vs reweight it): setting both is rejected.
    async_buffer: int = 0
    # w(τ) rule for async deliveries, τ = server versions elapsed since
    # the entry's cohort was dispatched: inv_sqrt = 1/sqrt(1+τ) (FedBuff),
    # uniform = 1.0, inv_linear = 1/(1+τ), exp = exp(-τ/2).
    staleness_weight: str = "inv_sqrt"
    # Full fault model: crash probability / scheduled outages / payload
    # corruption + validation-before-ingest knobs. None = fault-free
    # (bit-identical to a build without the fault machinery). When set,
    # both backends thread a survivor mask through the round: the
    # aggregate is a masked scatter/mean over survivors and the server
    # validates decoded payloads (NaN/Inf, index range, optional norm
    # clip) before they can touch the FedAMS m/v/v̂ state.
    fault: Optional["FaultConfig"] = None
    client_axes: Tuple[str, ...] = ("data",)   # mesh axes that enumerate clients
    use_kernels: bool = False      # use Pallas kernels for compress+server update
    # ZeRO-style sharding of the server optimizer state (m, v, v_hat) over
    # the client axes (or "data" in hierarchical mode): the update is
    # elementwise, so each shard owns a slice and the refreshed params are
    # all-gathered once per round.
    shard_server_state: bool = False
    state_shards: int = 0          # resolved from the mesh by launch.steps

    def __post_init__(self):
        def check(field, value, known):
            if value not in known:
                raise ValueError(
                    f"FedConfig.{field}={value!r} is not one of {known}")
        check("algorithm", self.algorithm, FED_ALGORITHMS)
        check("option", self.option, (1, 2))
        check("compressor", self.compressor, FED_COMPRESSORS)
        check("aggregation", self.aggregation, FED_AGGREGATIONS)
        check("mesh_sparse_impl", self.mesh_sparse_impl,
              FED_MESH_SPARSE_IMPLS)
        check("fused_ingest", self.fused_ingest, FED_FUSED_INGEST)
        check("server_state_dtype", self.server_state_dtype,
              FED_SERVER_STATE_DTYPES)
        if (self.server_state_dtype != "float32"
                and self.algorithm not in ("fedams", "fedcams",
                                           "fedamsgrad")):
            raise ValueError(
                f"FedConfig.server_state_dtype={self.server_state_dtype!r} "
                f"requires an algorithm that overwrites v/v̂ every round "
                f"(fedams/fedcams/fedamsgrad) — {self.algorithm!r} would "
                f"requant-drift passthrough state")
        if self.server_state_dtype == "int8" and self.shard_server_state:
            raise ValueError(
                "FedConfig.server_state_dtype='int8' is incompatible with "
                "shard_server_state — the blockscale layout does not "
                "slice along the state-shard axes")
        check("local_opt", self.local_opt, FED_LOCAL_OPTS)
        check("wire_pack_impl", self.wire_pack_impl, ("jnp", "pallas"))
        check("sparse_uplink", self.sparse_uplink, (None, True, False))
        if self.sparse_uplink and self.compressor not in ("topk",
                                                          "blocktopk"):
            raise ValueError(
                f"FedConfig.sparse_uplink=True requires a (value, index) "
                f"compressor (topk/blocktopk), got {self.compressor!r}")
        if not 0.0 < self.eta_l_decay <= 1.0:
            raise ValueError(
                f"FedConfig.eta_l_decay={self.eta_l_decay} must be in (0, 1]")
        if self.local_steps_min < 0 or self.local_steps_min > self.local_steps:
            raise ValueError(
                f"FedConfig.local_steps_min={self.local_steps_min} must be "
                f"in [0, local_steps={self.local_steps}]")
        if self.agg_groups < 1:
            raise ValueError(
                f"FedConfig.agg_groups={self.agg_groups} must be >= 1")
        if self.agg_groups > 1:
            if self.compressor not in ("topk", "blocktopk"):
                raise ValueError(
                    f"FedConfig.agg_groups={self.agg_groups} requires the "
                    f"sparse (vals, idx) pipeline — a (value, index) "
                    f"compressor (topk/blocktopk), got {self.compressor!r}")
            n_round = self.participating or self.num_clients
            if n_round % self.agg_groups:
                raise ValueError(
                    f"FedConfig.agg_groups={self.agg_groups} must divide "
                    f"the per-round client count n={n_round} — ragged "
                    f"groups would silently skew the tier-1 partials")
        if self.deadline_s < 0:
            raise ValueError(
                f"FedConfig.deadline_s={self.deadline_s} must be >= 0")
        if self.fault is not None or self.deadline_s > 0:
            from repro.comm.faults import FaultConfig
            if self.fault is not None and not isinstance(self.fault,
                                                         FaultConfig):
                raise ValueError(
                    f"FedConfig.fault must be a comm.faults.FaultConfig, "
                    f"got {type(self.fault).__name__}")
            if self.deadline_s > 0 and self.fault is not None \
                    and self.fault.deadline_s > 0:
                raise ValueError(
                    f"both FedConfig.deadline_s={self.deadline_s} and "
                    f"FedConfig.fault.deadline_s="
                    f"{self.fault.deadline_s} are set — pick one")
            deadline = self.deadline_s or (
                self.fault.deadline_s if self.fault is not None else 0.0)
            if deadline > 0 and not self.wire:
                raise ValueError(
                    "a round deadline (deadline_s > 0) needs the simulated "
                    "transport clock — set FedConfig(wire=True); the mesh "
                    "backend has no per-client times to cut against")
            if self.track_gamma:
                raise ValueError(
                    "FedConfig.fault/deadline_s requires track_gamma="
                    "False — the γ diagnostic consumes the dense mean "
                    "over the FULL cohort, which a partial round no "
                    "longer computes")
            if self.agg_groups > 1:
                raise ValueError(
                    "FedConfig.fault/deadline_s is incompatible with "
                    "agg_groups > 1 — the two-level group partials have "
                    "no per-client survivor masking yet")
            if self.client_chunk:
                raise ValueError(
                    "FedConfig.fault/deadline_s is incompatible with "
                    "client_chunk — the chunked scan accumulates dense "
                    "running sums the survivor mask cannot thread "
                    "through; run the unchunked round")
        check("staleness_weight", self.staleness_weight,
              FED_STALENESS_WEIGHTS)
        if self.async_buffer < 0:
            raise ValueError(
                f"FedConfig.async_buffer={self.async_buffer} must be >= 0")
        if self.async_buffer > 0:
            n_round = self.participating or self.num_clients
            if self.async_buffer > n_round:
                raise ValueError(
                    f"FedConfig.async_buffer={self.async_buffer} exceeds "
                    f"the cohort size n={n_round} — a flush would wait on "
                    f"more deliveries than one dispatch provides")
            if not self.wire:
                raise ValueError(
                    "FedConfig.async_buffer needs the simulated transport "
                    "clock — set FedConfig(wire=True); without per-client "
                    "delivery times there is no event order to buffer")
            if self.compressor not in ("topk", "blocktopk") \
                    or self.sparse_uplink is False:
                raise ValueError(
                    "FedConfig.async_buffer requires the select-once "
                    "sparse (vals, idx) uplink (topk/blocktopk, "
                    "sparse_uplink not False) — the buffered flush "
                    "consumes a fixed-shape Selection batch through the "
                    "validated weighted scatter")
            if self.track_gamma:
                raise ValueError(
                    "FedConfig.async_buffer requires track_gamma=False — "
                    "the γ diagnostic consumes the dense mean over a full "
                    "synchronous cohort, which a buffered flush never "
                    "forms")
            if self.two_way:
                raise ValueError(
                    "FedConfig.async_buffer is incompatible with two_way "
                    "— in-flight clients trained on a model the server-"
                    "side downlink EF stream has since rewritten")
            if self.agg_groups > 1 or self.client_chunk or self.ef_store:
                raise ValueError(
                    "FedConfig.async_buffer is incompatible with "
                    "agg_groups/client_chunk/ef_store — the buffered "
                    "flush is a flat fixed-shape (buffer, k) batch")
            deadline = self.deadline_s or (
                self.fault.deadline_s if self.fault is not None else 0.0)
            if deadline > 0:
                raise ValueError(
                    "FedConfig.async_buffer and a round deadline are "
                    "competing straggler strategies (reweight late work "
                    "vs drop it) — set one")


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    rounds: int = 100
    microbatch: int = 0           # 0 = no microbatching within a local step
    remat_policy: str = "full"    # full | dots | none
    tp_collective: str = "psum"   # psum | rs_ag (see ParallelContext)
    log_every: int = 10
    checkpoint_every: int = 0
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def tp(self) -> int:
        return self.shape[self.axes.index("model")]

    @property
    def dp(self) -> int:
        return self.shape[self.axes.index("data")]


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ExperimentConfig:
    model: ModelConfig
    fed: FedConfig = field(default_factory=FedConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)

    def replace(self, **kw) -> "ExperimentConfig":
        return dataclasses.replace(self, **kw)


def mreplace(cfg, **kw):
    """dataclasses.replace that tolerates nested dataclass fields."""
    return dataclasses.replace(cfg, **kw)
