"""deepseek-coder-33b [dense]: llama-arch GQA 56H/kv8 [arXiv:2401.14196].
long_500k via flagged sliding-window variant."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
    long_context_variant_window=4096,
    source="arXiv:2401.14196",
)

smoke = ModelConfig(
    name="deepseek-coder-33b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=320,
    vocab_size=512,
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="variant",
                notes="long_500k via sliding-window variant")
