"""gemma2-2b [dense]: alternating local/global attention with softcaps,
head_dim 256 [arXiv:2408.00118]. long_500k native (as gemma2-27b)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_pattern=(4096, 0),
    sliding_window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118",
)

smoke = ModelConfig(
    name="gemma2-2b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    attn_pattern=(16, 0),
    logit_softcap=30.0,
    attn_softcap=50.0,
    act="gelu",
    tie_embeddings=True,
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="native",
                notes="alternating local/global; long_500k native")
