"""gemma2-27b [dense]: alternating local(4096)/global attention, logit
softcap 30 / attention softcap 50, head_dim 128 [arXiv:2408.00118].
long_500k runs natively: half the layers are sliding-window; the global
layers attend the full (sequence-sharded) cache — decode cost is linear."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    attn_pattern=(4096, 0),          # local, global alternating
    sliding_window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118",
)

smoke = ModelConfig(
    name="gemma2-27b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    attn_pattern=(16, 0),
    logit_softcap=30.0,
    attn_softcap=50.0,
    act="gelu",
    tie_embeddings=True,
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="native",
                notes="alternating local/global; long_500k native")
