"""internvl2-1b [vlm]: InternViT + InternLM2 decoder [arXiv:2404.16821].

We implement the language backbone (InternLM2-1b: llama-arch, GQA 14H/kv2);
the vision encoder + projector are a stub — training inputs are precomputed
patch embeddings (B, S, d_model), per the assignment carve-out. Decode
consumes token ids (text generation). long_500k runs as an explicitly
flagged sliding-window VARIANT (the real model is full-attention)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    rope_theta=1_000_000.0,
    frontend="vision",
    long_context_variant_window=4096,
    source="arXiv:2404.16821",
)

smoke = ModelConfig(
    name="internvl2-1b-smoke",
    family="vlm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    frontend="vision",
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="variant",
                notes="vision frontend stubbed; long_500k via sliding-window variant")
