from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    ExperimentConfig,
    FedConfig,
    MeshConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    MTPConfig,
    RGLRUConfig,
    ShapeConfig,
    TrainConfig,
    XLSTMConfig,
)
from repro.configs.registry import ARCH_IDS, ArchSpec, all_archs, get_arch  # noqa: F401
