"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed top-4 experts
[hf:Qwen/Qwen1.5-MoE-A2.7B]. Experts padded 60->64 for 16-way expert
parallelism (router logits of pad experts pinned to -inf). long_500k via
flagged sliding-window variant."""
from repro.configs.base import ModelConfig, MoEConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                  d_ff_expert=1408, d_ff_shared=5632, capacity_factor=1.25),
    long_context_variant_window=4096,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

smoke = ModelConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    qkv_bias=True,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=2,
                  d_ff_expert=64, d_ff_shared=128, capacity_factor=2.0),
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="variant",
                notes="experts padded 60->64 for EP; long_500k via variant")
