"""Architecture registry: every assigned arch + the paper's own eval model.

Each arch module exposes ``SPEC: ArchSpec`` with the exact assigned config,
a reduced smoke config (<=2 layers, d_model<=512, <=4 experts), and the
policy knobs the launcher needs (hierarchical-FL mode for models whose
TP replica exceeds a pod slice; long_500k eligibility per DESIGN.md §4).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "internvl2-1b",
    "deepseek-v3-671b",
    "qwen1.5-32b",
    "hubert-xlarge",
    "gemma2-27b",
    "qwen2-moe-a2.7b",
    "deepseek-coder-33b",
    "recurrentgemma-2b",
    "xlstm-350m",
    "gemma2-2b",
]


@dataclass(frozen=True)
class ArchSpec:
    model: ModelConfig
    smoke: ModelConfig
    # "per_data": every index of the data axis is one FL client (default).
    # "per_pod": the whole pod slice is one client (hierarchical cross-silo
    #            mode for models whose TP replica exceeds 16 chips).
    client_mode: str = "per_data"
    # long_500k policy: "native" (sub-quadratic by architecture),
    # "variant" (sliding-window variant, flagged deviation), "skip".
    long_500k: str = "variant"
    has_decode: bool = True
    notes: str = ""


_CACHE: Dict[str, ArchSpec] = {}


def get_arch(name: str) -> ArchSpec:
    if name not in _CACHE:
        mod_name = name.replace("-", "_").replace(".", "_")
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        _CACHE[name] = mod.SPEC
    return _CACHE[name]


def all_archs() -> Dict[str, ArchSpec]:
    return {n: get_arch(n) for n in ARCH_IDS}
