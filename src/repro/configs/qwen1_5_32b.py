"""qwen1.5-32b [dense]: llama-arch with QKV bias [hf:Qwen/Qwen1.5-0.5B
family card]. long_500k via flagged sliding-window variant."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    long_context_variant_window=4096,
    source="hf:Qwen/Qwen1.5-32B",
)

smoke = ModelConfig(
    name="qwen1.5-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=320,
    vocab_size=512,
    qkv_bias=True,
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="variant",
                notes="long_500k via sliding-window variant")
