"""xlstm-350m [ssm]: alternating mLSTM (matrix memory) / sLSTM blocks
[arXiv:2405.04517]. long_500k native: decode state is O(1). sLSTM core is
replicated over the model axis (4-head block-diag recurrence, DESIGN.md)."""
from repro.configs.base import ModelConfig, XLSTMConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    xlstm=XLSTMConfig(pattern=("mlstm", "slstm"), mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0),
    source="arXiv:2405.04517",
)

smoke = ModelConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    block_pattern=("mlstm", "slstm"),
    xlstm=XLSTMConfig(),
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="native",
                notes="sLSTM core replicated over model axis; long_500k native")
