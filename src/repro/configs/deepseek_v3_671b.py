"""deepseek-v3-671b [moe]: MLA + 1 shared/256 routed top-8 MoE + MTP
[arXiv:2412.19437].

Deviations (DESIGN.md §7): all 61 layers are MoE (the real model's first 3
are dense) so the layer stack scans uniformly. MLA dims follow the report
(kv_lora 512, q_lora 1536, rope 64, nope/v 128). FL runs in hierarchical
per-pod client mode: a 16-way-TP replica cannot hold 671B params, so the
whole pod slice is one cross-silo client with internal data parallelism.
long_500k is allowed natively: the MLA latent cache is 576 floats/position
and decode cost is linear in context."""
from repro.configs.base import MLAConfig, MTPConfig, ModelConfig, MoEConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=0,
    vocab_size=129280,
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, num_shared_experts=1,
                  d_ff_expert=2048, d_ff_shared=2048, capacity_factor=1.25),
    mtp=MTPConfig(depth=1, loss_weight=0.3),
    source="arXiv:2412.19437",
)

smoke = ModelConfig(
    name="deepseek-v3-671b-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=0,
    vocab_size=512,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                  nope_head_dim=32, v_head_dim=32),
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                  d_ff_expert=64, d_ff_shared=64, capacity_factor=2.0),
    mtp=MTPConfig(depth=1, loss_weight=0.3),
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, client_mode="per_pod",
                long_500k="native",
                notes="all-MoE stack (real model: first 3 dense); per-pod FL client")
