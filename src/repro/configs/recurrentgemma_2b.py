"""recurrentgemma-2b [hybrid]: Griffin — RG-LRU + local attention, pattern
(rec, rec, attn) [arXiv:2402.19427]. 26 layers = 8 full periods + 2 tail
recurrent layers (handled unscanned). long_500k native: recurrent state is
O(1), attention layers are windowed (2048)."""
from repro.configs.base import ModelConfig, RGLRUConfig
from repro.configs.registry import ArchSpec

config = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    attn_pattern=(2048,),
    sliding_window=2048,
    act="gelu",
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    source="arXiv:2402.19427",
)

smoke = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    block_pattern=("rglru", "attn"),
    attn_pattern=(16,),
    act="gelu",
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=128, conv_width=4),
    dtype="float32",
)

SPEC = ArchSpec(model=config, smoke=smoke, long_500k="native",
                notes="RG-LRU+local attn; long_500k native")
