"""Production training driver: federated LM training on a jax mesh.

On real TPU hardware this drives the full production mesh; in this
container it runs the same code path on small host meshes (the smoke
configs train end-to-end on CPU). Examples:

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --rounds 20 --algorithm fedcams --compressor topk
    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
        --dp 4 --tp 2 --devices 8 --rounds 10
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-trainable)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (set before jax import)")
    ap.add_argument("--algorithm", default="fedcams")
    ap.add_argument("--compressor", default="topk")
    ap.add_argument("--ratio", type=float, default=1.0 / 64.0)
    ap.add_argument("--aggregation", default="dense")
    ap.add_argument("--agg-groups", type=int, default=1,
                    help="two-level hierarchical sparse aggregation "
                         "(DESIGN.md §scale-out): split the --dp clients "
                         "into this many edge groups; each group merges its "
                         "members' (vals, idx) selections into one dense "
                         "partial and only the g partials reach the root. "
                         "Requires a sparse --compressor and dp %% groups "
                         "== 0; 1 = flat single-level aggregation")
    ap.add_argument("--mesh-sparse-impl", default="auto",
                    choices=("auto", "kernel", "jnp"),
                    help="sparse-aggregation selection provider (DESIGN.md "
                         "§3): the fused Pallas topk_ef_sparse kernel vs "
                         "the jnp Compressor.select path; auto = kernel "
                         "where it compiles (TPU), jnp elsewhere. NB "
                         "forcing 'kernel' implies --use-kernels (the "
                         "whole KernelImpl: fused server update + EF too)")
    ap.add_argument("--fused-ingest", default="auto",
                    choices=("auto", "kernel", "jnp", "off"),
                    help="one-pass fused server ingest (DESIGN.md §3): "
                         "scatter-mean + FedAMS update in a single "
                         "read-modify-write over optimizer state, no "
                         "dense mean delta. auto = fuse where the round "
                         "is eligible (sparse blocktopk aggregation), "
                         "kernel Pallas where it compiles (TPU). NB "
                         "forcing 'kernel' implies --use-kernels")
    ap.add_argument("--server-state-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"),
                    help="server second-moment (v, v̂) storage dtype; "
                         "bf16 halves optimizer-state HBM residency "
                         "(int8-blockscale is FedSim-only)")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-opt", default="sgd",
                    choices=("sgd", "sgdm", "prox"),
                    help="local-update rule (core/local.py, DESIGN.md §8)")
    ap.add_argument("--local-momentum", type=float, default=0.9,
                    help="heavy-ball beta for --local-opt sgdm")
    ap.add_argument("--prox-mu", type=float, default=0.01,
                    help="proximal strength for --local-opt prox")
    ap.add_argument("--eta-l-decay", type=float, default=1.0,
                    help="per-round local LR decay (round t trains at "
                         "eta_l * decay^t; 1.0 = constant)")
    ap.add_argument("--local-steps-min", type=int, default=0,
                    help="heterogeneous per-client local work: client i "
                         "runs K_i ~ U{min..K} steps (0 = homogeneous)")
    ap.add_argument("--participating", type=int, default=0)
    ap.add_argument("--crash-prob", type=float, default=0.0,
                    help="fault injection (DESIGN.md §robustness): P(a "
                         "client crashes per round); crashed clients drop "
                         "out of the masked survivor aggregate and keep "
                         "stale EF residuals")
    ap.add_argument("--corrupt-prob", type=float, default=0.0,
                    help="P(a delivered payload was damaged in transit); "
                         "the server validates before ingest and rejects "
                         "offenders (needs --aggregation sparse with a "
                         "topk-family --compressor)")
    ap.add_argument("--corrupt-mode", default="nan",
                    choices=("nan", "inf", "bitflip", "truncate"))
    ap.add_argument("--max-update-norm", type=float, default=0.0,
                    help="per-client L2 clip applied to validated payloads "
                         "before ingest (0 = off)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="FedSim wire-mode only; the mesh driver rejects "
                         "it (no transport clock) — model stragglers as "
                         "crashes here")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="FedSim wire-mode only (DESIGN.md §11): fire a "
                         "buffered async aggregation every this-many "
                         "deliveries instead of waiting for the cohort "
                         "(0 = synchronous); the mesh driver rejects it")
    ap.add_argument("--staleness-weight", default="inv_sqrt",
                    choices=("inv_sqrt", "uniform", "inv_linear", "exp"),
                    help="async flush weight w(τ) per buffered entry, τ = "
                         "server versions since its dispatch")
    ap.add_argument("--eta", type=float, default=0.5)
    ap.add_argument("--eta-l", type=float, default=0.05)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--scan-rounds", type=int, default=0,
                    help="scan this many rounds per device dispatch "
                         "(0/1 = one jitted call per round)")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.configs import FedConfig, TrainConfig
    from repro.configs.registry import get_arch
    from repro.core.mesh import (build_fed_round, fed_batch_defs,
                                 fed_state_defs, init_fed_state)
    from repro.data.synthetic import FederatedLMData
    from repro.kernels.ops import KernelImpl
    from repro.launch.mesh import make_mesh
    from repro.models import params as pdefs
    from repro.models.model import Model
    from repro.sharding.rules import ParallelContext

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    num_clients = args.dp
    if args.agg_groups > 1:
        # two-level aggregation: the client axis splits into (group, member)
        # so tier 1 gathers run over "data" and tier 2 over "cgroup"
        if args.dp % args.agg_groups:
            ap.error(f"--dp {args.dp} not divisible by "
                     f"--agg-groups {args.agg_groups}")
        mesh = make_mesh((args.agg_groups, args.dp // args.agg_groups,
                          args.tp), ("cgroup", "data", "model"))
        client_axes = ("cgroup", "data")
    else:
        mesh = make_mesh((args.dp, args.tp), ("data", "model"))
        client_axes = ("data",) if args.dp > 1 else ()
    if args.deadline_s > 0:
        ap.error("--deadline-s is FedSim wire-mode only — the mesh driver "
                 "has no transport clock to cut against; use --crash-prob "
                 "to model dropouts here")
    if args.async_buffer > 0:
        ap.error("--async-buffer is FedSim wire-mode only — the event-"
                 "driven buffered engine needs the simulated transport "
                 "clock's per-client delivery times, which the mesh "
                 "driver does not model")
    fault = None
    if args.crash_prob > 0 or args.corrupt_prob > 0 \
            or args.max_update_norm > 0:
        from repro.comm.faults import FaultConfig
        fault = FaultConfig(crash_prob=args.crash_prob,
                            corrupt_prob=args.corrupt_prob,
                            corrupt_mode=args.corrupt_mode,
                            max_update_norm=args.max_update_norm,
                            seed=args.fault_seed)
    fed = FedConfig(algorithm=args.algorithm, compressor=args.compressor,
                    compress_ratio=args.ratio, aggregation=args.aggregation,
                    agg_groups=args.agg_groups,
                    mesh_sparse_impl=args.mesh_sparse_impl,
                    fused_ingest=args.fused_ingest,
                    server_state_dtype=args.server_state_dtype,
                    local_steps=args.local_steps, num_clients=num_clients,
                    local_opt=args.local_opt,
                    local_momentum=args.local_momentum,
                    prox_mu=args.prox_mu, eta_l_decay=args.eta_l_decay,
                    local_steps_min=args.local_steps_min,
                    participating=args.participating, eta=args.eta,
                    eta_l=args.eta_l,
                    client_axes=client_axes,
                    # the γ diagnostic consumes the full-cohort dense mean,
                    # which a partial (fault-tolerant) round never computes
                    track_gamma=fault is None,
                    fault=fault)
    train = TrainConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                        rounds=args.rounds, remat_policy="none")
    model = Model(cfg, tp=args.tp)
    ctx = ParallelContext(model_axis="model" if args.tp > 1 else None,
                          tp=args.tp, client_axes=fed.client_axes,
                          num_clients=fed.num_clients)

    # forcing the kernel selection provider implies constructing the whole
    # KernelImpl — build_fed_round then also routes the server update and
    # dense-path EF through the fused kernels, exactly as --use-kernels
    kernel_impl = (KernelImpl() if args.use_kernels
                   or args.mesh_sparse_impl == "kernel"
                   or args.fused_ingest == "kernel" else None)
    rnd = build_fed_round(model, fed, train, ctx, kernel_impl=kernel_impl)
    sdefs = fed_state_defs(model, fed)
    state_specs = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
    bdefs = fed_batch_defs(model, fed, train)
    batch_specs = jax.tree.map(lambda d: d.spec, bdefs, is_leaf=pdefs.is_def)
    # donate the federated state: params/opt-moments/EF errors update in
    # place instead of being copied every round
    from repro.core.mesh import mesh_metric_specs
    step = jax.jit(compat.shard_map(rnd, mesh=mesh,
                                 in_specs=(state_specs, batch_specs, P()),
                                 out_specs=(state_specs,
                                            mesh_metric_specs(fed)),
                                 check_vma=True),
                   donate_argnums=(0,))
    scan_step = None
    if args.scan_rounds and args.scan_rounds > 1:
        from repro.core.mesh import build_fed_rounds_scan, scan_batch_specs
        scan_step = jax.jit(compat.shard_map(
            build_fed_rounds_scan(rnd), mesh=mesh,
            in_specs=(state_specs, scan_batch_specs(batch_specs), P(None)),
            out_specs=(state_specs, mesh_metric_specs(fed, scan=True)),
            check_vma=True), donate_argnums=(0,))
    state = init_fed_state(model, fed, jax.random.PRNGKey(train.seed))
    nparams = sum(int(np.prod(l.shape))
                  for l in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={nparams/1e6:.1f}M clients={num_clients} "
          f"algo={fed.algorithm}/{fed.compressor} mesh={args.dp}x{args.tp}")

    data = FederatedLMData(num_clients=max(num_clients, 1),
                           vocab_size=cfg.vocab_size, seed=train.seed)
    t0 = time.time()
    if scan_step is not None:
        from repro.core.mesh import stage_mesh_rounds
        r = 0
        while r < train.rounds:
            chunk = min(args.scan_rounds, train.rounds - r)
            batch, seeds = stage_mesh_rounds(data, r, chunk, fed.local_steps,
                                             train.global_batch,
                                             train.seq_len)
            state, met = scan_step(state, batch, seeds)
            losses = np.asarray(met["loss"])  # one sync per chunk
            for i in range(chunk):
                rr = r + i
                if rr % args.log_every == 0 or rr == train.rounds - 1:
                    print(f"round {rr:4d}  loss {float(losses[i]):8.4f}  "
                          f"({time.time() - t0:.1f}s)")
            r += chunk
    else:
        for r in range(train.rounds):
            raw = data.mesh_batch(r, fed.local_steps, train.global_batch,
                                  train.seq_len)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            state, met = step(state, batch, jnp.int32(r))
            if r % args.log_every == 0 or r == train.rounds - 1:
                extra = ""
                if "survivors" in met:
                    extra = (f"surv {float(met['survivors']):3.0f}  "
                             f"rej {float(met['rejected']):3.0f}  ")
                print(f"round {r:4d}  loss {float(met['loss']):8.4f}  "
                      f"{extra}({time.time() - t0:.1f}s)")
    if args.checkpoint:
        from repro.checkpoint import save_pytree
        save_pytree(args.checkpoint, jax.device_get(state._asdict()),
                    {"arch": cfg.name, "rounds": train.rounds})
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
