"""Serving driver: prefill a batch of prompts, then greedy-decode.

Runs the same prefill/decode step functions the dry-run lowers for the
production mesh, on a small host mesh (smoke configs on CPU):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.models import params as pdefs
    from repro.models.model import Model, greedy_sample
    from repro.sharding.rules import ParallelContext

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode serving")
    max_len = args.max_len or (args.prompt_len + args.gen)
    model = Model(cfg, tp=args.tp)
    ctx = ParallelContext(model_axis="model" if args.tp > 1 else None,
                          tp=args.tp)

    params = model.init(jax.random.PRNGKey(0))
    if args.checkpoint:
        from repro.checkpoint import load_pytree
        params, meta = load_pytree(args.checkpoint, params)
        print(f"restored {meta}")

    if args.tp > 1:
        mesh = make_mesh((args.tp,), ("model",))
        pspecs = jax.tree.map(lambda d: d.spec, model.defs(),
                              is_leaf=pdefs.is_def)
        cdefs = model.cache_defs(args.batch, max_len, seq_sharded=False)
        cspecs = jax.tree.map(lambda d: d.spec, cdefs, is_leaf=pdefs.is_def)

        prefill = jax.jit(compat.shard_map(
            lambda p, t: model.prefill(p, t, ctx, max_len=max_len),
            mesh=mesh, in_specs=(pspecs, P()),
            out_specs=(P("model"), cspecs)))

        def dstep(p, t, c, pos):
            lg, c2 = model.decode_step(p, t, c, pos, ctx, max_len=max_len)
            return greedy_sample(lg, ctx), c2

        decode = jax.jit(compat.shard_map(
            dstep, mesh=mesh, in_specs=(pspecs, P(), cspecs, P()),
            out_specs=(P(), cspecs)))
    else:
        prefill = jax.jit(lambda p, t: model.prefill(p, t, ctx, max_len=max_len))

        def dstep(p, t, c, pos):
            lg, c2 = model.decode_step(p, t, c, pos, ctx, max_len=max_len)
            return greedy_sample(lg, ctx), c2

        decode = jax.jit(dstep)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    logits, caches = prefill(params, jnp.asarray(prompts))
    tok = greedy_sample(logits, ctx)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [np.asarray(tok[:, 0])]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        tok, caches = decode(params, tok, caches, pos)
        tok = tok[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    t_dec = time.time() - t0
    gen = np.stack(out, 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_dec/max(args.gen-1,1)*1e3:.2f} ms/token  "
          f"({args.batch*(args.gen-1)/max(t_dec,1e-9):.1f} tok/s)")
    for b in range(min(args.batch, 4)):
        print(f"  seq[{b}]: {prompts[b, -4:].tolist()} -> {gen[b].tolist()}")


if __name__ == "__main__":
    main()
