"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — a
layer stack scanned over 61 groups would under-report FLOPs by 61x. This
module parses the compiled HLO text into its computation graph, costs each
computation (dot FLOPs from operand shapes, output-buffer bytes, collective
payload bytes by kind) and resolves the call graph with while-loop
``known_trip_count`` multipliers.

Conventions (held fixed so §Perf deltas are comparable):
  * FLOPs: 2 · prod(out_shape) · prod(contracted lhs dims) per dot;
    non-dot elementwise FLOPs are ignored (sub-percent for transformers).
  * Bytes (HBM traffic proxy):
      - dot: lhs + rhs + output bytes (weight/cache READS are the real
        bottleneck for decode);
      - dynamic-update-slice: the UPDATE operand bytes (XLA aliases the
        target in place — in-loop cache/stack writes cost the slice, not
        the whole buffer);
      - reduce: first-operand + output bytes;
      - other scheduled ops: output bytes (fusion bodies excluded — their
        intermediates stay in registers/VMEM; the fusion's own output
        buffer is counted at the call site).
  * Collectives: payload = output bytes (tuple outputs summed), multiplied
    by loop trip counts like everything else.

A second, additive accounting — ``rw_bytes`` — models total HBM
read+write traffic (reads AND writes both charged), for bandwidth-bound
memory-stream comparisons like the fused-vs-two-pass server ingest
(benchmarks/bench_rounds.py ``server_ingest``). The historic ``bytes``
field is untouched so §Perf deltas stay comparable. rw conventions:
  * skipped ops (parameter/constant/get-tuple-element/bitcast/tuple/
    after-all): 0 — no scheduled traffic;
  * dynamic-update-slice: r + w = 2 × update-slice bytes (aliased);
  * dynamic-slice: r + w = 2 × output bytes (only the slice moves);
  * dot: r = operand bytes, w = output bytes;
  * reduce: r = first-operand bytes, w = output bytes;
  * fusion: loop-aware. Outside any while loop the site charges
    w = output bytes (or the dus alias) + r = Σ operand buffer bytes
    resolved through the symbol table. Inside a while body it charges
    the callee's internal slice/update traffic instead — scatter-style
    loop bodies keep their operands resident and touch a few elements
    per trip, so flat operand charging would multiply whole buffers by
    the trip count;
  * custom-call/call without a resolvable callee: w = output,
    r = Σ operands;
  * collectives: r = w = payload;
  * everything else scheduled: w = output bytes, r = Σ operand bytes.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_BUF_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"?(\d+)"?')
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _shape_elems(shape_str: str) -> int:
    if not shape_str:
        return 1
    n = 1
    for s in shape_str.split(","):
        n *= int(s)
    return n


def _buf_bytes(dtype: str, shape_str: str) -> int:
    return _shape_elems(shape_str) * _DTYPE_BYTES.get(dtype, 0)


@dataclass
class Call:
    callee: str
    kind: str            # fusion | call | while_body | while_cond | branch
    trip: int = 1
    # rw charge candidates for fusion call sites, picked at resolve time:
    # flat = output (or dus alias) + Σ operand buffers — right when the
    # fusion runs once and streams its operands; slice = the callee body's
    # internal slice/update traffic — right inside a while loop, where the
    # operands stay resident and each trip touches a few elements.
    rw_flat: float = 0.0
    rw_slice: float = 0.0


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    rw_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    calls: List[Call] = field(default_factory=list)
    fused: bool = False   # referenced via calls= (bytes not scheduled)
    # if the computation ROOT is (a tuple of) dynamic-update-slice, the
    # fusion output is aliased in place: the caller should charge the
    # update-slice bytes, not the whole buffer.
    out_alias_bytes: Optional[float] = None


@dataclass
class HloCost:
    flops: float
    bytes: float
    coll_bytes: Dict[str, float]
    coll_count: Dict[str, int]
    rw_bytes: float = 0.0

    @property
    def weighted_coll_bytes(self) -> float:
        return sum(b * (2.0 if k == "all-reduce" else 1.0)
                   for k, b in self.coll_bytes.items())


def _operand_bytes(rest: str, symtab: Dict[str, List[Tuple[str, str]]]) -> int:
    """Σ buffer bytes of every %operand that resolves in the symbol table
    (callee/computation references aren't instruction names, so they drop
    out naturally)."""
    total = 0
    for a in re.findall(r"%([\w\.\-]+)", rest):
        bufs = symtab.get(a)
        if bufs:
            total += sum(_buf_bytes(d, s) for d, s in bufs)
    return total


def _parse_out_bufs(rhs: str) -> Tuple[List[Tuple[str, str]], str]:
    """rhs = everything after '='. Returns (output buffers, remainder)."""
    # output is either `type[shape]{layout} opcode(...)` or a tuple
    # `(type[shape]{..}, type[shape]{..}) opcode(...)`.
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        out_part, rest = rhs[:i + 1], rhs[i + 1:]
    else:
        m = re.match(r"^[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?(?::\w+\(\d+\))?\s*", rhs)
        if not m:
            return [], rhs
        out_part, rest = m.group(0), rhs[m.end():]
    return _BUF_RE.findall(out_part), rest.strip()


def parse_hlo(text: str) -> Dict[str, CompCost]:
    comps: Dict[str, CompCost] = {}
    cur: Optional[str] = None
    symtab: Dict[str, List[Tuple[str, str]]] = {}
    entry = None

    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.endswith("{") and "->" in line:
                cur = m.group(2)
                comps[cur] = CompCost()
                symtab = {}
                dus_bytes = {}
                small_ops = {}
                if m.group(1):
                    entry = cur
            continue
        if line == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(2), m.group(3)
        if name not in dus_bytes:
            pass
        bufs, rest = _parse_out_bufs(rhs)
        symtab[name] = bufs
        opm = re.match(r"^([\w\-]+)\(", rest)
        if not opm:
            continue
        op = opm.group(1)
        cc = comps[cur]
        out_bytes = sum(_buf_bytes(d, s) for d, s in bufs)

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLLECTIVES:
            payload = out_bytes
            if base_op == "reduce-scatter":
                # per-device traffic ~= the full (pre-scatter) input
                args = re.findall(r"%([\w\.\-]+)",
                                  rest[rest.find("(") + 1:rest.find(")")])
                first = symtab.get(args[0]) if args else None
                if first:
                    payload = sum(_buf_bytes(d, s) for d, s in first)
            cc.coll[base_op] = cc.coll.get(base_op, 0.0) + payload
            cc.coll_count[base_op] = cc.coll_count.get(base_op, 0) + 1
            cc.bytes += out_bytes
            cc.rw_bytes += 2.0 * payload
            continue
        if op.endswith("-done"):
            continue

        if op == "tuple":
            if m.group(1):  # ROOT tuple: alias if every element is a dus
                args = re.findall(r"%([\w\.\-]+)",
                                  rest[len(op) + 1:rest.find(")")])
                if args and all(a in dus_bytes or a in small_ops
                                for a in args):
                    cc.out_alias_bytes = sum(
                        dus_bytes.get(a, small_ops.get(a, 0.0))
                        for a in args)
            continue
        if op in ("parameter", "constant", "get-tuple-element",
                  "bitcast", "after-all"):
            continue

        if op == "dot":
            # operand shapes via the symbol table
            args = re.findall(r"%([\w\.\-]+)", rest[len(op) + 1:rest.find(")")])
            lhs_shape = None
            operand_bytes = 0
            for ai, a in enumerate(args[:2]):
                b2 = symtab.get(a)
                if b2:
                    operand_bytes += sum(_buf_bytes(d, s) for d, s in b2)
                    if ai == 0:
                        lhs_shape = b2[0][1]
            cdims = _DIMS_RE.search(rest)
            contracted = 1
            if lhs_shape and cdims:
                dims = [int(x) for x in cdims.group(1).split(",") if x]
                sizes = [int(x) for x in lhs_shape.split(",") if x]
                for dim in dims:
                    if dim < len(sizes):
                        contracted *= sizes[dim]
            out_elems = sum(_shape_elems(s) for _, s in bufs)
            cc.flops += 2.0 * out_elems * contracted
            cc.bytes += out_bytes + operand_bytes
            cc.rw_bytes += out_bytes + operand_bytes
            continue

        if op == "dynamic-update-slice":
            # in-place aliased write: traffic = the update slice (operand 1)
            args = re.findall(r"%([\w\.\-]+)",
                              rest[len(op) + 1:rest.find(")")])
            upd = symtab.get(args[1]) if len(args) > 1 else None
            ub = sum(_buf_bytes(d, s) for d, s in upd) if upd else 0
            dus_bytes[name] = ub
            cc.bytes += ub
            cc.rw_bytes += 2.0 * ub
            if m.group(1):  # ROOT dus => fusion output aliased
                cc.out_alias_bytes = ub
            continue

        if op == "dynamic-slice":
            # only the slice moves: r = w = output bytes
            if out_bytes <= 4096:
                small_ops[name] = float(out_bytes)
            cc.bytes += out_bytes
            cc.rw_bytes += 2.0 * out_bytes
            continue

        if op == "reduce":
            args = re.findall(r"%([\w\.\-]+)",
                              rest[len(op) + 1:rest.find(")")])
            first = symtab.get(args[0]) if args else None
            fb = sum(_buf_bytes(d, s) for d, s in first) if first else 0
            cc.bytes += fb + out_bytes
            cc.rw_bytes += fb + out_bytes
            continue

        if op == "while":
            trip = 1
            tm = _TRIP_RE.search(rest)
            if tm:
                trip = int(tm.group(1))
            bm = re.search(r"body=%?([\w\.\-]+)", rest)
            cm = re.search(r"condition=%?([\w\.\-]+)", rest)
            if bm:
                cc.calls.append(Call(bm.group(1), "while_body", trip))
            if cm:
                cc.calls.append(Call(cm.group(1), "while_cond", trip))
            continue

        if op in ("fusion", "custom-call", "call", "async-start"):
            fm = re.search(r"(?:calls|to_apply|called_computation)=%?([\w\.\-]+)",
                           rest)
            alias = None
            if fm:
                callee = comps.setdefault(fm.group(1), CompCost())
                cc.calls.append(Call(fm.group(1), "fusion", 1))
                alias = callee.out_alias_bytes
            cc.bytes += out_bytes if alias is None else alias
            if fm:
                # rw is charged at resolve time: flat (operands + output)
                # when this site runs outside any while loop, slice-level
                # (the callee's internal ds/dus traffic) inside one — loop
                # bodies keep their operands resident across trips
                site = cc.calls[-1]
                site.rw_flat = ((out_bytes if alias is None else alias)
                                + _operand_bytes(rest, symtab))
                site.rw_slice = callee.rw_bytes
            else:
                cc.rw_bytes += out_bytes + _operand_bytes(rest, symtab)
            continue

        if op == "conditional":
            for br in re.findall(r"%([\w\.\-]+)",
                                 rest[rest.find("branch_computations"):]) or []:
                cc.calls.append(Call(br, "branch", 1))
            cc.bytes += out_bytes
            cc.rw_bytes += out_bytes
            continue

        # reduce/sort/map to_apply bodies are scalar lambdas — skip linking
        if out_bytes <= 4096:
            small_ops[name] = float(out_bytes)
        cc.bytes += out_bytes
        cc.rw_bytes += out_bytes + _operand_bytes(rest, symtab)

    # mark fused computations (their own bytes are not scheduled memory)
    for c in comps.values():
        for call in c.calls:
            if call.kind == "fusion" and call.callee in comps:
                comps[call.callee].fused = True

    comps["__entry__"] = comps.get(entry, CompCost()) if entry else CompCost()
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def resolve_cost(comps: Dict[str, CompCost]) -> HloCost:
    entry = comps.get("__entry_name__")
    memo: Dict[Tuple[str, bool],
               Tuple[float, float, float,
                     Dict[str, float], Dict[str, int]]] = {}

    def visit(name: str, stack=(), in_loop: bool = False
              ) -> Tuple[float, float, float,
                         Dict[str, float], Dict[str, int]]:
        key = (name, in_loop)
        if key in memo:
            return memo[key]
        if name in stack or name not in comps or name.startswith("__"):
            return 0.0, 0.0, 0.0, {}, {}
        c = comps[name]
        flops = c.flops
        byts = 0.0 if c.fused else c.bytes
        # fused bodies keep intermediates in registers/VMEM: their rw
        # traffic is charged at the fusion call site, not here
        rw = 0.0 if c.fused else c.rw_bytes
        coll = dict(c.coll)
        cnt = dict(c.coll_count)
        for call in c.calls:
            child_loop = (in_loop
                          or call.kind in ("while_body", "while_cond"))
            f, b, r, co, cn = visit(call.callee, stack + (name,),
                                    child_loop)
            mult = call.trip
            flops += f * mult
            byts += b * mult
            rw += r * mult
            if call.kind == "fusion":
                rw += (call.rw_slice if in_loop else call.rw_flat) * mult
            for k, v in co.items():
                coll[k] = coll.get(k, 0.0) + v * mult
            for k, v in cn.items():
                cnt[k] = cnt.get(k, 0) + v * mult
        memo[key] = (flops, byts, rw, coll, cnt)
        return memo[key]

    if not isinstance(entry, str):
        return HloCost(0.0, 0.0, {}, {})
    f, b, r, co, cn = visit(entry)
    return HloCost(flops=f, bytes=b, coll_bytes=co, coll_count=cn,
                   rw_bytes=r)


def analyze(text: str) -> HloCost:
    return resolve_cost(parse_hlo(text))
