"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), per the brief:

    compute    = HLO_FLOPs_per_chip / peak_flops_bf16
    memory     = HLO_bytes_per_chip / hbm_bw
    collective = Σ collective_bytes × factor / ici_bw_per_link

The bandwidth/peak constants come from a :class:`~repro.launch.mesh.
BackendSpec` (``launch.mesh.BACKEND_SPECS``); the default is tpu_v5e
(197e12 / 819e9 / 50e9 — the paper's reference part and the historical
hardwired numbers), overridable per call via ``spec=`` or globally via
the ``REPRO_BACKEND`` env var.

``cost_analysis()`` is the per-device SPMD program, so its flops/bytes are
already per-chip. Collective bytes are parsed from the compiled HLO: the sum
of output-buffer sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with the ring-bandwidth convention
all-reduce ≈ 2× payload (reduce-scatter + all-gather phases) and 1×
otherwise. The convention is held fixed across all measurements so §Perf
deltas are comparable.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK,  # noqa: F401
                               PEAK_FLOPS_BF16, BackendSpec, backend_spec)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one `dtype[shape]` buffer, e.g. f32[16,1024]{1,0}
_BUF_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _buf_bytes(dtype: str, shape_str: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if shape_str:
        for s in shape_str.split(","):
            n *= int(s)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def weighted_bytes(self) -> float:
        total = 0.0
        for kind, b in self.bytes_by_kind.items():
            total += b * (2.0 if kind == "all-reduce" else 1.0)
        return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        kind = None
        for k in _COLLECTIVES:
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        eq = line.find("=")
        opn = line.find(f" {kind}")
        if eq < 0 or opn < 0:
            continue
        out_part = line[eq + 1:opn]
        total = sum(_buf_bytes(d, s) for d, s in _BUF_RE.findall(out_part))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + total
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO flops × chips)
    chips: int

    def to_dict(self):
        return dict(self.__dict__)


def model_flops_for(cfg, shape_kind: str, tokens: float, local_steps: int = 1):
    """Analytic MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D fwd."""
    n_active = cfg.num_active_params()
    if shape_kind == "train":
        return 6.0 * n_active * tokens * local_steps
    return 2.0 * n_active * tokens


def roofline_from_hlo(hc, *, chips: int, model_flops: float,
                      spec: BackendSpec | None = None) -> Roofline:
    """Preferred path: trip-count-aware HloCost from launch.hlo_analysis.

    ``spec`` selects the backend bandwidth/peak constants; ``None`` keeps
    the default resolution (``REPRO_BACKEND`` env var, else tpu_v5e — the
    historical hardwired numbers)."""
    return _mk_roofline(hc.flops, hc.bytes, hc.weighted_coll_bytes,
                        chips=chips, model_flops=model_flops, spec=spec)


def roofline_from(cost: Dict, stats: CollectiveStats, *, chips: int,
                  model_flops: float,
                  spec: BackendSpec | None = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = stats.weighted_bytes
    return _mk_roofline(flops, hbm, coll, chips=chips,
                        model_flops=model_flops, spec=spec)


def _mk_roofline(flops, hbm, coll, *, chips: int, model_flops: float,
                 spec: BackendSpec | None = None) -> Roofline:
    spec = spec or backend_spec()
    compute_s = flops / spec.peak_flops_bf16
    memory_s = hbm / spec.hbm_bw
    collective_s = coll / spec.ici_bw_per_link
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, 1.0)
    return Roofline(flops_per_chip=flops, hbm_bytes_per_chip=hbm,
                    collective_bytes=coll, compute_s=compute_s,
                    memory_s=memory_s, collective_s=collective_s,
                    dominant=dominant, model_flops=model_flops,
                    useful_ratio=useful, chips=chips)
