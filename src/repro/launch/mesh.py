"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the caller controls
when devices are enumerated (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import — see launch/dryrun.py).
"""
from __future__ import annotations

import jax

# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s per link


def _mesh_kwargs(n_axes: int) -> dict:
    """Version shim: ``jax.sharding.AxisType`` (and the ``axis_types=``
    kwarg of ``jax.make_mesh``) only exist on newer jax; on 0.4.x every
    mesh axis is implicitly Auto, so omitting the kwarg is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small local runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_mesh_kwargs(len(axes)))
