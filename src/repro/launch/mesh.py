"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the caller controls
when devices are enumerated (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import — see launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import os

import jax


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Per-chip roofline constants for one accelerator backend."""
    name: str
    peak_flops_bf16: float     # FLOP/s
    hbm_bw: float              # B/s
    ici_bw_per_link: float     # B/s per link (host interconnect for cpu)


#: Known backends. The numbers are per chip; ``cpu`` is a rough stand-in
#: for the container host (measurements on it are relative, not absolute).
BACKEND_SPECS = {
    "tpu_v5e": BackendSpec("tpu_v5e", peak_flops_bf16=197e12,
                           hbm_bw=819e9, ici_bw_per_link=50e9),
    "tpu_v4": BackendSpec("tpu_v4", peak_flops_bf16=275e12,
                          hbm_bw=1228e9, ici_bw_per_link=100e9),
    "cpu": BackendSpec("cpu", peak_flops_bf16=2e12,
                       hbm_bw=50e9, ici_bw_per_link=10e9),
}

DEFAULT_BACKEND = "tpu_v5e"


def backend_spec(name: str | None = None) -> BackendSpec:
    """Resolve a :class:`BackendSpec` by name; ``None`` reads the
    ``REPRO_BACKEND`` env var and falls back to ``tpu_v5e`` (the paper's
    reference part, and the historical hardwired constants)."""
    name = name or os.environ.get("REPRO_BACKEND") or DEFAULT_BACKEND
    try:
        return BACKEND_SPECS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}: pick one of "
            f"{sorted(BACKEND_SPECS)} (or extend BACKEND_SPECS)") from None


# Back-compat module constants (tpu_v5e): existing call sites and §Perf
# numbers keep their historical meaning.
PEAK_FLOPS_BF16 = BACKEND_SPECS["tpu_v5e"].peak_flops_bf16
HBM_BW = BACKEND_SPECS["tpu_v5e"].hbm_bw
ICI_BW_PER_LINK = BACKEND_SPECS["tpu_v5e"].ici_bw_per_link


def _mesh_kwargs(n_axes: int) -> dict:
    """Version shim: ``jax.sharding.AxisType`` (and the ``axis_types=``
    kwarg of ``jax.make_mesh``) only exist on newer jax; on 0.4.x every
    mesh axis is implicitly Auto, so omitting the kwarg is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small local runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_mesh_kwargs(len(axes)))
