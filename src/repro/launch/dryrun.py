import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract roofline terms.

This is the proof (without hardware) that the distribution config is
coherent: a sharding mismatch, an unsupported collective or a spec error
fails the compile. Results stream into a JSON file so long sweeps are
resumable.

Usage:
    python -m repro.launch.dryrun --arch all --shape all --mesh single \
        --out results/dryrun.json
    python -m repro.launch.dryrun --arch deepseek-v3-671b --shape train_4k \
        --mesh multi
"""

import argparse
import json
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--algorithm", default="fedcams")
    ap.add_argument("--compressor", default="topk")
    ap.add_argument("--aggregation", default="dense")
    ap.add_argument("--ratio", type=float, default=1.0 / 64.0)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--delta-dtype", default="float32",
                    help="wire dtype for the dense client collective")
    ap.add_argument("--xlstm-chunkwise", type=int, default=0,
                    help="chunk size for chunkwise-recurrent mLSTM (0=off)")
    ap.add_argument("--moe-cf", type=float, default=0.0,
                    help="override MoE capacity factor (0=config default)")
    ap.add_argument("--tp-collective", default="psum",
                    choices=["psum", "rs_ag"])
    ap.add_argument("--shard-server-state", action="store_true")
    ap.add_argument("--overwrite", action="store_true",
                    help="recompute cases already present in --out")
    args = ap.parse_args()

    # imports AFTER the XLA flag is set
    import jax  # noqa: E402
    from repro.configs import ARCH_IDS, INPUT_SHAPES, FedConfig, TrainConfig
    from repro.configs.registry import get_arch
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import model_flops_for, roofline_from_hlo
    from repro.launch.steps import build_step, shape_allowed

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    import dataclasses

    fed = FedConfig(algorithm=args.algorithm, compressor=args.compressor,
                    compress_ratio=args.ratio, aggregation=args.aggregation,
                    local_steps=args.local_steps, delta_dtype=args.delta_dtype,
                    shard_server_state=args.shard_server_state)
    train = TrainConfig(remat_policy=args.remat,
                        tp_collective=args.tp_collective)

    def apply_variants(spec):
        cfg = spec.model
        if args.xlstm_chunkwise and cfg.xlstm is not None:
            cfg = dataclasses.replace(
                cfg, xlstm=dataclasses.replace(
                    cfg.xlstm, chunkwise=True,
                    chunk_size=args.xlstm_chunkwise))
        if args.moe_cf and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe,
                                             capacity_factor=args.moe_cf))
        if cfg is not spec.model:
            spec = dataclasses.replace(spec, model=cfg)
        return spec

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    mesh_cache = {}
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        if multi not in mesh_cache:
            mesh_cache[multi] = make_production_mesh(multi_pod=multi)
        mesh = mesh_cache[multi]
        chips = mesh.devices.size
        for arch in archs:
            spec = apply_variants(get_arch(arch))
            for shape_name in shapes:
                shape = INPUT_SHAPES[shape_name]
                key = f"{args.tag}/{mesh_name}/{arch}/{shape_name}"
                cached = results.get(key, {})
                if cached.get("status") in ("ok", "skipped") and not args.overwrite:
                    print(f"[skip-cached] {key}")
                    continue
                ok, why = shape_allowed(spec, shape)
                if not ok:
                    results[key] = {"status": "skipped", "reason": why}
                    print(f"[skip] {key}: {why}")
                    _flush(args.out, results)
                    continue
                t0 = time.time()
                try:
                    bundle = build_step(spec, shape, mesh, fed, train,
                                        chunk=args.chunk)
                    lowered = bundle.lower()
                    t_lower = time.time() - t0
                    compiled = lowered.compile()
                    t_compile = time.time() - t0 - t_lower
                    try:
                        mem = compiled.memory_analysis()
                        mem_d = {
                            "argument_size": getattr(mem, "argument_size_in_bytes", None),
                            "output_size": getattr(mem, "output_size_in_bytes", None),
                            "temp_size": getattr(mem, "temp_size_in_bytes", None),
                            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
                        }
                    except Exception as e:  # pragma: no cover
                        mem_d = {"error": str(e)}
                    cost = compiled.cost_analysis() or {}
                    cost = {k: float(v) for k, v in cost.items()
                            if isinstance(v, (int, float)) and k in
                            ("flops", "bytes accessed", "transcendentals")}
                    hc = analyze(compiled.as_text())
                    if shape.kind == "train":
                        tokens = shape.global_batch * shape.seq_len
                        mf = model_flops_for(bundle.model.cfg, "train", tokens,
                                             fed.local_steps)
                    elif shape.kind == "prefill":
                        mf = model_flops_for(bundle.model.cfg, "prefill",
                                             shape.global_batch * shape.seq_len)
                    else:
                        mf = model_flops_for(bundle.model.cfg, "decode",
                                             shape.global_batch)
                    rl = roofline_from_hlo(hc, chips=chips, model_flops=mf)
                    results[key] = {
                        "status": "ok",
                        "description": bundle.description,
                        "lower_s": round(t_lower, 1),
                        "compile_s": round(t_compile, 1),
                        "memory": mem_d,
                        "xla_cost_analysis_raw": cost,
                        "collectives": {
                            "bytes_by_kind": hc.coll_bytes,
                            "count_by_kind": hc.coll_count,
                        },
                        "roofline": rl.to_dict(),
                    }
                    print(f"[ok] {key}: compute={rl.compute_s:.3e}s "
                          f"memory={rl.memory_s:.3e}s "
                          f"collective={rl.collective_s:.3e}s "
                          f"dominant={rl.dominant} useful={rl.useful_ratio:.2f} "
                          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
                except Exception as e:
                    results[key] = {"status": "error", "error": str(e)[-2000:],
                                    "traceback": traceback.format_exc()[-4000:]}
                    print(f"[ERROR] {key}: {e}")
                _flush(args.out, results)


def _flush(path, results):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


if __name__ == "__main__":
    main()
