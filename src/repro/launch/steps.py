"""Builders that bind (architecture × input shape × mesh) to a lowerable
SPMD step function plus its abstract input specs.

Used by launch/dryrun.py (lower+compile+roofline), launch/train.py and
launch/serve.py (real execution on small meshes). ``input_specs`` follow the
required dry-run pattern: ShapeDtypeStructs with NamedShardings — weak-type
correct, shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.configs.base import (FedConfig, ModelConfig, ShapeConfig,
                                TrainConfig)
from repro.configs.registry import ArchSpec
from repro.core.mesh import (build_fed_round, fed_batch_defs,
                             fed_state_defs)
from repro.models import params as pdefs
from repro.models.model import Model
from repro.sharding.rules import ParallelContext


# ---------------------------------------------------------------------------
# Resolution helpers
# ---------------------------------------------------------------------------


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_fed(spec: ArchSpec, fed: FedConfig, mesh) -> FedConfig:
    """Bind client axes + client count to the mesh per the arch's FL mode."""
    sizes = mesh_axis_sizes(mesh)
    if spec.client_mode == "per_pod":
        axes = tuple(a for a in ("pod",) if a in sizes)
    else:
        axes = tuple(a for a in ("pod", "data") if a in sizes)
    m = 1
    for a in axes:
        m *= sizes[a]
    shard_axes = axes if axes else tuple(a for a in ("data",) if a in sizes)
    shards = 1
    for a in shard_axes:
        shards *= sizes[a]
    return dataclasses.replace(fed, client_axes=axes, num_clients=m,
                               state_shards=shards)


def train_ctx(fed: FedConfig, mesh,
              tp_collective: str = "psum") -> ParallelContext:
    sizes = mesh_axis_sizes(mesh)
    hierarchical = "data" not in fed.client_axes
    return ParallelContext(
        model_axis="model", tp=sizes.get("model", 1),
        data_axis="data" if hierarchical else None,
        dp=sizes.get("data", 1) if hierarchical else 1,
        client_axes=fed.client_axes, num_clients=fed.num_clients,
        tp_collective=tp_collective)


def serve_ctx(mesh, *, seq_sharded: bool) -> ParallelContext:
    sizes = mesh_axis_sizes(mesh)
    return ParallelContext(
        model_axis="model", tp=sizes.get("model", 1),
        seq_axis="data" if seq_sharded else None,
        seq_shards=sizes.get("data", 1) if seq_sharded else 1)


def serve_batch_axes(mesh) -> Tuple[str, ...]:
    sizes = mesh_axis_sizes(mesh)
    return tuple(a for a in ("pod", "data") if a in sizes)


def remap_defs(defs, mapping: Dict[str, Any]):
    """Rewrite mesh-axis names inside ParamDef specs (e.g. "data" ->
    ("pod","data") when a batch dim spreads over two axes)."""

    def one(d: pdefs.ParamDef) -> pdefs.ParamDef:
        spec = P(*(mapping.get(e, e) if isinstance(e, str) else e
                   for e in d.spec))
        return dataclasses.replace(d, spec=spec)

    return jax.tree.map(one, defs, is_leaf=pdefs.is_def)


def variant_for_shape(spec: ArchSpec, shape: ShapeConfig) -> ModelConfig:
    """Apply the (flagged) sliding-window long-context variant if needed."""
    cfg = spec.model
    if shape.name == "long_500k" and spec.long_500k == "variant":
        w = cfg.long_context_variant_window or 4096
        cfg = dataclasses.replace(cfg, attn_pattern=(w,))
    return cfg


def shape_allowed(spec: ArchSpec, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.kind == "decode" and not spec.has_decode:
        return False, "encoder-only architecture: no decode step"
    if shape.name == "long_500k" and spec.long_500k == "skip":
        return False, "pure full-attention / encoder arch: long_500k skipped"
    return True, ""


# ---------------------------------------------------------------------------
# Step bundles
# ---------------------------------------------------------------------------


@dataclass
class StepBundle:
    """A jit-wrapped SPMD step plus abstract inputs for .lower()."""

    fn: Callable
    abstract_args: Tuple
    model: Model
    fed: Optional[FedConfig] = None
    description: str = ""

    def lower(self):
        return self.fn.lower(*self.abstract_args)


def _specs(defs):
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=pdefs.is_def)


def build_train_step(spec: ArchSpec, shape: ShapeConfig, mesh,
                     fed: FedConfig, train: TrainConfig,
                     *, kernel_impl=None, chunk: int = 2048) -> StepBundle:
    """The paper's fed_round as the train step for this (arch, mesh)."""
    assert shape.kind == "train"
    cfg = spec.model
    sizes = mesh_axis_sizes(mesh)
    fed = resolve_fed(spec, fed, mesh)
    train = dataclasses.replace(train, global_batch=shape.global_batch,
                                seq_len=shape.seq_len)
    model = Model(cfg, tp=sizes.get("model", 1))
    ctx = train_ctx(fed, mesh, train.tp_collective)

    sdefs = fed_state_defs(model, fed)
    bdefs = fed_batch_defs(model, fed, train)
    state_specs, batch_specs = _specs(sdefs), _specs(bdefs)

    rnd = build_fed_round(model, fed, train, ctx, chunk=chunk,
                          kernel_impl=kernel_impl)
    from repro.core.mesh import mesh_metric_specs
    fn = jax.jit(compat.shard_map(
        rnd, mesh=mesh,
        in_specs=(state_specs, batch_specs, P()),
        out_specs=(state_specs, mesh_metric_specs(fed)),
        check_vma=True))
    abstract = (pdefs.abstract_params(sdefs, mesh),
                pdefs.abstract_params(bdefs, mesh),
                jax.ShapeDtypeStruct((), jnp.int32))
    return StepBundle(fn=fn, abstract_args=abstract, model=model, fed=fed,
                      description=f"fed_round[{fed.algorithm}/"
                                  f"{fed.compressor}:{fed.aggregation}] "
                                  f"K={fed.local_steps} m={fed.num_clients}")


def build_prefill_step(spec: ArchSpec, shape: ShapeConfig, mesh,
                       *, chunk: int = 2048) -> StepBundle:
    cfg = variant_for_shape(spec, shape)
    sizes = mesh_axis_sizes(mesh)
    model = Model(cfg, tp=sizes.get("model", 1))
    ctx = serve_ctx(mesh, seq_sharded=False)
    baxes = serve_batch_axes(mesh)
    bax = baxes[0] if len(baxes) == 1 else tuple(baxes)

    pdefs_tree = model.defs()
    param_specs = _specs(pdefs_tree)

    if cfg.is_encoder:
        bdefs = {"embeddings": pdefs.ParamDef(
            (shape.global_batch, shape.seq_len, cfg.d_model),
            P(bax, None, None), dtype=cfg.dtype)}
        bspecs = _specs(bdefs)

        def step(params, batch):
            return model.encode(params, batch, ctx, chunk=chunk)

        out_specs = P(bax, None, "model")
        fn = jax.jit(compat.shard_map(step, mesh=mesh,
                                   in_specs=(param_specs, bspecs),
                                   out_specs=out_specs))
        abstract = (model.abstract_params(mesh),
                    pdefs.abstract_params(bdefs, mesh))
        return StepBundle(fn=fn, abstract_args=abstract, model=model,
                          description="encode (encoder-only prefill)")

    cdefs = model.cache_defs(shape.global_batch, shape.seq_len,
                             seq_sharded=False)
    if len(baxes) > 1:
        cdefs = remap_defs(cdefs, {"data": bax})
    cache_specs = _specs(cdefs)
    tok_def = pdefs.ParamDef((shape.global_batch, shape.seq_len),
                             P(bax, None), dtype="int32")

    def step(params, tokens):
        return model.prefill(params, tokens, ctx, max_len=shape.seq_len,
                             chunk=chunk)

    fn = jax.jit(compat.shard_map(
        step, mesh=mesh,
        in_specs=(param_specs, tok_def.spec),
        out_specs=(P(bax, "model"), cache_specs)))
    abstract = (model.abstract_params(mesh),
                pdefs.abstract_params({"t": tok_def}, mesh)["t"])
    return StepBundle(fn=fn, abstract_args=abstract, model=model,
                      description="prefill")


def build_decode_step(spec: ArchSpec, shape: ShapeConfig, mesh,
                      *, chunk: int = 2048) -> StepBundle:
    cfg = variant_for_shape(spec, shape)
    sizes = mesh_axis_sizes(mesh)
    model = Model(cfg, tp=sizes.get("model", 1))
    seq_sharded = shape.name == "long_500k"
    ctx = serve_ctx(mesh, seq_sharded=seq_sharded)
    baxes = serve_batch_axes(mesh)
    bax = (baxes[0] if len(baxes) == 1 else tuple(baxes)) if not seq_sharded else None

    param_specs = _specs(model.defs())
    cdefs = model.cache_defs(shape.global_batch, shape.seq_len,
                             seq_sharded=seq_sharded)
    if not seq_sharded and len(baxes) > 1:
        cdefs = remap_defs(cdefs, {"data": bax})
    cache_specs = _specs(cdefs)
    tok_def = pdefs.ParamDef((shape.global_batch, 1), P(bax, None),
                             dtype="int32")

    def step(params, token, caches, pos):
        return model.decode_step(params, token, caches, pos, ctx,
                                 max_len=shape.seq_len)

    fn = jax.jit(compat.shard_map(
        step, mesh=mesh,
        in_specs=(param_specs, tok_def.spec, cache_specs, P()),
        out_specs=(P(bax, "model"), cache_specs)))
    abstract = (model.abstract_params(mesh),
                pdefs.abstract_params({"t": tok_def}, mesh)["t"],
                pdefs.abstract_params(cdefs, mesh),
                jax.ShapeDtypeStruct((), jnp.int32))
    return StepBundle(fn=fn, abstract_args=abstract, model=model,
                      description="decode" + (" (seq-sharded cache)"
                                              if seq_sharded else ""))


def build_step(spec: ArchSpec, shape: ShapeConfig, mesh, fed: FedConfig,
               train: TrainConfig, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(spec, shape, mesh, fed, train, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(spec, shape, mesh,
                                  chunk=kw.get("chunk", 2048))
    return build_decode_step(spec, shape, mesh, chunk=kw.get("chunk", 2048))
