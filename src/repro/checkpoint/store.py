"""Minimal durable checkpointing: flattened pytree -> .npz + JSON manifest.

The manifest records key paths, shapes and dtypes so a restore can verify
structural compatibility before touching arrays; writes are atomic
(tmp + rename) so an interrupted save never corrupts the previous
checkpoint. Sharded arrays are gathered to host before writing (checkpoints
are taken at the federated-round boundary where everything is addressable).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_pytree(path: str, tree, extra_meta: Dict[str, Any] | None = None):
    """Save ``tree`` to ``path`` (a directory)."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {f"a{i}": np.asarray(v) for i, (k, v) in enumerate(flat.items())}
    manifest = {
        "keys": list(flat.keys()),
        "shapes": [list(np.asarray(v).shape) for v in flat.values()],
        "dtypes": [str(np.asarray(v).dtype) for v in flat.values()],
        "meta": extra_meta or {},
    }
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **arrays)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json.tmp"), "w") as f:
        json.dump(manifest, f)
    os.replace(os.path.join(path, "manifest.json.tmp"),
               os.path.join(path, "manifest.json"))


def load_pytree(path: str, like) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like``. Returns (tree, meta)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    want = {jax.tree_util.keystr(p): l for p, l in flat_like}
    if list(want.keys()) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(want.keys())
        raise ValueError(f"checkpoint structure mismatch; differing keys: "
                         f"{sorted(missing)[:5]} ...")
    leaves = []
    for i, (key, like_leaf) in enumerate(want.items()):
        arr = data[f"a{i}"]
        if tuple(arr.shape) != tuple(np.shape(like_leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(like_leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]
