"""Minimal durable checkpointing: flattened pytree -> .npz + JSON manifest.

The manifest records key paths, shapes and dtypes so a restore can verify
structural compatibility before touching arrays; writes are atomic
(tmp + rename) so an interrupted save never corrupts the previous
checkpoint. Sharded arrays are gathered to host before writing (checkpoints
are taken at the federated-round boundary where everything is addressable).

Also home to :class:`EFStore` — the host-side sharded error-feedback store
behind ``FedConfig.ef_store`` (DESIGN.md §scale-out): per-client EF rows
live in lazily materialized numpy shards with async prefetch, so FedSim's
device footprint is the participating cohort, not (m, d).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_pytree(path: str, tree, extra_meta: Dict[str, Any] | None = None):
    """Save ``tree`` to ``path`` (a directory)."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {f"a{i}": np.asarray(v) for i, (k, v) in enumerate(flat.items())}
    manifest = {
        "keys": list(flat.keys()),
        "shapes": [list(np.asarray(v).shape) for v in flat.values()],
        "dtypes": [str(np.asarray(v).dtype) for v in flat.values()],
        "meta": extra_meta or {},
    }
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **arrays)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json.tmp"), "w") as f:
        json.dump(manifest, f)
    os.replace(os.path.join(path, "manifest.json.tmp"),
               os.path.join(path, "manifest.json"))


def load_pytree(path: str, like) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like``. Returns (tree, meta)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    want = {jax.tree_util.keystr(p): l for p, l in flat_like}
    if list(want.keys()) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(want.keys())
        raise ValueError(f"checkpoint structure mismatch; differing keys: "
                         f"{sorted(missing)[:5]} ...")
    leaves = []
    for i, (key, like_leaf) in enumerate(want.items()):
        arr = data[f"a{i}"]
        if tuple(arr.shape) != tuple(np.shape(like_leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(like_leaf)}")
        want_dt = np.asarray(like_leaf).dtype
        if str(arr.dtype) != manifest["dtypes"][i]:
            raise ValueError(
                f"dtype mismatch for {key}: arrays.npz holds {arr.dtype} "
                f"but the manifest recorded {manifest['dtypes'][i]} — the "
                f"checkpoint files disagree (corrupt or mixed save)")
        if arr.dtype != want_dt:
            raise ValueError(
                f"dtype mismatch for {key}: checkpoint holds {arr.dtype}, "
                f"restore target expects {want_dt} — a silent cast here "
                f"would corrupt optimizer state (e.g. int8 blockscale "
                f"payloads read as counts)")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]


# ===========================================================================
# Host-side sharded error-feedback store (FedConfig.ef_store)
# ===========================================================================


class _Prefetch:
    """One in-flight async gather: ``buf`` is filled by ``thread``."""

    __slots__ = ("idx", "buf", "thread")

    def __init__(self, idx: np.ndarray):
        self.idx = idx
        self.buf: Optional[np.ndarray] = None
        self.thread: Optional[threading.Thread] = None


class EFStore:
    """Host-side sharded (m, d) error-feedback store (DESIGN.md §scale-out).

    Rows (one fp32 error vector per client) live host-side in fixed-size
    numpy shards of ``shard_clients`` rows each, **lazily materialized**: a
    shard allocates only once one of its clients is first written, so a
    m=10^6 store costs O(clients ever selected)·d, not m·d — untouched
    clients read as the zeros they would hold anyway.

    Per round the driver calls :meth:`gather` for the participating rows
    (-> a dense (n, d) block the jitted round consumes), :meth:`scatter` to
    write the updated rows back, and optionally :meth:`prefetch` to
    assemble the *next* round's rows on a background thread while the
    device computes. A scatter that lands while a prefetch is in flight
    patches the overlapping rows in the prefetched buffer, so a client
    participating in consecutive rounds never reads a stale row
    (property-tested in tests/test_scale_out.py).
    """

    def __init__(self, num_clients: int, d: int, shard_clients: int = 256):
        if shard_clients < 1:
            raise ValueError(f"shard_clients={shard_clients} must be >= 1")
        self.num_clients = int(num_clients)
        self.d = int(d)
        self.shard_clients = int(shard_clients)
        self._shards: Dict[int, np.ndarray] = {}
        self._lock = threading.Lock()
        self._pf: Optional[_Prefetch] = None

    @property
    def nbytes(self) -> int:
        """Host bytes actually materialized (lazy shards only)."""
        return sum(s.nbytes for s in self._shards.values())

    def _shard_rows(self, s: int) -> int:
        return min(self.shard_clients,
                   self.num_clients - s * self.shard_clients)

    def _gather_locked(self, idx: np.ndarray) -> np.ndarray:
        out = np.zeros((idx.size, self.d), np.float32)
        for j, c in enumerate(idx):
            s, r = divmod(int(c), self.shard_clients)
            shard = self._shards.get(s)
            if shard is not None:
                out[j] = shard[r]
        return out

    def gather(self, idx) -> np.ndarray:
        """Rows for this round's cohort as a dense (n, d) fp32 block.

        Consumes a matching in-flight :meth:`prefetch` (a non-matching one
        stays queued for the round it was issued for)."""
        idx = np.asarray(idx, np.int64)
        pf = self._pf
        if pf is not None and np.array_equal(pf.idx, idx):
            self._pf = None
            pf.thread.join()
            return pf.buf
        with self._lock:
            return self._gather_locked(idx)

    def prefetch(self, idx) -> None:
        """Start assembling ``gather(idx)`` on a background thread. At most
        one prefetch is in flight; issuing another replaces it."""
        idx = np.asarray(idx, np.int64)
        old = self._pf
        if old is not None and old.thread is not None:
            old.thread.join()
        pf = _Prefetch(idx)

        def work():
            with self._lock:
                pf.buf = self._gather_locked(idx)

        pf.thread = threading.Thread(target=work, daemon=True)
        self._pf = pf
        pf.thread.start()

    def scatter(self, idx, rows) -> None:
        """Write the cohort's updated rows back (allocating shards on first
        touch) and patch any overlapping rows in an in-flight prefetch."""
        idx = np.asarray(idx, np.int64)
        rows = np.asarray(rows, np.float32)
        if rows.shape != (idx.size, self.d):
            raise ValueError(f"scatter rows shape {rows.shape} != "
                             f"({idx.size}, {self.d})")
        pf = self._pf
        if pf is not None:
            pf.thread.join()  # buf is complete before we patch it
        with self._lock:
            for j, c in enumerate(idx):
                s, r = divmod(int(c), self.shard_clients)
                shard = self._shards.get(s)
                if shard is None:
                    shard = self._shards[s] = np.zeros(
                        (self._shard_rows(s), self.d), np.float32)
                shard[r] = rows[j]
            if pf is not None and pf.buf is not None:
                pos = {int(c): j for j, c in enumerate(pf.idx)}
                for j, c in enumerate(idx):
                    p = pos.get(int(c))
                    if p is not None:
                        pf.buf[p] = rows[j]
