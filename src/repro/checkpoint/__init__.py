from repro.checkpoint.store import load_pytree, save_pytree  # noqa: F401
