"""Million-client scale-out machinery (DESIGN.md §scale-out).

Three independently testable pieces make m = 10^6 clients feasible on one
host without changing a single round's math:

* ``EFStore`` (checkpoint/store.py): the per-client EF error buffer moves
  host-side into lazily materialized numpy shards; the device only ever
  holds the participating cohort's (n, d) rows. The store must be an
  exact, race-free mirror of the resident (m, d) buffer under the
  gather → update → scatter cycle FedSim drives, including the async
  prefetch that overlaps round r+1's gather with round r's compute.
* ``FedSim(fed.ef_store)``: the round brackets the jitted body with the
  store. Per-client rng and batch rows key off cohort *position*, not
  client id, so the streamed run must be BIT-identical to the resident
  one — asserted here end-to-end, loss, params, and the full EF state.
* Lazy ``SimulatedNetwork`` links: per-client bandwidth draws happen on
  first participation, keyed by (seed, client_id) — deterministic,
  participation-order independent, O(0) construction at any m.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import EFStore
from repro.comm.transport import NetworkConfig, SimulatedNetwork
from repro.configs.base import FedConfig
from repro.core.rounds import FedSim
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

MC = MLPConfig(in_dim=8, hidden=16, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=32, num_classes=4, feature_dim=8,
                               alpha=0.5, seed=0)


# -- EFStore: host-side sharded EF state -------------------------------------


def test_efstore_lazy_construction_at_any_scale():
    """Constructing a store for 10^9 clients allocates nothing; rows never
    scattered to read back as zeros (the EF init state)."""
    s = EFStore(10**9, 64)
    assert s.nbytes == 0
    rows = s.gather(np.array([0, 123_456_789, 999_999_999]))
    assert rows.shape == (3, 64) and rows.dtype == np.float32
    assert not rows.any()
    assert s.nbytes == 0                      # gather materializes nothing


def test_efstore_shards_materialize_only_on_write():
    s = EFStore(10_000, 32, shard_clients=100)
    s.scatter(np.array([5, 7]), np.ones((2, 32), np.float32))
    assert s.nbytes == 100 * 32 * 4           # one shard, not 10_000 rows
    s.scatter(np.array([9_999]), np.ones((1, 32), np.float32))
    assert s.nbytes == 2 * 100 * 32 * 4       # last shard is clamped...
    # (shard 99 holds exactly rows 9900..9999 -> same 100-row size here)


def test_efstore_final_shard_is_ragged():
    s = EFStore(250, 8, shard_clients=100)    # shards of 100, 100, 50
    s.scatter(np.array([249]), np.full((1, 8), 3.0, np.float32))
    assert s.nbytes == 50 * 8 * 4
    assert float(s.gather(np.array([249]))[0, 0]) == 3.0


def test_efstore_mirrors_resident_buffer_under_round_cycle():
    """Property: over chained gather → update → scatter rounds with random
    (overlapping) cohorts and interleaved prefetches, the store stays an
    exact mirror of a resident (m, d) numpy buffer — including the
    prefetch-patching path, where round r writes rows that round r+1's
    already-running background gather also covers."""
    m, d, n = 500, 16, 32
    rng = np.random.default_rng(0)
    ref = np.zeros((m, d), np.float32)
    s = EFStore(m, d, shard_clients=64)
    prev_idx = None
    for r in range(30):
        # overlap consecutive cohorts half the time (the prefetch-patch
        # case: a client participating in rounds r and r+1 must see its
        # round-r write, not the stale prefetched snapshot)
        if prev_idx is not None and r % 2:
            keep = prev_idx[: n // 2]
            rest = rng.choice(np.setdiff1d(np.arange(m), keep), n - keep.size,
                              replace=False)
            idx = np.concatenate([keep, rest])
        else:
            idx = rng.choice(m, n, replace=False)
        rows = s.gather(idx)
        np.testing.assert_array_equal(rows, ref[idx])
        upd = rng.normal(size=(n, d)).astype(np.float32)
        nxt = rng.choice(m, n, replace=False)
        s.prefetch(nxt)                       # overlaps the "compute"
        s.scatter(idx, upd)
        ref[idx] = upd
        prev_idx = nxt
        if r % 3 == 0:
            # the next gather may or may not match the queued prefetch
            probe = rng.choice(m, 4, replace=False)
            np.testing.assert_array_equal(s.gather(probe), ref[probe])
    # full-state check: every row agrees, materialized or not
    np.testing.assert_array_equal(s.gather(np.arange(m)), ref)


def test_efstore_gather_consumes_only_matching_prefetch():
    s = EFStore(100, 4)
    s.scatter(np.array([1]), np.full((1, 4), 5.0, np.float32))
    s.prefetch(np.array([1, 2]))
    got = s.gather(np.array([3, 4]))          # mismatch: fresh locked gather
    assert not got.any()
    got = s.gather(np.array([1, 2]))          # match: consumes the buffer
    assert float(got[0, 0]) == 5.0 and not got[1].any()


def test_efstore_concurrent_prefetch_is_threadsafe():
    """scatter() joining a prefetch thread that is mid-gather must not
    deadlock or tear rows."""
    s = EFStore(2_000, 8, shard_clients=64)
    rng = np.random.default_rng(1)
    for _ in range(20):
        idx = rng.choice(2_000, 64, replace=False)
        s.prefetch(idx)
        s.scatter(idx[:32], np.ones((32, 8), np.float32))
        got = s.gather(idx)
        assert (got[:32] == 1.0).all()
    assert threading.active_count() < 20      # threads are joined, not leaked


# -- FedSim ef_store: streamed EF ≡ resident EF, bit for bit -----------------


def _run_sim(ef_store, rounds=6, m=32, n=8, use_run_rounds=False):
    fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                    compress_ratio=1 / 8, eta=0.05, eta_l=0.1,
                    local_steps=2, num_clients=m, participating=n,
                    ef_store=ef_store)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    params = pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0))
    st = sim.init(params)
    rng = jax.random.PRNGKey(1)
    keys, idxs, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, m, n))
        idxs.append(idx)
        keys.append(k2)
        batches.append(DATA.round_batches(idx, r, 2, 16))
    losses = []
    if use_run_rounds:
        stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
        st, mets = sim.run_rounds(st, jax.tree.map(jnp.asarray, stack),
                                  jnp.asarray(np.stack(idxs)),
                                  jnp.stack(keys))
        losses = [float(met["loss"]) for met in mets]
    else:
        for r in range(rounds):
            st, met = sim.round(st, jax.tree.map(jnp.asarray, batches[r]),
                                jnp.asarray(idxs[r]), keys[r])
            losses.append(float(met["loss"]))
    # normalize the EF view: resident -> the full (m, d) buffer; store ->
    # gather every client's row
    if ef_store:
        errors = sim._efs.gather(np.arange(m))
    else:
        errors = np.asarray(st.errors)
    return losses, st, errors


@pytest.mark.parametrize("use_run_rounds", [False, True],
                         ids=["round_loop", "run_rounds"])
def test_ef_store_bit_identical_to_resident(use_run_rounds):
    """The streamed run (cohort rows around the jitted body, prefetch in
    run_rounds mode) reproduces the resident run exactly: every round's
    loss, the final params, and the complete per-client EF state."""
    ref_losses, ref_st, ref_err = _run_sim(False)
    losses, st, err = _run_sim(True, use_run_rounds=use_run_rounds)
    assert losses == ref_losses
    for a, b in zip(jax.tree.leaves(st.params),
                    jax.tree.leaves(ref_st.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(err, ref_err)


def test_ef_store_device_state_is_cohort_sized():
    """The whole point: with ef_store the SimState carries (n, d), not
    (m, d) — the m-row buffer never touches the device."""
    fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                    compress_ratio=1 / 8, num_clients=1000, participating=4,
                    ef_store=True)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    assert st.errors.shape[0] == 4
    assert sim._efs.nbytes == 0               # nothing materialized yet


def test_ef_store_requires_fedsim():
    from repro.core.mesh import build_fed_round
    fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                    compress_ratio=1 / 8, ef_store=True, num_clients=4)
    with pytest.raises(ValueError, match="ef_store"):
        build_fed_round(object(), fed, None, None)


# -- FedSim hierarchical aggregation + tiered wire accounting ----------------


def test_sim_grouped_aggregation_matches_flat_loss_curve():
    """agg_groups on the sim reassociates only the server aggregate; the
    training signal must stay equivalent (same curve within float noise)
    and the wire metrics must bill tier 2."""
    def run(groups, wire=False):
        fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                        compress_ratio=1 / 8, eta=0.05, eta_l=0.1,
                        local_steps=2, num_clients=8, agg_groups=groups,
                        wire=wire)
        sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
        st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
        rng = jax.random.PRNGKey(1)
        mets = []
        for r in range(5):
            rng, k2 = jax.random.split(rng)
            idx = np.arange(8)
            b = DATA.round_batches(idx, r, 2, 16)
            st, met = sim.round(st, jax.tree.map(jnp.asarray, b),
                                jnp.asarray(idx), k2)
            mets.append(met)
        return mets

    flat = run(1)
    hier = run(4)
    for a, b in zip(flat, hier):
        assert float(b["loss"]) == pytest.approx(float(a["loss"]), rel=1e-5)
    # tiered wire billing: tier 2 = g dense fp32 partials
    met = run(4, wire=True)[-1]
    d = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0))))
    assert met["wire_tier2_bytes"] == 4 * 4 * d
    assert met["wire_up_bytes"] == (met["wire_tier1_bytes"]
                                    + met["wire_tier2_bytes"])
    flat_met = run(1, wire=True)[-1]
    assert flat_met["wire_tier2_bytes"] == 0
    assert flat_met["wire_up_bytes"] == flat_met["wire_tier1_bytes"]


# -- lazy SimulatedNetwork links ---------------------------------------------


def test_lazy_network_constructs_instantly_at_any_m():
    net = SimulatedNetwork(NetworkConfig(), 10**9)
    assert net._links == {}
    t = net.round([999_999_999, 5], 1000, 1000, round_idx=0)
    assert t.round_time_s > 0 and len(net._links) == 2


def test_lazy_network_draws_are_order_independent():
    """A client's link quality is a pure function of (seed, client id):
    two networks that meet the same clients in different rounds and orders
    agree on every shared client's bandwidth draw."""
    a = SimulatedNetwork(NetworkConfig(seed=3), 1000)
    b = SimulatedNetwork(NetworkConfig(seed=3), 1000)
    a.round([7, 3, 500], 100, 100, round_idx=0)
    b.round([900], 100, 100, round_idx=5)
    b.round([3], 100, 100, round_idx=6)
    b.round([500, 7], 100, 100, round_idx=7)
    for c in (3, 7, 500):
        assert a._links[c] == b._links[c]
    # different seed -> different links
    c_net = SimulatedNetwork(NetworkConfig(seed=4), 1000)
    c_net.round([3], 100, 100, round_idx=0)
    assert c_net._links[3] != a._links[3]


def test_lazy_network_timing_deterministic_per_round():
    net1 = SimulatedNetwork(NetworkConfig(seed=0), 50)
    net2 = SimulatedNetwork(NetworkConfig(seed=0), 50)
    t1 = net1.round(np.arange(10), 5000, 2000, round_idx=3)
    t2 = net2.round(np.arange(10), 5000, 2000, round_idx=3)
    assert t1.round_time_s == t2.round_time_s
    assert t1.slowest_client == t2.slowest_client
