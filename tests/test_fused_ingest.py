"""One-pass fused server ingest (DESIGN.md §3).

Contract under test, per layer:

* ``server_ingest(impl="jnp")`` — the blocked-scatter fused path is
  BIT-IDENTICAL to the two-pass baseline (``server_aggregate_sparse`` +
  ``server_update``) at every ``server_state_dtype`` (float32, bfloat16,
  int8-blockscale: x, m, v, v̂ — including the int8 q codes and scales),
  across FedAMS options and fedamsgrad, over multiple chained steps (the
  storage round-trip accumulates identically).
* ``kernels.fedams_ingest`` — matches ``ref.fedams_ingest_ref`` (m/v/v̂
  bitwise); through ``server_ingest(impl="kernel")`` it stays within
  ≲1 ulp of the two-pass baseline (the kernel accumulates client
  collisions in a fori_loop, a different summation order on collided
  coordinates only).
* resolution — ``resolve_fused_ingest`` / FedSim eligibility: auto fuses
  the unchunked sparse blocktopk round (jnp on CPU), auto degrades to
  "off" when the γ diagnostic needs a dense aggregate, and a forced knob
  the build cannot honor raises instead of silently falling back.
* FedSim — multi-round trajectories are bit-identical fused vs two-pass
  at every state dtype (losses, params, EF errors); quantized second
  moments track the fp32 trajectory within a documented tolerance.
* pipeline shape — the fused jaxpr scatters only on the 2-D (nb, block)
  domain: no 1-D dense-length scatter-add (the materialized mean delta
  the two-pass hands between its jits) appears anywhere in the fused
  round.
"""
import dataclasses

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core.compressors import block_layout, make_compressor
from repro.core.rounds import FedSim
from repro.core.sampling import sample_clients
from repro.core.server_opt import (QuantState, init_server_state,
                                   server_ingest, server_update)
from repro.core.stages import resolve_fused_ingest, server_aggregate_sparse
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

# -- flat-leaf problem shared by the numerics tests --------------------------

D, BLOCK, NCLI, RATIO = 5000, 64, 7, 1 / 8
BS, NB = block_layout(D, BLOCK)


def _selections(seed, steps):
    """Per-step gathered (vals, idx) stacks from the real blocktopk
    selection (faithful layout: global idx in the zero-padded block
    domain, zero-valued pad entries in the tail block)."""
    comp = make_compressor("blocktopk", RATIO, BLOCK)
    r = np.random.default_rng(seed)
    out = []
    for _ in range(steps):
        tots = jnp.asarray(r.normal(size=(NCLI, D)), jnp.float32)
        sels = [comp.select(tots[j]) for j in range(NCLI)]
        out.append((jnp.stack([s.vals for s in sels]),
                    jnp.stack([s.idx for s in sels])))
    return out


def _fed(algo="fedcams", option=1, **kw):
    return FedConfig(algorithm=algo, compressor="blocktopk",
                     compress_ratio=RATIO, aggregation="sparse",
                     option=option, eta=0.5, track_gamma=False, **kw)


def _second_eq(a, b, msg):
    """Bitwise equality for one second-moment leaf in storage form."""
    if isinstance(a, QuantState):
        np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q),
                                      err_msg=f"{msg} q")
        np.testing.assert_array_equal(np.asarray(a.scale),
                                      np.asarray(b.scale),
                                      err_msg=f"{msg} scale")
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=msg)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("algo,option", [("fedcams", 1), ("fedcams", 2),
                                         ("fedamsgrad", 1)])
def test_jnp_fused_bitwise_vs_two_pass(dtype, algo, option):
    """The fused jnp ingest IS the two-pass baseline, bit for bit, at
    every state dtype — chained over steps so the bf16/int8 storage
    round-trip (dequant → fp32 math → requant) is exercised on state the
    previous step wrote."""
    fed = _fed(algo, option, server_state_dtype=dtype)
    x_a = x_b = jnp.asarray(np.random.default_rng(1).normal(size=D),
                            jnp.float32)
    st_a = init_server_state(x_a, dtype, BS)
    st_b = init_server_state(x_b, dtype, BS)
    for step, (vals, idx) in enumerate(_selections(2, 3)):
        agg = server_aggregate_sparse(vals, idx, D, NCLI)
        x_a, st_a = server_update(fed, st_a, x_a, agg)
        x_b, st_b = server_ingest(fed, st_b, x_b, vals, idx, NCLI,
                                  block=BS, impl="jnp")
        msg = f"{algo} opt{option} {dtype} step{step}"
        np.testing.assert_array_equal(np.asarray(x_a), np.asarray(x_b),
                                      err_msg=f"{msg} x")
        np.testing.assert_array_equal(np.asarray(st_a.m), np.asarray(st_b.m),
                                      err_msg=f"{msg} m")
        _second_eq(st_a.v, st_b.v, f"{msg} v")
        _second_eq(st_a.vhat, st_b.vhat, f"{msg} vhat")
        assert int(st_a.t) == int(st_b.t)


@pytest.mark.parametrize("option", [1, 2])
def test_kernel_ingest_matches_ref(option):
    """Pallas ``fedams_ingest`` vs the per-client scatter-loop reference:
    m/v/v̂ bitwise; x gets the usual cross-program FMA/rsqrt allowance
    (tests/test_server_opt.py owns the single-program bitwise gate)."""
    from repro.kernels import ref
    from repro.kernels.fedams_ingest import fedams_ingest

    (vals, idx), = _selections(3, 1)
    r = np.random.default_rng(4)
    N = NB * BS
    k = vals.shape[1] // NB
    mk = lambda pos: jnp.asarray(
        np.abs(r.normal(size=N)) if pos else r.normal(size=N), jnp.float32)
    x, m, v, vh = mk(0), mk(0), mk(1), mk(1)
    kw = dict(n_div=NCLI, eta=0.5, beta1=0.9, beta2=0.99, eps=1e-3,
              option=option, block=BS)
    got = fedams_ingest(x, m, v, vh, vals.reshape(NCLI, NB, k),
                        idx.reshape(NCLI, NB, k), **kw)
    want = jax.jit(lambda *a: ref.fedams_ingest_ref(*a, **kw))(
        x, m, v, vh, vals.reshape(NCLI, NB, k), idx.reshape(NCLI, NB, k))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-6, atol=1e-6, err_msg="x")
    for g, w, nm in zip(got[1:], want[1:], "m v vhat".split()):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=nm)


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_kernel_ingest_near_two_pass(dtype):
    """``server_ingest(impl="kernel")`` vs the two-pass baseline: the
    kernel's per-client collision fori_loop reorders the scatter sum, so
    collided coordinates may move ≲1 ulp — everything else identical.
    int8: the q codes stay bitwise (requant quantizes away the ulp); the
    per-block scales carry the same ≲1-ulp allowance."""
    fed = _fed("fedcams", 1, server_state_dtype=dtype)
    x = jnp.asarray(np.random.default_rng(5).normal(size=D), jnp.float32)
    st_a = init_server_state(x, dtype, BS)
    st_b = init_server_state(x, dtype, BS)
    (vals, idx), = _selections(6, 1)
    agg = server_aggregate_sparse(vals, idx, D, NCLI)
    x_a, st_a = server_update(fed, st_a, x, agg)
    x_b, st_b = server_ingest(fed, st_b, x, vals, idx, NCLI,
                              block=BS, impl="kernel")
    np.testing.assert_allclose(np.asarray(x_a), np.asarray(x_b),
                               rtol=0, atol=1e-6, err_msg="x")
    np.testing.assert_allclose(np.asarray(st_a.m), np.asarray(st_b.m),
                               rtol=0, atol=1e-6, err_msg="m")
    if dtype == "int8":
        np.testing.assert_array_equal(np.asarray(st_a.v.q),
                                      np.asarray(st_b.v.q))
        np.testing.assert_allclose(np.asarray(st_a.v.scale),
                                   np.asarray(st_b.v.scale),
                                   rtol=1e-6, atol=0)
    else:
        np.testing.assert_allclose(np.asarray(st_a.v), np.asarray(st_b.v),
                                   rtol=0, atol=1e-6, err_msg="v")
        np.testing.assert_allclose(np.asarray(st_a.vhat),
                                   np.asarray(st_b.vhat),
                                   rtol=0, atol=1e-6, err_msg="vhat")


# -- resolution / validation -------------------------------------------------


MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=12, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)
M, N, K = 12, 4, 2


def _make(**fed_kw):
    kw = dict(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=K,
              num_clients=M, participating=N, compressor="blocktopk",
              compress_ratio=1 / 8, sparse_uplink=True, track_gamma=False)
    kw.update(fed_kw)
    fed = FedConfig(**kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    return sim, st


def _stage(rounds):
    rng = jax.random.PRNGKey(1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, M, N))
        batches.append(DATA.round_batches(idx, r, K, 16))
        idxs.append(idx)
        keys.append(k2)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    return stacked, jnp.asarray(np.stack(idxs)), jnp.stack(keys)


def _run_loop(sim, st, batches, idx, keys, rounds):
    met = None
    for r in range(rounds):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st, met = sim.round(st, b_r, idx[r], keys[r])
    return st, met


def test_fused_ingest_auto_resolution_and_validation():
    sim, _ = _make()                                 # eligible, CPU -> jnp
    assert sim._fused == "jnp"
    sim, _ = _make(track_gamma=True)                 # γ needs dense agg
    assert sim._fused == "off"
    sim, _ = _make(client_chunk=2)                   # chunked scan
    assert sim._fused == "off"
    sim, _ = _make(compressor="topk")                # ungrouped layout
    assert sim._fused == "off"
    sim, _ = _make(fused_ingest="off")               # explicit off
    assert sim._fused == "off"
    # forcing the knob on an ineligible round raises at build time
    with pytest.raises(ValueError, match="cannot fuse"):
        _make(fused_ingest="jnp", track_gamma=True)
    with pytest.raises(ValueError, match="cannot fuse"):
        _make(fused_ingest="kernel", client_chunk=2)
    # the resolver itself: forced kernel without a KernelImpl; auto picks
    # the kernel exactly where it compiles
    fed = _fed()
    with pytest.raises(ValueError, match="kernel_impl"):
        resolve_fused_ingest(dataclasses.replace(fed, fused_ingest="kernel"),
                             eligible=True, have_kernel=False, compiled=False)
    assert resolve_fused_ingest(fed, eligible=True, have_kernel=True,
                                compiled=True) == "kernel"
    assert resolve_fused_ingest(fed, eligible=True, have_kernel=True,
                                compiled=False) == "jnp"
    assert resolve_fused_ingest(fed, eligible=False, have_kernel=True,
                                compiled=True) == "off"


def test_state_dtype_config_validation():
    """Quantized second moments need an algorithm that overwrites v/v̂
    every round, and the int8 blockscale layout cannot shard."""
    with pytest.raises(ValueError, match="requant-drift"):
        FedConfig(algorithm="fedadam", server_state_dtype="bfloat16")
    with pytest.raises(ValueError, match="shard_server_state"):
        FedConfig(algorithm="fedcams", server_state_dtype="int8",
                  shard_server_state=True)
    for dtype in ("bfloat16", "int8"):               # fedams family is fine
        FedConfig(algorithm="fedcams", server_state_dtype=dtype)


# -- FedSim trajectories -----------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_sim_trajectory_bitwise_fused_vs_two_pass(dtype):
    """Multi-round FedSim: the fused round and the two-pass round produce
    the SAME trajectory bit for bit at every state dtype — losses, params,
    client EF errors."""
    R = 4
    batches, idx, keys = _stage(R)
    sim_f, st_f = _make(server_state_dtype=dtype)
    sim_o, st_o = _make(server_state_dtype=dtype, fused_ingest="off")
    assert sim_f._fused == "jnp" and sim_o._fused == "off"
    mets_f, mets_o = [], []
    for r in range(R):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st_f, m_f = sim_f.round(st_f, b_r, idx[r], keys[r])
        st_o, m_o = sim_o.round(st_o, b_r, idx[r], keys[r])
        mets_f.append(m_f)
        mets_o.append(m_o)
    flat = lambda p: jax.flatten_util.ravel_pytree(p)[0]
    assert bool(jnp.all(flat(st_f.params) == flat(st_o.params))), dtype
    assert bool(jnp.all(st_f.errors == st_o.errors)), dtype
    assert st_f.bits == st_o.bits
    for m_f, m_o in zip(mets_f, mets_o):
        assert float(m_f["loss"]) == float(m_o["loss"]), dtype


def test_sim_quantized_state_tracks_f32_loss():
    """Documented tolerance for the quantized second-moment storage: the
    bf16/int8 trajectories track the fp32 one closely on this problem —
    the quantization error enters only via the stored v/v̂ read back next
    round (README perf table caveat)."""
    R = 4
    batches, idx, keys = _stage(R)
    _, met_f = _run_loop(*_make(server_state_dtype="float32"),
                         batches, idx, keys, R)
    for dtype in ("bfloat16", "int8"):
        _, met_q = _run_loop(*_make(server_state_dtype=dtype),
                             batches, idx, keys, R)
        lf, lq = float(met_f["loss"]), float(met_q["loss"])
        assert abs(lq - lf) <= 0.05 * max(1.0, abs(lf)), (dtype, lq, lf)


# -- pipeline shape: no dense mean delta on the fused path -------------------


def _scatter_add_shapes(jaxpr):
    """Output shapes of every scatter-add in a jaxpr, sub-jaxprs included."""
    shapes = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scatter-add":
            shapes.extend(tuple(v.aval.shape) for v in eqn.outvars)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for sub in vs:
                if isinstance(sub, jax.core.ClosedJaxpr):
                    shapes.extend(_scatter_add_shapes(sub.jaxpr))
                elif isinstance(sub, jax.core.Jaxpr):
                    shapes.extend(_scatter_add_shapes(sub))
    return shapes


def test_fused_jaxpr_has_no_dense_delta_scatter():
    """The structural claim behind the bytes-moved win: the fused ingest
    scatters client values straight onto the blocked (nb, block) domain —
    no 1-D dense-length scatter-add (the materialized mean delta) exists
    in its jaxpr. The two-pass aggregate is exactly that 1-D scatter."""
    fed = _fed()
    x = jnp.zeros(D, jnp.float32)
    st = init_server_state(x, "float32", BS)
    (vals, idx), = _selections(7, 1)
    fused = jax.make_jaxpr(
        lambda s, xx, vv, ii: server_ingest(fed, s, xx, vv, ii, NCLI,
                                            block=BS, impl="jnp"))(
        st, x, vals, idx)
    f_shapes = _scatter_add_shapes(fused.jaxpr)
    assert f_shapes and all(len(s) == 2 and s == (NB, BS)
                            for s in f_shapes), f_shapes
    two = jax.make_jaxpr(
        lambda vv, ii: server_aggregate_sparse(vv, ii, D, NCLI))(vals, idx)
    t_shapes = _scatter_add_shapes(two.jaxpr)
    assert any(len(s) == 1 and s[0] >= D for s in t_shapes), t_shapes


def test_fused_sim_round_has_no_dense_delta_scatter():
    """Same proof on the WHOLE fused FedSim round: every scatter-add that
    touches a dense-parameter-length 1-D buffer is gone (client-side EF
    scatters are batched 2-D and the server scatter is (nb, block)); the
    two-pass round keeps the 1-D aggregate scatter."""
    from repro.core.sim import _CoreState

    batches, idx, keys = _stage(1)
    b0 = jax.tree.map(lambda x: x[0], batches)

    def round_jaxpr(sim, st):
        return jax.make_jaxpr(
            lambda c, b, i, k: sim._round_impl(c, b, i, k, jnp.int32(0)))(
            _CoreState(*st[:5]), b0, idx[0], keys[0])

    sim_f, st_f = _make()
    d = sim_f._d
    dense_1d = [s for s in _scatter_add_shapes(round_jaxpr(sim_f, st_f).jaxpr)
                if len(s) == 1 and s[0] >= d]
    assert not dense_1d, dense_1d
    sim_o, st_o = _make(fused_ingest="off")
    dense_1d = [s for s in _scatter_add_shapes(round_jaxpr(sim_o, st_o).jaxpr)
                if len(s) == 1 and s[0] >= d]
    assert dense_1d, "two-pass round lost its aggregate scatter?"
