"""Sim-vs-mesh differential parity harness (DESIGN.md §3).

The two execution backends — FedSim's vmapped global-vector simulation and
the shard_map mesh SPMD path — must be the SAME algorithm. This file makes
that a permanent fixture instead of per-PR spot checks:

* **Paired runs** (``tests/mesh_parity_harness.py`` under a forced
  8-device subprocess via ``conftest.run_forced_devices``): identical
  configs across (dense, topk, blocktopk, packedsign, kernel-routed
  blocktopk, fused one-pass ingest jnp + kernel, two-level hierarchical
  blocktopk jnp + kernel) × (wire on/off), three
  rounds each. Per-client EF state is
  asserted BIT-identical — which is also the per-round selection-equality
  proof: the EF residual is ``tot`` with exactly the selected coordinates
  zeroed, so differing selections would disagree wherever ``tot ≠ 0``.
  Params (the aggregate pushed through the elementwise server update) are
  bit-identical on the compacted-Selection paths — the gathered scatter-add
  runs in the same client order as the sim's segment scatter — and within
  ~1 ulp on the dense psum path (AllReduce vs axis-0 reduce association).
* **Jaxpr payload regression**: the traced sparse mesh round's client-axis
  all_gathers carry O(k) words per leaf (never d), run exactly one
  selection per leaf, and their operand bytes equal ``mesh_wire_bytes``
  — the wire metric is measured truth, not an analytic estimate.
* **Single-device stage properties** (hypothesis, with the
  tests/hypothesis_fallback shim): the mesh select-once stages
  (``topk_select_tree`` jnp + Pallas ``KernelImpl.topk_select_tree``,
  ``sparse_topk_leaf``, ``packed_sign_leaf``) against the dense reference
  compressor — tie-breaking identical to ``lax.top_k``, padded tails when
  ``d % block != 0``, packed bits when ``d % 8 != 0``. A unit-size
  ``ParallelContext`` turns the collectives into identities, so the leaf
  numerics are testable without a mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # see tests/hypothesis_fallback.py
    from hypothesis_fallback import given, settings, st

from conftest import forced_devices_json

from repro.configs.base import FedConfig
from repro.core.compressors import block_layout, make_compressor
from repro.core.mesh import leaf_wire_bytes, mesh_wire_bytes
from repro.core.stages import (mesh_agg_strategy, mesh_uplink,
                               packed_sign_leaf, resolve_mesh_sparse_impl,
                               sparse_topk_leaf, topk_select_tree)
from repro.kernels.ops import KernelImpl
from repro.sharding.rules import ParallelContext

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


# -- paired sim/mesh runs (forced 8-device subprocess) -----------------------


@pytest.fixture(scope="module")
def parity():
    """Run the whole config grid in ONE subprocess (shared jax init; both
    sides of every pair see the same XLA codegen) and cache the per-round
    tree-compare summaries."""
    return forced_devices_json(
        "from mesh_parity_harness import main; main()", devices=8,
        timeout=1800)


CASE_NAMES = ["dense", "topk", "blocktopk", "packedsign",
              "blocktopk_kernel", "blocktopk_fused",
              "blocktopk_fused_kernel", "blocktopk_hier",
              "blocktopk_hier_kernel"]


@pytest.mark.slow
@pytest.mark.parametrize("wire", [False, True], ids=["nowire", "wire"])
@pytest.mark.parametrize("name", CASE_NAMES)
def test_sim_mesh_parity(parity, name, wire):
    rows = parity["cases"][f"{name}_wire{int(wire)}"]
    assert len(rows) >= 3
    for row in rows:
        r = row["round"]
        # EF state (== the selection, see module docstring): bit-identical
        # on every strategy, every round
        assert row["errors_bitwise"], (name, wire, r, row)
        # losses are computed before aggregation -> identical
        assert row["loss_mesh"] == pytest.approx(row["loss_sim"],
                                                 rel=1e-6), (name, wire, r)
        if name == "dense":
            # dense psum vs the sim's axis-0 mean: association only
            assert row["params_maxdiff_rel"] <= 2e-7, (name, wire, r, row)
        else:
            # compacted-Selection gather and packed-sign sum run in the
            # sim's exact client order -> the whole state is bit-identical.
            # The hierarchical cases stay in this branch: both backends
            # scatter each group's members in the same order and reduce the
            # stacked (g, d) partials with the same jnp.sum, so the
            # two-level reassociation is identical on both sides (measured
            # bitwise, not just ≤1 ulp).
            assert row["params_bitwise"], (name, wire, r, row)


@pytest.mark.slow
def test_parity_wire_mode_changes_nothing(parity):
    """The sim's wire mode (encode→decode at float32 values) is bit-exact,
    so the SAME mesh run matches both the wire and non-wire sim — the
    grid's wire dimension must be indistinguishable in the summaries."""
    for name in CASE_NAMES:
        a = parity["cases"][f"{name}_wire0"]
        b = parity["cases"][f"{name}_wire1"]
        for ra, rb in zip(a, b):
            assert ra["errors_bitwise"] == rb["errors_bitwise"]
            assert ra["loss_sim"] == rb["loss_sim"], name


# -- jaxpr: the collective payload is O(k), selected exactly once ------------


@pytest.mark.slow
def test_sparse_mesh_payload_is_o_k_and_selects_once(parity):
    """Traced blocktopk sparse round, two leaves (2176 and 300 elements):
    exactly one top_k per leaf, two all_gathers per leaf (vals + idx), and
    the gathered operand bytes are the compacted-Selection size — equal to
    ``mesh_wire_bytes`` and far below the dense d-word payload."""
    jx = parity["jaxpr"]["blocktopk"]
    assert jx["top_k"] == jx["num_leaves"]        # one selection per leaf
    assert jx["argmax"] == 0                      # (k > 1 everywhere here)
    assert len(jx["gathered"]) == 2 * jx["num_leaves"]
    # per-leaf expected payload: nb*kb (value, index) pairs, 8 bytes each
    expect = sum(8 * nb * max(1, round(bs / 8))
                 for bs, nb in (block_layout(2176, 2048),
                                block_layout(300, 2048)))
    assert jx["gathered_bytes"] == expect
    assert jx["gathered_bytes"] == jx["metric_bytes"]
    assert jx["gathered_bytes"] < jx["dense_bytes"] / 2


@pytest.mark.slow
def test_packed_sign_payload_matches_metric(parity):
    """Packed-sign: the gather carries ceil(d/8) sign bytes + one fp32
    scale per leaf, and the metric equals the traced payload."""
    jx = parity["jaxpr"]["packedsign"]
    assert jx["top_k"] == 0
    assert jx["gathered_bytes"] == ((2176 + 7) // 8 + 4
                                    + (300 + 7) // 8 + 4)
    assert jx["gathered_bytes"] == jx["metric_bytes"]
    assert jx["gathered_bytes"] < jx["dense_bytes"] / 16


@pytest.mark.slow
def test_hier_root_payload_beats_flat(parity):
    """Traced hierarchical round (g=2 groups of 4, ratio 1/2): the member
    ("data") axis carries two gathers per leaf (vals + idx), the group
    ("cgroup") axis exactly one — the dense fp32 partial. The root
    collective therefore carries g·d·4 bytes, independent of the client
    count, vs the flat root's n·k·8 — at this ratio a >4× reduction,
    proved on the traced collective operands, not the analytic metric
    (which must agree with them, per tier)."""
    jx = parity["jaxpr_hier"]
    assert len(jx["tier1_gathers"]) == 2 * jx["num_leaves"]
    assert len(jx["tier2_gathers"]) == jx["num_leaves"]
    # metric == measured, per tier
    assert jx["tier1_operand_bytes"] == jx["metric_tier1_bytes"]
    assert jx["tier2_operand_bytes"] == jx["metric_tier2_bytes"]
    # tier 2 is the dense fp32 partial: d words per leaf, no index stream
    assert jx["metric_tier2_bytes"] == (2176 + 300) * 4
    # the O(g·d) vs O(n·k) win at the root
    assert jx["root_bytes_hier"] == (jx["agg_groups"]
                                     * jx["metric_tier2_bytes"])
    assert jx["root_bytes_flat"] == (jx["num_clients"]
                                     * jx["metric_tier1_bytes"])
    assert jx["root_bytes_hier"] < jx["root_bytes_flat"] / 4


# -- mesh_wire_bytes: strategy resolution (the metric follows execution) -----


def test_mesh_wire_bytes_resolves_through_executed_strategy():
    """Every fallback the round takes, the metric takes too: sparse
    aggregation with a compressor that has no compacted form, and
    non-fedcams algorithms, are billed as the dense psum they run."""
    tree = {"a": jnp.zeros(300)}
    dense = 300 * 4
    sparse_fed = FedConfig(algorithm="fedcams", aggregation="sparse",
                           compressor="blocktopk", compress_ratio=1 / 64)
    assert mesh_agg_strategy(sparse_fed) == "sparse_topk"
    assert mesh_wire_bytes(sparse_fed, tree) < dense
    # sign has no (vals, idx) form -> the round falls back to dense psum
    for fallback in (FedConfig(algorithm="fedcams", aggregation="sparse",
                               compressor="sign"),
                     FedConfig(algorithm="fedcams", aggregation="sparse",
                               compressor="int8"),
                     FedConfig(algorithm="fedavg", aggregation="sparse",
                               compressor="blocktopk")):
        assert mesh_agg_strategy(fallback) == "dense"
        assert mesh_wire_bytes(fallback, tree) == dense
    # narrowed dense collective is billed at the narrowed dtype
    bf16 = FedConfig(algorithm="fedcams", delta_dtype="bfloat16")
    assert mesh_wire_bytes(bf16, tree) == 300 * 2
    assert leaf_wire_bytes(sparse_fed, 300) == mesh_wire_bytes(sparse_fed,
                                                               tree)


def test_leaf_wire_bytes_follows_kernel_block():
    """A non-default KernelImpl block changes the gathered layout; the
    metric must be billed at the SAME block (build_fed_round threads one
    ``sparse_block`` into the compressor, the kernel, and the metric)."""
    fed = FedConfig(algorithm="fedcams", aggregation="sparse",
                    compressor="blocktopk", compress_ratio=1 / 2048)
    d = 2048
    ki = KernelImpl(block=512)
    sel, _ = ki.topk_select_leaf(1 / 2048, jnp.zeros(d),
                                 jnp.zeros(d))
    assert leaf_wire_bytes(fed, d, block=512) == sel.vals.size * 8
    # billing the default layout instead would under-report 4x here
    assert leaf_wire_bytes(fed, d, block=2048) != sel.vals.size * 8


def test_mesh_sparse_impl_resolution():
    """auto -> kernel only where it compiles (TPU); kernel demands an impl
    at build time; jnp always wins when forced."""
    fed = FedConfig(algorithm="fedcams", aggregation="sparse",
                    compressor="blocktopk")
    ki = KernelImpl()       # interpret resolves per backend (CPU here)
    assert resolve_mesh_sparse_impl(fed, None) == "jnp"
    assert resolve_mesh_sparse_impl(fed, ki) == (
        "kernel" if ki.compiled else "jnp")
    assert resolve_mesh_sparse_impl(
        fed, KernelImpl(interpret=False)) == "kernel"
    forced = FedConfig(algorithm="fedcams", aggregation="sparse",
                       compressor="blocktopk", mesh_sparse_impl="kernel")
    assert resolve_mesh_sparse_impl(forced, ki) == "kernel"
    with pytest.raises(ValueError, match="mesh_sparse_impl"):
        resolve_mesh_sparse_impl(forced, None)
    with pytest.raises(ValueError, match="mesh_sparse_impl"):
        FedConfig(mesh_sparse_impl="pallas")


# -- two-level hierarchical aggregation (DESIGN.md §scale-out) ---------------


def test_hier_strategy_and_tier_billing():
    """agg_groups > 1 on the sparse pipeline resolves to the hierarchical
    strategy; the tier split bills tier 1 identically to the flat per-client
    payload and tier 2 as the dense fp32 partial per leaf (zero on every
    flat strategy)."""
    from repro.core.mesh import leaf_tier2_bytes, mesh_wire_bytes_tiers
    tree = {"w": jnp.zeros(2176), "b": jnp.zeros(300)}
    kw = dict(algorithm="fedcams", aggregation="sparse",
              compressor="blocktopk", compress_ratio=1 / 8, num_clients=8)
    flat = FedConfig(**kw)
    hier = FedConfig(agg_groups=4, client_axes=("cgroup", "data"), **kw)
    assert mesh_agg_strategy(hier) == "sparse_topk_hier"
    t_hier = mesh_wire_bytes_tiers(hier, tree)
    t_flat = mesh_wire_bytes_tiers(flat, tree)
    assert t_hier["tier1"] == t_flat["tier1"] == mesh_wire_bytes(flat, tree)
    assert t_flat["tier2"] == 0
    assert t_hier["tier2"] == (2176 + 300) * 4
    assert leaf_tier2_bytes(hier, 300) == 1200
    assert leaf_tier2_bytes(flat, 300) == 0
    # tp model shards each push their own partial into the collectives
    assert mesh_wire_bytes_tiers(hier, tree, tp=2)["tier2"] == 2 * (2476 * 4)


def test_agg_groups_config_validation():
    """Hierarchical aggregation demands the (vals, idx) pipeline and groups
    that divide the per-round cohort — ragged groups would silently skew
    the tier-1 partials."""
    kw = dict(algorithm="fedcams", aggregation="sparse", num_clients=8)
    with pytest.raises(ValueError, match="agg_groups"):
        FedConfig(agg_groups=0, **kw)
    with pytest.raises(ValueError, match="vals, idx"):
        FedConfig(agg_groups=2, compressor="sign", **kw)
    with pytest.raises(ValueError, match="divide"):
        FedConfig(agg_groups=3, compressor="blocktopk", **kw)
    with pytest.raises(ValueError, match="divide"):
        FedConfig(agg_groups=4, compressor="blocktopk", participating=6,
                  num_clients=32, algorithm="fedcams", aggregation="sparse")
    # participating (not num_clients) is the per-round cohort that must
    # split evenly
    ok = FedConfig(agg_groups=4, compressor="blocktopk", participating=8,
                   num_clients=30, algorithm="fedcams", aggregation="sparse")
    assert ok.agg_groups == 4


def test_grouped_aggregate_matches_flat():
    """server_aggregate_sparse_grouped == the flat scatter-mean whenever no
    coordinate is selected by clients in two different groups, and within
    1 ulp of the fp32 sum when collisions do occur (the PR-4 collision
    analysis lifted one level: group partials reassociate the adds)."""
    from repro.core.stages import (server_aggregate_sparse,
                                   server_aggregate_sparse_grouped)
    rng = np.random.default_rng(7)
    d, n, k = 64, 8, 4
    # disjoint indices across ALL clients -> no reassociation freedom
    idx = jnp.asarray(rng.permutation(d)[:n * k].reshape(n, k), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    flat = server_aggregate_sparse(vals, idx, d, float(n))
    for g in (1, 2, 4, 8):
        got = server_aggregate_sparse_grouped(vals, idx, d, float(n), g)
        assert np.array_equal(np.asarray(got), np.asarray(flat)), g
    # colliding indices: same coordinate hit from different groups
    idx2 = jnp.asarray(rng.integers(0, 8, size=(n, k)), jnp.int32)
    flat2 = np.asarray(server_aggregate_sparse(vals, idx2, d, float(n)))
    for g in (2, 4):
        got2 = np.asarray(
            server_aggregate_sparse_grouped(vals, idx2, d, float(n), g))
        scale = np.abs(flat2).max()
        assert np.abs(got2 - flat2).max() <= 2 * np.finfo(np.float32).eps \
            * scale, g


# -- single-device stage properties ------------------------------------------

_CTX1 = ParallelContext()    # no client axes: collectives are identities


def _uplink_1client(comp, tot, impl="jnp", block=64):
    """Run the mesh sparse uplink machinery for ONE participating client:
    returns (agg, new_err) — with identity collectives and n_eff=1 the
    aggregate must equal the dense reference compression of tot."""
    delta = {"x": tot}
    err = {"x": jnp.zeros_like(tot)}
    if impl == "kernel":
        sels, errs = KernelImpl(block=block).topk_select_tree(
            comp.ratio, delta, err, jnp.float32(1.0))
    else:
        sels, errs = topk_select_tree(comp, delta, err, jnp.float32(1.0))
    agg = sparse_topk_leaf(sels["x"], tot, 1.0, _CTX1)
    return agg, errs["x"]


@given(st.integers(8, 5000), st.sampled_from([1 / 2, 1 / 8, 1 / 64]))
def test_sparse_leaf_padded_tail_matches_dense(d, ratio):
    """Property: for any leaf size (d % block != 0 included — the final
    block selects from the zero-padded domain), the select-once mesh
    uplink reproduces the dense blocktopk compression and its EF residual
    exactly, on BOTH selection providers."""
    block = 64
    comp = make_compressor("blocktopk", ratio, block)
    tot = jnp.asarray(np.random.default_rng(d).normal(size=d), jnp.float32)
    ref = comp.compress(tot)
    for impl in ("jnp", "kernel"):
        agg, err = _uplink_1client(comp, tot, impl, block)
        assert np.array_equal(np.asarray(agg), np.asarray(ref)), (impl, d)
        assert np.array_equal(np.asarray(err), np.asarray(tot - ref)), (
            impl, d, ratio)


def test_sparse_leaf_tie_breaking_matches_top_k():
    """Ties in |value| keep the lowest index, exactly like ``lax.top_k``,
    through the whole mesh sparse uplink — jnp and kernel providers."""
    block = 8
    tot = jnp.asarray([1.0, -2.0, 2.0, -2.0, 0.5, 2.0, -1.0, 0.0,
                       3.0, -3.0, 3.0, 0.0, 0.0, 0.0, 0.0, -3.0],
                      jnp.float32)
    comp = make_compressor("blocktopk", 2 / 8, block)
    from jax import lax
    xb = tot.reshape(2, block)
    _, idx = lax.top_k(jnp.abs(xb), 2)
    ref = jnp.zeros_like(xb).at[jnp.arange(2)[:, None], idx].set(
        jnp.take_along_axis(xb, idx, axis=1)).reshape(-1)
    for impl in ("jnp", "kernel"):
        agg, err = _uplink_1client(comp, tot, impl, block)
        assert np.array_equal(np.asarray(agg), np.asarray(ref)), impl
        # block 1: |−2| at idx 1 and |2| at idx 2 tie -> keep 1 then 2
        assert np.flatnonzero(np.asarray(agg)[:8]).tolist() == [1, 2]
        # block 2: three 3.0s tie -> lowest two indices win
        assert np.flatnonzero(np.asarray(agg)[8:]).tolist() == [0, 1]


@given(st.integers(3, 600))
def test_packed_sign_leaf_any_d(d):
    """Property: packed_sign_leaf at any d (d % 8 != 0 pads the bit
    buffer; the pad bits must be sliced off, not decoded as signs) equals
    the sign compressor: hat == scale·sign(tot) with sign(0) := +1, and
    the 1-client aggregate equals the hat."""
    tot = jnp.asarray(np.random.default_rng(d).normal(size=d), jnp.float32)
    tot = tot.at[d // 2].set(0.0)       # exercise sign(0) := +1
    comp = make_compressor("sign")
    ref = comp.compress(tot)
    agg, hat = packed_sign_leaf(tot, jnp.float32(1.0), 1.0, _CTX1)
    assert np.array_equal(np.asarray(hat), np.asarray(ref)), d
    assert np.array_equal(np.asarray(agg), np.asarray(ref)), d


def test_mesh_uplink_kernel_and_jnp_bit_identical():
    """The full mesh_uplink stage (multi-leaf tree, masked client) agrees
    bit-for-bit between the jnp and kernel selection providers."""
    rng = np.random.default_rng(0)
    delta = {"w": jnp.asarray(rng.normal(size=500), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(13, 7)), jnp.float32)}
    err = jax.tree.map(
        lambda x: 0.3 * jnp.asarray(rng.normal(size=x.shape), jnp.float32),
        delta)
    comp = make_compressor("blocktopk", 1 / 8)
    for mask in (1.0, 0.0):
        outs = {}
        for impl, ki in (("jnp", None), ("kernel", KernelImpl())):
            fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                            compress_ratio=1 / 8, aggregation="sparse",
                            mesh_sparse_impl=impl if ki else "jnp")
            outs[impl] = mesh_uplink(fed, comp, _CTX1, ki,
                                     jax.random.PRNGKey(0), delta, err,
                                     jnp.float32(mask), 1.0)
        for leaf in delta:
            a, e_a = outs["jnp"][0][leaf], outs["jnp"][1][leaf]
            b, e_b = outs["kernel"][0][leaf], outs["kernel"][1][leaf]
            assert np.array_equal(np.asarray(a), np.asarray(b)), (mask, leaf)
            assert np.array_equal(np.asarray(e_a), np.asarray(e_b))
            if mask == 0.0:   # masked-out client: nothing sent, EF frozen
                assert float(jnp.abs(a).max()) == 0.0
                assert np.array_equal(np.asarray(e_a),
                                      np.asarray(err[leaf]))
