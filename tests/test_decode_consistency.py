"""Decode path ≡ full-sequence forward for every cached family (the
strongest correctness check of the serving substrate: rolling windowed
caches, MLA absorbed decode, RG-LRU/mLSTM/sLSTM recurrent states)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                RGLRUConfig, XLSTMConfig)
from repro.models import stack as stack_mod
from repro.models.layers import embed_lookup, rms_norm
from repro.models.model import Model
from repro.sharding.rules import ParallelContext

CTX = ParallelContext()

CASES = {
    "dense_gqa": ModelConfig(
        name="d", family="dense", num_layers=3, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32"),
    "local_global_softcap": ModelConfig(
        name="w", family="dense", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, attn_pattern=(6, 0),
        attn_softcap=50.0, logit_softcap=30.0, dtype="float32"),
    "mla_moe": ModelConfig(
        name="m", family="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=64, dtype="float32",
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      d_ff_expert=64, capacity_factor=8.0)),
    "hybrid_rglru": ModelConfig(
        name="r", family="hybrid", num_layers=5, d_model=64, num_heads=4,
        num_kv_heads=1, d_ff=128, vocab_size=64, dtype="float32",
        block_pattern=("rglru", "rglru", "attn"), attn_pattern=(6,),
        rglru=RGLRUConfig(lru_width=64)),
    "xlstm": ModelConfig(
        name="x", family="ssm", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=64, dtype="float32",
        block_pattern=("mlstm", "slstm"), xlstm=XLSTMConfig()),
}


def _reference_logits(model, params, tokens):
    cfg = model.cfg
    x = embed_lookup(params["embed"], tokens, CTX, cfg.dtype)
    h, _ = stack_mod.stack_train(params["stack"], x, cfg, CTX,
                                 remat_policy="none")
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    return model._unembed(params, h, CTX)


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    S, max_len, B = 12, 16, 2
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    ref = _reference_logits(model, params, tokens)
    caches = model.init_cache(B, max_len)
    step = jax.jit(lambda p, t, c, pos: model.decode_step(
        p, t, c, pos, CTX, max_len=max_len))
    outs = []
    for i in range(S):
        lg, caches = step(params, tokens[:, i:i + 1], caches, jnp.int32(i))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(ref))) + 1.0
    err = float(jnp.max(jnp.abs(dec - ref)))
    assert err < 2e-3 * scale, f"{name}: {err} vs scale {scale}"


@pytest.mark.parametrize("name", ["dense_gqa", "hybrid_rglru", "mla_moe"])
def test_prefill_then_decode_matches_forward(name):
    cfg = CASES[name]
    S, max_len, B = 12, 16, 2
    half = 6
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size)
    ref = _reference_logits(model, params, tokens)
    lg, caches = model.prefill(params, tokens[:, :half], CTX,
                               max_len=max_len)
    assert float(jnp.max(jnp.abs(lg - ref[:, half - 1]))) < 1e-2
    step = jax.jit(lambda p, t, c, pos: model.decode_step(
        p, t, c, pos, CTX, max_len=max_len))
    for i in range(half, S):
        lg, caches = step(params, tokens[:, i:i + 1], caches, jnp.int32(i))
        assert float(jnp.max(jnp.abs(lg - ref[:, i]))) < 1e-2


def test_rolling_cache_beyond_window():
    """Decode past the window: rolling cache must keep matching a full
    forward restricted to the window."""
    cfg = CASES["local_global_softcap"]
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    B, S, max_len = 1, 14, 8  # window 6 < S
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                cfg.vocab_size)
    ref = _reference_logits(model, params, tokens)
    caches = model.init_cache(B, max_len)
    step = jax.jit(lambda p, t, c, pos: model.decode_step(
        p, t, c, pos, CTX, max_len=max_len))
    for i in range(S):
        lg, caches = step(params, tokens[:, i:i + 1], caches, jnp.int32(i))
        if i < max_len:  # global layers only exact within cache capacity
            assert float(jnp.max(jnp.abs(lg - ref[:, i]))) < 1e-2
