"""Fault-tolerant rounds (DESIGN.md §robustness): deterministic
injection, deadline cutoff, wire corruption + validation-before-ingest,
survivor-masked aggregation parity, and EF semantics under drops — on
FedSim and (subprocess) the forced-8-device mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (FaultConfig, FaultInjector, FaultPlan,
                        NetworkConfig, SimulatedNetwork)
from repro.comm.faults import (INVALID_IDX, corrupt_dense, corrupt_selection,
                               validate_dense, validate_selection)
from repro.comm.transport import RoundTiming
from repro.configs.base import FedConfig
from repro.core.rounds import FedSim
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

pytestmark = pytest.mark.faults

MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=8, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)


# -- config validation -------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(crash_prob=1.5), dict(crash_prob=-0.1), dict(corrupt_prob=2.0),
    dict(corrupt_mode="zap"), dict(deadline_s=-1.0),
    dict(max_update_norm=-2.0), dict(crash_trace=((0, 5, 1),)),
    dict(crash_trace=((-1, 0, 1),)),
])
def test_faultconfig_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        FaultConfig(**kw)


def test_fedconfig_fault_validation():
    base = dict(algorithm="fedcams", compressor="blocktopk",
                compress_ratio=1 / 8, track_gamma=False)
    FedConfig(fault=FaultConfig(), **base)                 # fine
    FedConfig(deadline_s=1.0, wire=True, **base)           # fine
    with pytest.raises(ValueError, match="track_gamma"):
        FedConfig(fault=FaultConfig(),
                  **dict(base, track_gamma=True))
    with pytest.raises(ValueError, match="wire"):
        FedConfig(deadline_s=1.0, **base)
    with pytest.raises(ValueError, match="pick one"):
        FedConfig(deadline_s=1.0, wire=True,
                  fault=FaultConfig(deadline_s=2.0), **base)
    with pytest.raises(ValueError, match="agg_groups"):
        FedConfig(fault=FaultConfig(), agg_groups=2, **base)
    with pytest.raises(ValueError, match="client_chunk"):
        FedConfig(fault=FaultConfig(), client_chunk=2, **base)
    with pytest.raises(ValueError, match="FaultConfig"):
        FedConfig(fault={"crash_prob": 0.5}, **base)


# -- injector ----------------------------------------------------------------


def _timing(times):
    times = np.asarray(times, np.float64)
    return RoundTiming(round_time_s=float(times.max(initial=0.0)),
                       uplink_bytes=0, downlink_bytes=0, slowest_client=-1,
                       mean_client_time_s=0.0, client_times_s=times)


def test_injector_deterministic_in_config_and_round():
    cfg = FaultConfig(crash_prob=0.3, corrupt_prob=0.2, seed=7)
    a, b = FaultInjector(cfg, 100), FaultInjector(cfg, 100)
    idx = np.arange(10)
    for r in range(5):
        pa, ia = a.plan(idx, r)
        pb, ib = b.plan(idx, r)
        for la, lb in zip(pa, pb):
            assert np.array_equal(la, lb)
        assert ia == ib
    draws = {tuple(a.plan(idx, r)[0].survivors.tolist()) for r in range(12)}
    assert len(draws) > 1        # the stream actually varies per round


def test_crash_trace_windows():
    cfg = FaultConfig(crash_trace=((3, 1, 4), (5, 0, 10 ** 9)))
    inj = FaultInjector(cfg, 10)
    idx = np.array([1, 3, 5])
    for r in range(6):
        plan, info = inj.plan(idx, r)
        assert plan.survivors[0] == 1.0                      # untouched id
        assert plan.survivors[1] == (0.0 if 1 <= r < 4 else 1.0)
        assert plan.survivors[2] == 0.0                      # persistent
        assert info["crashed"] == 1.0 + (1 <= r < 4)


def test_deadline_cut_and_round_time():
    inj = FaultInjector(FaultConfig(deadline_s=1.0), 8)
    times = np.array([0.5, 2.0, 0.9, 1.5])
    plan, info = inj.plan(np.arange(4), 0, _timing(times))
    assert np.array_equal(plan.survivors, [1.0, 0.0, 1.0, 0.0])
    assert info["deadline_cut"] == 2.0 and info["survivors"] == 2.0
    assert info["round_time_s"] == 1.0     # truncated: someone missed it
    plan2, info2 = inj.plan(np.arange(4), 0,
                            _timing(np.array([0.1, 0.2, 0.3, 0.4])))
    assert plan2.survivors.sum() == 4.0
    assert info2["round_time_s"] == pytest.approx(0.4)  # nobody cut
    with pytest.raises(ValueError, match="RoundTiming"):
        inj.plan(np.arange(4), 0, None)    # deadline needs the clock


def test_crashes_drop_out_of_round_time_without_deadline():
    inj = FaultInjector(FaultConfig(crash_trace=((1, 0, 10),)), 8)
    _, info = inj.plan(np.arange(4), 0,
                       _timing(np.array([0.5, 2.0, 0.9, 1.5])))
    assert info["round_time_s"] == pytest.approx(1.5)   # 2.0s client crashed


# -- corruption + validation-before-ingest ----------------------------------


def _plan(corrupt, xor=0x20000001, keep=0.5):
    c = np.asarray(corrupt, np.float32)
    return FaultPlan(
        survivors=np.ones_like(c),
        corrupt=c,
        xor_bits=np.where(c > 0, np.uint32(xor), np.uint32(0)),
        trunc_keep=np.where(c > 0, np.float32(keep), np.float32(1.0)))


def _sel(n=3, k=8, domain=64):
    r = np.random.default_rng(0)
    vals = jnp.asarray(r.normal(size=(n, k)), jnp.float32)
    idx = jnp.asarray(r.integers(0, domain, size=(n, k)), jnp.int32)
    return vals, idx


@pytest.mark.parametrize("mode", ["nan", "inf", "bitflip", "truncate"])
def test_corrupt_then_validate_rejects_only_offenders(mode):
    vals, idx = _sel()
    cv, cidx = corrupt_selection(vals, idx, _plan([1.0, 0.0, 0.0]), mode)
    # clean clients' payloads pass through untouched
    assert np.array_equal(np.asarray(cv[1:]), np.asarray(vals[1:]))
    assert np.array_equal(np.asarray(cidx[1:]), np.asarray(idx[1:]))
    out, valid = validate_selection(cv, cidx, 64)
    assert np.array_equal(np.asarray(valid), [0.0, 1.0, 1.0]), mode
    assert np.all(np.asarray(out[0]) == 0.0)       # zeroed, not masked-NaN
    assert np.isfinite(np.asarray(out)).all()
    assert np.array_equal(np.asarray(out[1:]), np.asarray(vals[1:]))


def test_truncate_marks_suffix_invalid():
    vals, idx = _sel(n=1, k=8)
    cv, cidx = corrupt_selection(vals, idx, _plan([1.0], keep=0.5),
                                 "truncate")
    cut = np.asarray(cidx[0]) == INVALID_IDX
    assert 0 < cut.sum() < 8                  # a strict suffix was cut
    assert np.all(np.asarray(cv[0])[cut] == 0.0)


def test_padded_tail_indices_pass_validation():
    # legit padded-tail entries index into [d, bs*nb) — the domain check
    # must accept them (they scatter into the zero-padded tail)
    vals = jnp.ones((1, 4), jnp.float32)
    idx = jnp.asarray([[0, 5, 62, 63]], jnp.int32)   # d=60, domain=64
    _, valid = validate_selection(vals, idx, 64)
    assert valid[0] == 1.0
    _, invalid = validate_selection(vals, jnp.asarray([[0, 5, 64, 63]],
                                                      jnp.int32), 64)
    assert invalid[0] == 0.0


def test_validate_selection_norm_clip():
    vals = jnp.full((2, 4), 5.0, jnp.float32)        # L2 = 10 per client
    idx = jnp.zeros((2, 4), jnp.int32)
    out, valid = validate_selection(vals, idx, 8, max_norm=1.0)
    assert np.all(np.asarray(valid) == 1.0)          # clipped, not rejected
    norms = np.linalg.norm(np.asarray(out), axis=-1)
    assert np.allclose(norms, 1.0, rtol=1e-5)


def test_validate_dense_rejects_nonfinite_and_truncated():
    hats = jnp.asarray(np.random.default_rng(1).normal(size=(3, 16)),
                       jnp.float32)
    bad = hats.at[0, 3].set(jnp.nan)
    out, valid = validate_dense(bad)
    assert np.array_equal(np.asarray(valid), [0.0, 1.0, 1.0])
    assert np.isfinite(np.asarray(out)).all()
    _, v2 = validate_dense(hats, truncated=jnp.asarray([0.0, 1.0, 0.0]))
    assert np.array_equal(np.asarray(v2), [1.0, 0.0, 1.0])
    cd = corrupt_dense(hats, _plan([1.0, 0.0, 0.0]), "nan")
    assert np.isnan(np.asarray(cd[0])).any()
    assert np.array_equal(np.asarray(cd[1:]), np.asarray(hats[1:]))


# -- FedSim: fault rounds end-to-end ----------------------------------------


def _run_sim(fault, *, m=8, n=4, rounds=6, seed=0, edit_row0_at=None,
             network=None, snapshots=False, scan=False, **fed_kw):
    fed = FedConfig(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=2,
                    num_clients=m, participating=n, compressor="blocktopk",
                    compress_ratio=1 / 8, track_gamma=False, fault=fault,
                    **fed_kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed, network=network)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(seed)))
    rng = jax.random.PRNGKey(seed + 1)
    staged = []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, m, n))
        staged.append((idx, DATA.round_batches(idx, r, 2, 8), k2))
    if scan:
        batches = jax.tree.map(
            lambda *xs: jnp.asarray(np.stack(xs)), *[s[1] for s in staged])
        idxs = jnp.asarray(np.stack([s[0] for s in staged]))
        keys = jnp.stack([s[2] for s in staged])
        st, mets = sim.run_rounds(st, batches, idxs, keys)
        return mets, st, []
    mets, snaps = [], []
    for r, (idx, b, k2) in enumerate(staged):
        if edit_row0_at == r:
            err = np.asarray(st.errors).copy()
            err[0] = 0.0
            st = st._replace(errors=jnp.asarray(err))
        st, met = sim.round(st, jax.tree.map(jnp.asarray, b),
                            jnp.asarray(idx), k2)
        mets.append(met)
        if snapshots:
            snaps.append(np.asarray(st.errors).copy())
    return mets, st, snaps


def _flat_state(st):
    return np.concatenate([np.asarray(leaf).ravel()
                           for leaf in jax.tree.leaves(st.params)]
                          + [np.asarray(st.errors).ravel()])


@pytest.mark.parametrize("fed_kw", [
    dict(),                                         # select-once sparse path
    dict(sparse_uplink=False),                      # dense reference path
    dict(wire=True),                                # wire codec in the loop
], ids=["sparse", "dense", "wire"])
def test_allones_fault_plan_is_bitwise_noop(fed_kw):
    """FaultConfig() enables the masked machinery with nobody failing —
    losses and state must be bit-identical to the fault-free build."""
    net = (lambda: SimulatedNetwork(NetworkConfig(seed=3), 8)) \
        if fed_kw.get("wire") else (lambda: None)
    base, st0, _ = _run_sim(None, network=net(), **fed_kw)
    par, st1, _ = _run_sim(FaultConfig(), network=net(), **fed_kw)
    assert [float(m["loss"]) for m in base] == \
        [float(m["loss"]) for m in par]
    assert np.array_equal(_flat_state(st0), _flat_state(st1))
    assert all(float(m["survivors"]) == 4.0 and float(m["rejected"]) == 0.0
               for m in par)


def test_scan_matches_loop_under_faults():
    fault = FaultConfig(crash_prob=0.3, corrupt_prob=0.3, seed=5)
    loop, st_l, _ = _run_sim(fault)
    scan, st_s, _ = _run_sim(fault, scan=True)
    assert [float(m["loss"]) for m in loop] == \
        [float(m["loss"]) for m in scan]
    assert [float(m["survivors"]) for m in loop] == \
        [float(m["survivors"]) for m in scan]
    assert np.array_equal(_flat_state(st_l), _flat_state(st_s))


@pytest.mark.parametrize("mode", ["nan", "inf", "bitflip", "truncate"])
def test_corruption_rejected_before_ingest(mode):
    mets, st, _ = _run_sim(FaultConfig(corrupt_prob=0.5, corrupt_mode=mode,
                                       seed=2))
    assert sum(float(m["rejected"]) for m in mets) > 0
    assert np.isfinite(_flat_state(st)).all()
    assert all(np.isfinite(float(m["loss"])) for m in mets)


def test_deadline_truncates_round_time_and_cuts_stragglers():
    net = SimulatedNetwork(NetworkConfig(straggler_prob=0.5,
                                         straggler_slowdown=50.0, seed=1), 8)
    mets, st, _ = _run_sim(FaultConfig(deadline_s=1.0), network=net,
                           wire=True)
    cut = sum(float(m["deadline_cut"]) for m in mets)
    assert cut > 0
    for m in mets:
        assert m["round_time_s"] <= 1.0
        assert float(m["survivors"]) == 4.0 - float(m["deadline_cut"])
    assert np.isfinite(_flat_state(st)).all()


def test_crashed_client_keeps_stale_residual():
    fault = FaultConfig(crash_trace=((0, 1, 3),))
    _, _, snaps = _run_sim(fault, m=4, n=4, rounds=4, snapshots=True)
    stale = snaps[0][0]
    assert np.array_equal(snaps[1][0], stale)      # dead: row untouched
    assert np.array_equal(snaps[2][0], stale)
    assert (snaps[1][1:] != snaps[0][1:]).any()    # the living moved on
    assert not np.array_equal(snaps[3][0], stale)  # rejoined: repaid


def test_rejoin_repays_exact_residual_vs_zeroed_twin():
    """Dropped client repays exactly its accumulated residual on rejoin.

    Twin runs share one jitted round program, so the rejoin-round delta
    for client 0 is bit-identical between them; EF rows are the uplink
    totals with exactly the selected coordinates zeroed, so off both
    selection supports run A's row must equal ``stale + (run Z's row)``
    — the same IEEE f32 add the round computed in-trace. The residual
    must also shift the selection itself (it is selected FROM
    ``stale + delta``, not from ``delta``)."""
    fault = FaultConfig(crash_trace=((0, 1, 3),))
    _, _, sa = _run_sim(fault, m=4, n=4, rounds=4, snapshots=True)
    _, _, sz = _run_sim(fault, m=4, n=4, rounds=4, snapshots=True,
                        edit_row0_at=3)
    stale, ea, ez = sa[0][0], sa[3][0], sz[3][0]
    assert np.array_equal(sz[2][0], stale)     # twin identical pre-edit
    off = (ea != 0.0) & (ez != 0.0)
    assert off.sum() > 100
    assert np.array_equal(ea[off], (stale[off] + ez[off]))
    assert ((ea == 0.0) != (ez == 0.0)).any()


def test_loss_within_2x_of_faultfree_under_nan_injection():
    base, _, _ = _run_sim(None, rounds=10)
    nan, _, _ = _run_sim(FaultConfig(corrupt_prob=0.1, corrupt_mode="nan",
                                     seed=4), rounds=10)
    assert float(nan[-1]["loss"]) <= 2.0 * float(base[-1]["loss"])


def test_fault_config_from_deadline_shorthand():
    """FedConfig(deadline_s=...) alone must arm the injector."""
    net = SimulatedNetwork(NetworkConfig(straggler_prob=0.5,
                                         straggler_slowdown=50.0, seed=1), 8)
    fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                    compress_ratio=1 / 8, num_clients=8, participating=4,
                    local_steps=2, eta=0.05, eta_l=0.1, wire=True,
                    track_gamma=False, deadline_s=1.0)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed, network=net)
    assert sim.faults is not None
    assert sim.faults.cfg.deadline_s == 1.0


def test_fault_replaces_deadline_into_config():
    fault = FaultConfig(crash_prob=0.1)
    fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                    compress_ratio=1 / 8, num_clients=8, wire=True,
                    track_gamma=False, deadline_s=0.5, fault=fault)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    assert sim.faults.cfg == dataclasses.replace(fault, deadline_s=0.5)


def test_deadline_run_sim_time_and_delivered_billing():
    """Regression (simulated-clock billing bugfix): over a deadline run
    the CommLog must accumulate the EFFECTIVE (deadline-truncated) round
    times — ``sim_time_s == Σ round_time_s`` exactly — and bill uplink
    bytes only for delivered clients, with the full cohort's sends kept
    as the _attempted diagnostic."""
    net = SimulatedNetwork(NetworkConfig(straggler_prob=0.5,
                                         straggler_slowdown=50.0, seed=1), 8)
    mets, st, _ = _run_sim(FaultConfig(deadline_s=1.0), rounds=6,
                           network=net, wire=True)
    cut = sum(float(m["deadline_cut"]) for m in mets)
    assert cut > 0                         # the deadline actually fired
    assert all(m["round_time_s"] <= 1.0 for m in mets)
    total = sum(m["round_time_s"] for m in mets)
    sim = mets[-1]["sim_time_s"]
    assert sim == pytest.approx(total, abs=1e-12)
    up_pc = mets[0]["wire_up_bytes_attempted"] // 4    # n = 4 clients
    for m in mets:
        assert m["wire_up_bytes_attempted"] == 4 * up_pc
        assert m["wire_up_bytes"] == int(m["survivors"]
                                         + m["rejected"]) * up_pc
    assert any(m["wire_up_bytes"] < m["wire_up_bytes_attempted"]
               for m in mets)


# -- forced-8-device mesh ----------------------------------------------------


@pytest.mark.slow
def test_mesh_fault_rounds_forced_devices():
    """Mesh backend: all-ones parity, stale-then-repay EF semantics, and
    NaN NACK rollback — one subprocess, 8 fake devices (see
    tests/fault_mesh_harness.py for the per-check contracts)."""
    from conftest import forced_devices_json
    out = forced_devices_json(
        "import json, fault_mesh_harness as h\n"
        "print(json.dumps(h.run_all()))\n")
    par = out["parity"]
    assert par["loss_bitwise"] and par["params_bitwise"] \
        and par["errors_bitwise"], par
    assert par["survivors"] == [8.0, 8.0, 8.0]
    rj = out["rejoin"]
    assert rj["stale_r1_bitwise"] and rj["stale_r2_bitwise"], rj
    assert rj["others_moved_r1"]
    assert rj["off_support_count"] > 100
    assert rj["repay_bitwise"], rj
    assert rj["selection_shifted_by_residual"]
    assert rj["survivors"] == [8.0, 7.0, 7.0, 8.0]
    cr = out["corruption"]
    assert cr["any_rejected"] and cr["state_finite"] and cr["loss_finite"]
    assert cr["nack_rows_match_rejected"], cr
