"""Error-feedback invariants (paper Algorithm 2 lines 12-16)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # see tests/hypothesis_fallback.py
    from hypothesis_fallback import given, settings, st

from repro.core.compressors import make_sign, make_topk
from repro.core.error_feedback import ef_compress, ef_compress_masked

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _tree(seed, shapes=((8, 4), (13,))):
    r = np.random.default_rng(seed)
    return {f"w{i}": jnp.asarray(r.normal(size=s), jnp.float32)
            for i, s in enumerate(shapes)}


@given(st.integers(0, 10**6))
def test_ef_identity(seed):
    """e' == (Δ + e) − Δ̂ exactly, per leaf."""
    delta, err = _tree(seed), _tree(seed + 1)
    comp = make_topk(1 / 4)
    hat, new_err = ef_compress(comp, delta, err)
    for k in delta:
        tot = delta[k] + err[k]
        assert np.allclose(np.asarray(hat[k] + new_err[k]), np.asarray(tot),
                           atol=1e-6)


@given(st.integers(0, 10**5), st.integers(2, 12))
def test_ef_telescoping(seed, T):
    """Σ_t Δ̂_t = Σ_t Δ_t + e_1 − e_{T+1} (compression error never lost)."""
    comp = make_sign()
    r = np.random.default_rng(seed)
    err = {"w": jnp.zeros(17)}
    total_hat = jnp.zeros(17)
    total_delta = jnp.zeros(17)
    for _ in range(T):
        delta = {"w": jnp.asarray(r.normal(size=17), jnp.float32)}
        hat, err = ef_compress(comp, delta, err)
        total_hat += hat["w"]
        total_delta += delta["w"]
    assert np.allclose(np.asarray(total_hat + err["w"]),
                       np.asarray(total_delta), atol=1e-4)


def test_stale_error_partial_participation():
    """Non-participating clients keep e unchanged and contribute zero."""
    comp = make_topk(1 / 2)
    delta, err = _tree(0), _tree(1)
    hat, new_err = ef_compress_masked(comp, delta, err,
                                      jnp.float32(0.0))
    for k in delta:
        assert np.allclose(np.asarray(hat[k]), 0.0)
        assert np.allclose(np.asarray(new_err[k]), np.asarray(err[k]))
    hat1, err1 = ef_compress_masked(comp, delta, err, jnp.float32(1.0))
    hat2, err2 = ef_compress(comp, delta, err)
    for k in delta:
        assert np.allclose(np.asarray(hat1[k]), np.asarray(hat2[k]))
        assert np.allclose(np.asarray(err1[k]), np.asarray(err2[k]))
