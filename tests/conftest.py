import json
import os
import subprocess
import sys
import textwrap

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real device. Multi-device tests funnel through run_forced_devices
# below, which spawns a subprocess that sets
# --xla_force_host_platform_device_count itself (test_sharding.py,
# test_mesh_parity.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests/ itself, for the optional-dependency shims (hypothesis_fallback)
# and the subprocess-side harness modules (mesh_parity_harness)
sys.path.insert(0, os.path.dirname(__file__))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_forced_devices(code: str, devices: int = 8,
                       timeout: int = 1200) -> str:
    """Run ``code`` in a subprocess seeing ``devices`` fake host CPU
    devices (the in-process suite must keep seeing ONE device — see the
    note at the top of this file). ``src/`` and ``tests/`` are both on the
    subprocess PYTHONPATH, so harness modules that live next to the tests
    (e.g. ``mesh_parity_harness``) import directly. Returns stdout;
    asserts a zero exit with the subprocess stderr tail on failure.

    This is the reusable differential-harness entry point: parametrized
    config grid → paired runs inside ONE subprocess (shared jax init, same
    process so same XLA codegen for both sides of every pair) → the
    subprocess prints a JSON summary consumed via
    :func:`forced_devices_json`."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, os.path.dirname(__file__)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def forced_devices_json(code: str, devices: int = 8, timeout: int = 1200):
    """:func:`run_forced_devices`, parsing the subprocess's last stdout
    line as JSON (the paired-run summary)."""
    return json.loads(run_forced_devices(code, devices, timeout)
                      .strip().splitlines()[-1])


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                       num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                       dtype="float32")
