import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real device. Multi-device sharding tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (test_sharding.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests/ itself, for the optional-dependency shims (hypothesis_fallback)
sys.path.insert(0, os.path.dirname(__file__))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                       num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                       dtype="float32")
