"""End-to-end FedSim behaviour: convergence, partial participation,
compression trade-offs (the paper's qualitative claims at test scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core.rounds import FedSim
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=12, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)


def run(algo, rounds=25, n=4, m=12, K=2, seed=0, **fed_kw):
    default_eta = 1.0 if algo == "fedavg" else 0.05
    fed = FedConfig(algorithm=algo, eta=fed_kw.pop("eta", default_eta),
                    eta_l=0.1, local_steps=K, num_clients=m,
                    participating=n, **fed_kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    params = pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(seed))
    st = sim.init(params)
    rng = jax.random.PRNGKey(seed + 1)
    losses = []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, m, n))
        b = DATA.round_batches(idx, r, K, 16)
        st, met = sim.round(st, jax.tree.map(jnp.asarray, b),
                            jnp.asarray(idx), k2)
        losses.append(float(met["loss"]))
    return losses, st


@pytest.mark.parametrize("algo", ["fedavg", "fedadagrad", "fedadam",
                                  "fedyogi", "fedamsgrad", "fedams"])
def test_all_algorithms_decrease_loss(algo):
    losses, _ = run(algo)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


@pytest.mark.parametrize("comp", ["topk", "sign", "blocktopk", "int8"])
def test_fedcams_converges_with_compression(comp):
    losses, st = run("fedcams", compressor=comp, compress_ratio=1 / 8)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05
    assert float(st.bits) > 0


def test_compression_reduces_bits_by_orders_of_magnitude():
    """Fig 4/5 + Table 1: FedCAMS bits << uncompressed bits (top-k r=1/64
    gives 32x = 32d/(64·d/64); r=1/256 gives >100x)."""
    _, st_unc = run("fedams", rounds=5)
    _, st_64 = run("fedcams", rounds=5, compressor="topk",
                   compress_ratio=1 / 64)
    _, st_256 = run("fedcams", rounds=5, compressor="topk",
                    compress_ratio=1 / 256)
    assert float(st_unc.bits) / float(st_64.bits) > 25
    assert float(st_unc.bits) / float(st_256.bits) > 100


def test_more_clients_faster_convergence():
    """Fig 2: larger n converges faster (averaged over seeds)."""
    f2 = np.mean([np.mean(run("fedams", n=2, rounds=20, seed=s)[0][-5:])
                  for s in range(3)])
    f8 = np.mean([np.mean(run("fedams", n=8, rounds=20, seed=s)[0][-5:])
                  for s in range(3)])
    assert f8 <= f2 + 0.02


def test_two_way_compression_runs_and_converges():
    """Appendix D (beyond-paper implementation)."""
    losses, _ = run("fedcams", compressor="topk", compress_ratio=1 / 8,
                    two_way=True, n=0)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_partial_participation_keeps_stale_errors():
    _, st = run("fedcams", compressor="topk", compress_ratio=1 / 8,
                rounds=3, n=2, m=12)
    errs = np.asarray(st.errors)
    # only sampled clients ever got non-zero error state
    touched = (np.abs(errs).sum(axis=1) > 0).sum()
    assert 0 < touched <= 3 * 2
