"""Per-architecture smoke tests (required deliverable f): instantiate the
REDUCED variant of each assigned family and run one forward/train step on
CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, FedConfig, TrainConfig
from repro.configs.registry import get_arch
from repro.core.rounds import build_fed_round, init_fed_state
from repro.models.model import Model
from repro.sharding.rules import ParallelContext

CTX = ParallelContext()
B, S = 2, 16


def _batch(cfg):
    r = np.random.default_rng(0)
    if cfg.frontend is not None:
        return {
            "embeddings": jnp.asarray(
                r.normal(size=(B, S, cfg.d_model)).astype(np.float32)),
            "labels": jnp.asarray(
                r.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)),
        }
    toks = r.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, -1))}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(
        lambda p, b: model.loss(p, b, CTX, remat_policy="none"))(
            params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert all(bool(jnp.isfinite(v)) for v in metrics.values())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One full federated round (the paper's train step) on the smoke
    config."""
    spec = get_arch(arch)
    cfg = spec.smoke
    model = Model(cfg, tp=1)
    fed = FedConfig(algorithm="fedcams", num_clients=1, local_steps=2,
                    compressor="topk", compress_ratio=1 / 8, client_axes=(),
                    eta=0.1, eta_l=0.05)
    train = TrainConfig(global_batch=B, seq_len=S, remat_policy="none")
    state = init_fed_state(model, fed, jax.random.PRNGKey(0))
    rnd = jax.jit(build_fed_round(model, fed, train,
                                  ParallelContext(client_axes=(),
                                                  num_clients=1)))
    b = _batch(cfg)
    batch = jax.tree.map(lambda x: jnp.stack([x, x]), b)  # K=2 local steps
    state2, met = rnd(state, batch, jnp.int32(0))
    assert bool(jnp.isfinite(met["loss"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         state.params, state2.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_arch(a).has_decode])
def test_smoke_decode_step(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.init_cache(B, 8)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, caches = jax.jit(
        lambda p, t, c: model.decode_step(p, t, c, jnp.int32(0), CTX,
                                          max_len=8))(params, tok, caches)
    assert logits.shape == (B, model.vocab_padded)
    assert bool(jnp.isfinite(logits).all())


def test_encoder_has_no_decode():
    spec = get_arch("hubert-xlarge")
    model = Model(spec.smoke, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="encoder-only"):
        model.decode_step(params, jnp.ones((1, 1), jnp.int32), {}, 0, CTX,
                          max_len=8)


def test_full_configs_match_assignment():
    """The exact assigned numbers."""
    rows = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "deepseek-v3-671b": (61, 7168, 128, 128, 0, 129280),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    }
    for arch, (L, d, h, kv, ff, v) in rows.items():
        cfg = get_arch(arch).model
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
        assert cfg.source
    assert get_arch("deepseek-v3-671b").model.moe.num_experts == 256
    assert get_arch("deepseek-v3-671b").model.moe.top_k == 8
    assert get_arch("qwen2-moe-a2.7b").model.moe.num_experts == 60
    assert get_arch("qwen2-moe-a2.7b").model.moe.top_k == 4
