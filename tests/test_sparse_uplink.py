"""The select-once sparse uplink fast path (DESIGN.md §3).

Contract under test, per layer:

* compressors — ``selection_to_dense(select(x), d) == compress(x)``
  bit-for-bit (same ``lax.top_k`` selection and tie-breaking), including
  the k=1 argmax fast path and padded final blocks.
* wire codecs — ``encode_from_selection(select(x)) == encode(x)`` byte for
  byte (the wire never re-runs top-k), ``decode_to_selection`` inverts it,
  and ``roundtrip_selection`` (the sim's shortcut past the byte shuffling)
  equals the full encode→decode roundtrip exactly for every value dtype.
* FedSim — with ``sparse_uplink`` on, a round's selection and
  error-feedback state are bit-identical to the dense reference path; the
  aggregate (and so params) differs only on coordinates several clients
  selected, by scatter-vs-reduce float reassociation (≲1 ulp/round),
  across ratios, client chunking, two-way, and wire on/off; the scan
  driver stays bit-identical to the per-round loop.
* pipeline shape — the wire-mode sparse round invokes ``lax.top_k`` at
  most once per client (zero times on the k=1 argmax path), verified by
  counting primitives in the traced jaxpr.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # see tests/hypothesis_fallback.py
    from hypothesis_fallback import given, settings, st

from repro.comm.wire import make_blocktopk_codec, make_topk_codec
from repro.configs.base import FedConfig
from repro.core.compressors import make_compressor, selection_to_dense
from repro.core.rounds import FedSim
from repro.core.sampling import sample_clients
from repro.core.stages import client_uplink, client_uplink_sparse
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _vec(seed, d):
    return jnp.asarray(np.random.default_rng(seed).normal(size=d),
                       jnp.float32)


# -- compressor selection ----------------------------------------------------


@given(st.sampled_from(["topk", "blocktopk"]),
       st.sampled_from([1 / 2, 1 / 8, 1 / 64, 1 / 2048]),
       st.integers(8, 5000))
def test_selection_matches_dense_compress(name, ratio, d):
    """Property: the compacted selection scatters back to exactly the dense
    compressor output — same kept set, same values, any ratio/size
    (including padded final blocks and the k=1 argmax fast path)."""
    comp = make_compressor(name, ratio, 64)
    x = _vec(d, d)
    dense = np.asarray(comp.compress(x)).reshape(-1)
    sel = comp.select(x)
    rec = np.asarray(selection_to_dense(sel, d))
    assert np.array_equal(rec, dense), (name, ratio, d)


def test_selection_tie_breaking_matches_top_k():
    """Ties in |value| keep the lowest index, exactly like lax.top_k — on
    the top_k path and on the k=1 argmax fast path."""
    x = jnp.asarray([1.0, -2.0, 2.0, -2.0, 0.5, 2.0, -1.0, 0.0], jnp.float32)
    comp = make_compressor("topk", 2 / 8)
    sel = comp.select(x)
    assert sorted(np.asarray(sel.idx).tolist()) == [1, 2]
    one = make_compressor("topk", 1 / 8)          # k=1 -> argmax path
    sel1 = one.select(x)
    assert np.asarray(sel1.idx).tolist() == [1]
    assert float(sel1.vals[0]) == -2.0            # value, not |value|


def test_blocktopk_selection_padded_tail_block():
    """The final short block selects from the zero-padded domain: indices
    may point past d and carry exact 0.0 — dropped by the dense scatter."""
    d, block = 70, 64
    x = _vec(3, d)
    comp = make_compressor("blocktopk", 1 / 2, block)
    sel = comp.select(x)
    gidx = np.asarray(sel.idx)
    vals = np.asarray(sel.vals)
    assert (vals[gidx >= d] == 0.0).all()
    assert np.array_equal(np.asarray(selection_to_dense(sel, d)),
                          np.asarray(comp.compress(x)).reshape(-1))


# -- wire codec selection paths ---------------------------------------------


CODECS = {
    "topk_f32": lambda: make_topk_codec(1 / 8),
    "topk_f16": lambda: make_topk_codec(1 / 8, "float16"),
    "topk_bf16": lambda: make_topk_codec(1 / 8, "bfloat16"),
    "blocktopk_f32": lambda: make_blocktopk_codec(1 / 8, block=64),
    "blocktopk_int8": lambda: make_blocktopk_codec(1 / 8, block=64,
                                                   value_dtype="int8"),
    "blocktopk_k1": lambda: make_blocktopk_codec(1 / 64, block=64),
}


def _comp_of(name):
    kind = name.split("_")[0]
    ratio = 1 / 64 if name.endswith("k1") else 1 / 8
    return make_compressor(kind, ratio, 64)


@pytest.mark.parametrize("name", list(CODECS))
@pytest.mark.parametrize("d", [37, 100, 5000])
def test_encode_from_selection_byte_identical(name, d):
    """Packing the already-computed selection produces the exact bytes the
    dense encode produces — so wire mode can skip the second top-k."""
    codec, comp = CODECS[name](), _comp_of(name)
    x = _vec(d + 17, d)
    b_dense = codec.encode(x)
    b_sel = codec.encode_from_selection(comp.select(x), d)
    assert np.array_equal(np.asarray(b_dense), np.asarray(b_sel)), name


@pytest.mark.parametrize("name", list(CODECS))
@pytest.mark.parametrize("d", [37, 100, 5000])
def test_roundtrip_selection_equals_byte_roundtrip(name, d):
    """The sim's shortcut (roundtrip_selection) is bit-identical to the
    full encode->bytes->decode_to_selection trip, for every value dtype —
    this is what licenses skipping the byte shuffling inside the round."""
    codec, comp = CODECS[name](), _comp_of(name)
    x = _vec(d + 31, d)
    sel = comp.select(x)
    via_bytes = codec.decode_to_selection(
        codec.encode_from_selection(sel, d), d)
    direct = codec.roundtrip_selection(sel, d)
    assert np.array_equal(np.asarray(via_bytes.idx), np.asarray(direct.idx))
    assert np.array_equal(np.asarray(via_bytes.vals),
                          np.asarray(direct.vals)), name
    # and scattering the received selection equals the dense decode
    dec = np.asarray(codec.decode(codec.encode(x), d))
    rec = np.asarray(selection_to_dense(direct, d))
    assert np.array_equal(rec, dec), name


# -- FedSim: sparse vs dense reference path ----------------------------------


MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=12, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)
M, N, K = 12, 4, 2


def _make(**fed_kw):
    kw = dict(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=K,
              num_clients=M, participating=N, compressor="topk",
              compress_ratio=1 / 8)
    kw.update(fed_kw)
    fed = FedConfig(**kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    return sim, st


def _stage(rounds):
    rng = jax.random.PRNGKey(1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, M, N))
        batches.append(DATA.round_batches(idx, r, K, 16))
        idxs.append(idx)
        keys.append(k2)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    return stacked, jnp.asarray(np.stack(idxs)), jnp.stack(keys)


def _flat(params):
    return jax.flatten_util.ravel_pytree(params)[0]


def _run_loop(sim, st, batches, idx, keys, rounds):
    for r in range(rounds):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st, met = sim.round(st, b_r, idx[r], keys[r])
    return st, met


PARITY_CASES = [
    {"compressor": "topk"},
    {"compressor": "topk", "wire": True},
    {"compressor": "topk", "wire": True, "two_way": True},
    {"compressor": "topk", "compress_ratio": 1 / 2048},
    {"compressor": "topk", "client_chunk": 2},
    {"compressor": "blocktopk"},
    {"compressor": "blocktopk", "wire": True},
    {"compressor": "blocktopk", "compress_ratio": 1 / 2048},
    {"compressor": "blocktopk", "client_chunk": 2, "wire": True},
]


@pytest.mark.parametrize("fed_kw", PARITY_CASES)
def test_sparse_round_bit_parity_with_dense(fed_kw):
    """One round from identical state: selection and EF errors are
    bit-identical to the dense path; params differ at most by the server
    update of the aggregate's scatter-vs-reduce reassociation (collided
    coordinates only, ≲1 ulp)."""
    batches, idx, keys = _stage(1)
    sim_d, st_d = _make(sparse_uplink=False, **fed_kw)
    sim_s, st_s = _make(sparse_uplink=True, **fed_kw)
    assert sim_s.sparse and not sim_d.sparse
    st_d, met_d = _run_loop(sim_d, st_d, batches, idx, keys, 1)
    st_s, met_s = _run_loop(sim_s, st_s, batches, idx, keys, 1)
    # client EF state: exactly equal, bit for bit
    assert bool(jnp.all(st_d.errors == st_s.errors)), fed_kw
    # server-side EF (two-way) is downstream of the aggregate, so it
    # inherits the aggregate's reassociation ulps
    np.testing.assert_allclose(np.asarray(st_s.server_error),
                               np.asarray(st_d.server_error),
                               rtol=0, atol=1e-8)
    # losses are computed before aggregation -> identical
    assert float(met_d["loss"]) == float(met_s["loss"])
    assert st_d.bits == st_s.bits
    # params: reassociation-only difference
    np.testing.assert_allclose(np.asarray(_flat(st_s.params)),
                               np.asarray(_flat(st_d.params)),
                               rtol=0, atol=1e-8)


@pytest.mark.parametrize("fed_kw", PARITY_CASES)
def test_sparse_trajectory_tracks_dense(fed_kw):
    """Multi-round: the 1-ulp/round aggregate reassociation stays a
    reassociation (no systematic drift) over several rounds."""
    R = 4
    batches, idx, keys = _stage(R)
    sim_d, st_d = _make(sparse_uplink=False, **fed_kw)
    sim_s, st_s = _make(sparse_uplink=True, **fed_kw)
    st_d, _ = _run_loop(sim_d, st_d, batches, idx, keys, R)
    st_s, _ = _run_loop(sim_s, st_s, batches, idx, keys, R)
    np.testing.assert_allclose(np.asarray(_flat(st_s.params)),
                               np.asarray(_flat(st_d.params)),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_s.errors),
                               np.asarray(st_d.errors), rtol=0, atol=1e-6)


@pytest.mark.parametrize("fed_kw", [
    {"compressor": "topk", "wire": True},
    {"compressor": "blocktopk", "wire": True, "two_way": True},
    {"compressor": "blocktopk", "compress_ratio": 1 / 2048,
     "client_chunk": 2},
    {"compressor": "topk", "local_opt": "sgdm", "eta_l_decay": 0.9,
     "local_steps_min": 1},
])
def test_sparse_scan_driver_bit_identical_to_loop(fed_kw):
    """run_rounds == R x round on the sparse path — same final state and
    per-round metrics, bit for bit (the sparse scatter lives inside the
    scanned body like every other stage)."""
    R = 4
    batches, idx, keys = _stage(R)
    sim_l, st_l = _make(sparse_uplink=True, **fed_kw)
    mets_l = []
    for r in range(R):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st_l, met = sim_l.round(st_l, b_r, idx[r], keys[r])
        mets_l.append(met)
    sim_s, st_s = _make(sparse_uplink=True, **fed_kw)
    st_s, mets_s = sim_s.run_rounds(st_s, batches, idx, keys)
    assert bool(jnp.all(_flat(st_l.params) == _flat(st_s.params)))
    assert bool(jnp.all(st_l.errors == st_s.errors))
    assert st_l.bits == st_s.bits and st_l.round == st_s.round == R
    for m_l, m_s in zip(mets_l, mets_s):
        assert set(m_l) == set(m_s)
        for k in m_l:
            assert float(m_l[k]) == float(m_s[k]), (k, m_l[k], m_s[k])


def test_sparse_uplink_auto_resolution_and_validation():
    sim, _ = _make()                                   # auto: topk -> on
    assert sim.sparse
    sim, _ = _make(compressor="sign")                  # auto: sign -> off
    assert not sim.sparse
    sim, _ = _make(sparse_uplink=False)
    assert not sim.sparse
    with pytest.raises(ValueError, match="sparse_uplink"):
        FedConfig(algorithm="fedcams", compressor="sign", sparse_uplink=True)


# -- select-once: top_k count in the traced pipeline -------------------------


def _count_primitive(jaxpr, name: str) -> int:
    """Occurrences of a primitive in a jaxpr, including sub-jaxprs."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for sub in vs:
                if isinstance(sub, jax.core.ClosedJaxpr):
                    n += _count_primitive(sub.jaxpr, name)
                elif isinstance(sub, jax.core.Jaxpr):
                    n += _count_primitive(sub, name)
    return n


def _uplink_jaxpr(sparse: bool, ratio=1 / 8, d=256, c=3):
    from repro.comm.wire import make_wire_codec
    comp = make_compressor("blocktopk", ratio, 64)
    codec = make_wire_codec("blocktopk", ratio, 64)
    delta = jnp.zeros((c, d))
    errs = jnp.zeros((c, d))
    pos = jnp.arange(c)
    rng = jax.random.PRNGKey(0)
    if sparse:
        return jax.make_jaxpr(
            lambda tt, pp: client_uplink_sparse(comp, codec, d, rng, tt,
                                                pp))(delta + errs, pos)
    return jax.make_jaxpr(
        lambda dd, ee, pp: client_uplink(comp, codec, d, rng, dd, ee, pp))(
            delta, errs, pos)


def test_wire_mode_selects_once_per_client():
    """The sparse wire uplink traces exactly ONE lax.top_k (the
    compressor's selection); encode_from_selection adds none. At k=1 the
    argmax fast path brings it to zero. The dense wire uplink's top_k count
    is >= the sparse one (it re-selects inside codec.encode)."""
    sparse = _uplink_jaxpr(sparse=True)
    assert _count_primitive(sparse.jaxpr, "top_k") == 1
    dense = _uplink_jaxpr(sparse=False)
    assert _count_primitive(dense.jaxpr, "top_k") >= 1
    k1 = _uplink_jaxpr(sparse=True, ratio=1 / 64)     # kb=1 -> argmax
    assert _count_primitive(k1.jaxpr, "top_k") == 0
    assert _count_primitive(k1.jaxpr, "argmax") == 1


def test_full_wire_round_top_k_budget():
    """Whole sparse wire round: one selection per client block plus the
    round-level gamma diagnostic — nothing else runs top_k."""
    sim, st = _make(compressor="blocktopk", wire=True, sparse_uplink=True)
    batches, idx, keys = _stage(1)
    b0 = jax.tree.map(lambda x: x[0], batches)
    from repro.core.sim import _CoreState
    jaxpr = jax.make_jaxpr(
        lambda c, b, i, k: sim._round_impl(c, b, i, k, jnp.int32(0)))(
            _CoreState(*st[:5]), b0, idx[0], keys[0])
    assert _count_primitive(jaxpr.jaxpr, "top_k") == 2  # selection + gamma
