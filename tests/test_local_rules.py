"""The pluggable local-update layer (core/local.py, DESIGN.md §8): rule
math, sgd inertness (explicit == default, both backends), convergence
sanity and EF exactness for every rule/knob, and the FedConfig
construction-time validation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs.base import FedConfig, ModelConfig, TrainConfig
from repro.core.local import (hetero_step_counts, local_lr, make_local_update,
                              run_local_steps)
from repro.core.sim import FedSim
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=12, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)
M, N, K = 12, 4, 2


def _run(rounds=20, seed=0, **fed_kw):
    kw = dict(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=K,
              num_clients=M, participating=N, compressor="topk",
              compress_ratio=1 / 8)
    kw.update(fed_kw)
    fed = FedConfig(**kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(seed)))
    rng = jax.random.PRNGKey(seed + 1)
    losses = []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, M, N))
        b = DATA.round_batches(idx, r, K, 16)
        st, met = sim.round(st, jax.tree.map(jnp.asarray, b),
                            jnp.asarray(idx), k2)
        losses.append(float(met["loss"]))
    return losses, st


def _flat(params):
    return np.asarray(ravel_pytree(params)[0])


# -- rule math (unit level) --------------------------------------------------


def test_sgd_rule_math():
    rule = make_local_update(FedConfig(local_opt="sgd"))
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    c = rule.init_carry(p)
    assert c == ()
    p1, c1 = rule.step(p, c, g, 0.1, p)
    np.testing.assert_array_equal(np.asarray(p1["w"]),
                                  np.array([0.95, 2.1], np.float32))


def test_sgdm_rule_math():
    """Heavy ball: u ← β·u + g, x ← x − η·u."""
    rule = make_local_update(FedConfig(local_opt="sgdm", local_momentum=0.5))
    p = {"w": jnp.array([1.0])}
    c = rule.init_carry(p)
    np.testing.assert_array_equal(np.asarray(c["w"]), np.array([0.0]))
    p1, u1 = rule.step(p, c, {"w": jnp.array([1.0])}, 0.1, p)
    assert float(p1["w"][0]) == pytest.approx(1.0 - 0.1 * 1.0)
    p2, u2 = rule.step(p1, u1, {"w": jnp.array([1.0])}, 0.1, p)
    # u2 = 0.5*1 + 1 = 1.5 -> p2 = 0.9 - 0.15
    assert float(u2["w"][0]) == pytest.approx(1.5)
    assert float(p2["w"][0]) == pytest.approx(0.9 - 0.15)


def test_prox_rule_math():
    """FedProx: x ← x − η·(g + μ·(x − x₀)) pulls toward the anchor."""
    rule = make_local_update(FedConfig(local_opt="prox", prox_mu=2.0))
    anchor = {"w": jnp.array([0.0])}
    p = {"w": jnp.array([1.0])}
    p1, _ = rule.step(p, rule.init_carry(p), {"w": jnp.array([0.0])}, 0.1,
                      anchor)
    # zero gradient: the proximal term alone moves x toward x0
    assert float(p1["w"][0]) == pytest.approx(1.0 - 0.1 * 2.0 * 1.0)


def test_local_lr_schedule():
    fed = FedConfig(eta_l=0.1, eta_l_decay=1.0)
    assert local_lr(fed, jnp.int32(7)) == 0.1  # plain float when off
    fed = FedConfig(eta_l=0.1, eta_l_decay=0.5)
    assert float(local_lr(fed, jnp.int32(0))) == pytest.approx(0.1)
    assert float(local_lr(fed, jnp.int32(3))) == pytest.approx(0.1 * 0.125)


def test_hetero_step_counts_range_and_determinism():
    fed = FedConfig(local_steps=4, local_steps_min=2)
    rng = jax.random.PRNGKey(0)
    k1 = np.asarray(hetero_step_counts(fed, rng, 64))
    k2 = np.asarray(hetero_step_counts(fed, rng, 64))
    np.testing.assert_array_equal(k1, k2)  # same rng -> same draw
    assert k1.min() >= 2 and k1.max() <= 4
    assert len(np.unique(k1)) > 1  # actually heterogeneous
    assert hetero_step_counts(FedConfig(local_steps=4), rng, 8) is None


def test_run_local_steps_masks_past_k_i():
    """k_i = 1 with K = 3 staged batches must equal a single step."""
    rule = make_local_update(FedConfig(local_opt="sgd"))
    p0 = {"w": jnp.array([1.0, -2.0])}
    batches = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)

    def grad_fn(p, b):
        return jnp.sum(b), {"w": b}

    one, loss1 = run_local_steps(rule, grad_fn, p0, batches, 0.1,
                                 k_i=jnp.int32(1))
    ref, _ = rule.step(p0, (), {"w": batches[0]}, 0.1, p0)
    np.testing.assert_array_equal(np.asarray(one["w"]), np.asarray(ref["w"]))
    assert float(loss1) == pytest.approx(float(jnp.sum(batches[0])))


# -- sgd is inert (both backends) --------------------------------------------


def test_sgd_explicit_equals_default_sim():
    """local_opt="sgd" is the default rule: explicitly selecting it must be
    bit-identical (the new plumbing adds nothing to the sgd round)."""
    _, st_def = _run(rounds=4)
    _, st_sgd = _run(rounds=4, local_opt="sgd", local_momentum=0.37,
                     prox_mu=0.91)  # unused hyperparams must not leak
    np.testing.assert_array_equal(_flat(st_def.params), _flat(st_sgd.params))
    np.testing.assert_array_equal(np.asarray(st_def.errors),
                                  np.asarray(st_sgd.errors))


def _mesh_history(rounds=4, **fed_kw):
    from repro.core.api import FederatedTrainer
    from repro.data.synthetic import FederatedLMData
    from repro.launch.mesh import make_mesh
    from repro.models.model import Model

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32")
    tr = FederatedTrainer(
        fed=FedConfig(algorithm="fedams", num_clients=1, local_steps=2,
                      client_axes=(), eta=0.3, eta_l=0.05, **fed_kw),
        train=TrainConfig(global_batch=4, seq_len=16, rounds=rounds,
                          remat_policy="none", log_every=100),
        model=Model(cfg, tp=1), mesh=make_mesh((1, 1), ("data", "model")))
    tr.lm_data = FederatedLMData(num_clients=1, vocab_size=64)
    h = tr.run(log=None)
    return [x["loss"] for x in h], _flat(tr.params)


def test_sgd_explicit_equals_default_mesh():
    h_def, p_def = _mesh_history()
    h_sgd, p_sgd = _mesh_history(local_opt="sgd", local_momentum=0.37,
                                 prox_mu=0.91)
    assert h_def == h_sgd
    np.testing.assert_array_equal(p_def, p_sgd)


# -- convergence sanity + the knob actually biting ---------------------------


@pytest.mark.parametrize("fed_kw", [{"local_opt": "sgdm"},
                                    {"local_opt": "sgdm",
                                     "local_momentum": 0.5},
                                    {"local_opt": "prox"},
                                    {"local_opt": "prox", "prox_mu": 0.1},
                                    {"eta_l_decay": 0.95},
                                    {"local_steps_min": 1},
                                    {"local_steps": 4,
                                     "local_steps_min": 2}])
def test_rule_converges_and_differs_from_sgd(fed_kw):
    losses, st = _run(**fed_kw)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05
    if fed_kw.get("local_steps", K) == K:
        _, st_sgd = _run(rounds=20)
        assert not np.array_equal(_flat(st.params), _flat(st_sgd.params)), \
            f"{fed_kw} produced the sgd round — knob is inert"


@pytest.mark.parametrize("fed_kw", [{"local_opt": "sgdm"},
                                    {"local_opt": "prox"},
                                    {"eta_l_decay": 0.9},
                                    {"local_steps_min": 1}])
def test_rule_converges_mesh(fed_kw):
    h, _ = _mesh_history(rounds=6, **fed_kw)
    assert np.isfinite(h).all()
    assert h[-1] < h[0]


# -- EF exactness per rule ---------------------------------------------------


@pytest.mark.parametrize("fed_kw", [{"local_opt": "sgd"},
                                    {"local_opt": "sgdm"},
                                    {"local_opt": "prox"},
                                    {"eta_l_decay": 0.9},
                                    {"local_steps_min": 1}])
def test_ef_tracks_exactly_what_was_sent(fed_kw):
    """For every local rule, the uplink's error feedback must satisfy
    new_err == (delta + err) − hat EXACTLY (same fp ops): EF tracks the
    value the wire carried, whatever produced the delta."""
    kw = dict(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=K,
              num_clients=M, participating=N, compressor="topk",
              compress_ratio=1 / 8)
    kw.update(fed_kw)
    fed = FedConfig(**kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    idx = np.arange(N)
    b = jax.tree.map(jnp.asarray, DATA.round_batches(idx, 0, K, 16))
    rng = jax.random.PRNGKey(3)
    start = sim.unravel(st.x_client)
    errs = jax.random.normal(jax.random.PRNGKey(9), (N, sim._d)) * 0.01
    k_all = hetero_step_counts(fed, rng, N)
    hats, new_errs, delta, _ = sim._clients_block(
        start, st.x_client, b, errs, jnp.arange(N), rng,
        local_lr(fed, jnp.int32(0)), k_all)
    np.testing.assert_array_equal(np.asarray(new_errs),
                                  np.asarray((delta + errs) - hats))
    assert np.abs(np.asarray(delta)).sum() > 0


# -- construction-time config validation -------------------------------------


@pytest.mark.parametrize("bad_kw", [{"algorithm": "fedcamsx"},
                                    {"algorithm": "FEDCAMS"},
                                    {"option": 3},
                                    {"compressor": "topkk"},
                                    {"aggregation": "sparse_topk"},
                                    {"local_opt": "adam"},
                                    {"wire_pack_impl": "triton"},
                                    {"eta_l_decay": 0.0},
                                    {"eta_l_decay": 1.5},
                                    {"local_steps_min": -1},
                                    {"local_steps": 2, "local_steps_min": 3}])
def test_fedconfig_rejects_typos_at_construction(bad_kw):
    with pytest.raises(ValueError, match="FedConfig"):
        FedConfig(**bad_kw)


def test_fedconfig_accepts_every_runtime_compressor_name():
    """The validated set must cover everything make_compressor accepts —
    including the "identity" alias for "none"."""
    for name in ("topk", "blocktopk", "sign", "packedsign", "randk", "int8",
                 "none", "identity"):
        FedConfig(compressor=name)


def test_fedconfig_replace_revalidates():
    fed = FedConfig()
    with pytest.raises(ValueError, match="FedConfig"):
        dataclasses.replace(fed, compressor="nope")
