"""Direct coverage for core/sampling.py (paper §3.2): without-replacement
sampling with uniform inclusion frequency, mask shapes/sizes, and the
Gumbel weighted sampler's weight monotonicity — previously only exercised
indirectly through the round executors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sampling import (participation_mask, sample_clients,
                                 weighted_participation_mask)


def test_sample_clients_without_replacement():
    m, n = 20, 8
    for s in range(50):
        idx = np.asarray(sample_clients(jax.random.PRNGKey(s), m, n))
        assert idx.shape == (n,) and idx.dtype == np.int32
        assert len(np.unique(idx)) == n          # no replacement
        assert idx.min() >= 0 and idx.max() < m


@pytest.mark.parametrize("n", [0, 20, 25])
def test_sample_clients_full_participation_edge(n):
    """n in {0, m, >m} means full participation: identity permutation."""
    idx = np.asarray(sample_clients(jax.random.PRNGKey(0), 20, n))
    np.testing.assert_array_equal(idx, np.arange(20))


def test_sample_clients_uniform_inclusion_frequency():
    """P{i ∈ S_t} = n/m for every client (paper §3.2): over many rounds the
    per-client inclusion frequency concentrates around n/m."""
    m, n, rounds = 16, 4, 2000
    counts = np.zeros(m)
    for s in range(rounds):
        counts[np.asarray(sample_clients(jax.random.PRNGKey(s), m, n))] += 1
    freq = counts / rounds
    # binomial std per client is sqrt(p(1-p)/rounds) ≈ 0.0097; 5 sigma
    np.testing.assert_allclose(freq, n / m, atol=0.05)


def test_participation_mask_size_and_membership():
    m, n = 20, 6
    rng = jax.random.PRNGKey(3)
    mask = np.asarray(participation_mask(rng, m, n))
    assert mask.shape == (m,)
    assert set(np.unique(mask)) <= {0.0, 1.0}
    assert mask.sum() == n
    # the mask marks exactly the sampled indices (shared rng => same draw)
    idx = np.asarray(sample_clients(rng, m, n))
    np.testing.assert_array_equal(np.flatnonzero(mask), np.sort(idx))


def test_participation_mask_full_when_n_zero_or_m():
    for n in (0, 8):
        mask = np.asarray(participation_mask(jax.random.PRNGKey(0), 8, n))
        np.testing.assert_array_equal(mask, np.ones(8))


def test_weighted_mask_size_and_full_participation():
    w = jnp.ones(10)
    mask = np.asarray(weighted_participation_mask(jax.random.PRNGKey(0), w, 4))
    assert mask.shape == (10,) and mask.sum() == 4
    assert set(np.unique(mask)) <= {0.0, 1.0}
    for n in (0, 10, 12):
        full = np.asarray(weighted_participation_mask(
            jax.random.PRNGKey(0), w, n))
        np.testing.assert_array_equal(full, np.ones(10))


def test_weighted_mask_monotone_in_weight():
    """Inclusion frequency must increase with weight (Gumbel top-n samples
    ∝ weights without replacement): a client with 8x the weight of another
    is selected more often; zero-ish weight is (almost) never selected."""
    m, n, rounds = 8, 2, 1500
    weights = jnp.asarray([8.0, 4.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1e-30])
    counts = np.zeros(m)
    for s in range(rounds):
        mask = np.asarray(weighted_participation_mask(
            jax.random.PRNGKey(s), weights, n))
        counts += mask
    freq = counts / rounds
    assert freq[0] > freq[1] > freq[3]      # monotone across 8x/4x/1x
    assert freq[0] > 2.5 * freq[3]          # and materially so
    assert freq[7] < 0.01                   # ~zero weight ~never sampled


def test_weighted_mask_uniform_weights_match_unweighted_frequency():
    """With uniform weights the Gumbel sampler reduces to uniform
    without-replacement sampling: inclusion frequency ≈ n/m."""
    m, n, rounds = 12, 3, 1500
    counts = np.zeros(m)
    for s in range(rounds):
        counts += np.asarray(weighted_participation_mask(
            jax.random.PRNGKey(s), jnp.ones(m), n))
    np.testing.assert_allclose(counts / rounds, n / m, atol=0.05)
