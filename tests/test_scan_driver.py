"""Scan-driven multi-round execution: loop/scan parity, donation safety,
client chunking, and the host-side exact counters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig, TrainConfig
from repro.core.rounds import FedSim, _CoreState
from repro.core.sampling import sample_clients
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=12, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)
M, N, K = 12, 4, 2


def _make(**fed_kw):
    kw = dict(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=K,
              num_clients=M, participating=N, compressor="topk",
              compress_ratio=1 / 8)
    kw.update(fed_kw)
    fed = FedConfig(**kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    return sim, st


def _stage(rounds):
    rng = jax.random.PRNGKey(1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, M, N))
        batches.append(DATA.round_batches(idx, r, K, 16))
        idxs.append(idx)
        keys.append(k2)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    return stacked, jnp.asarray(np.stack(idxs)), jnp.stack(keys)


def _flat(params):
    return jax.flatten_util.ravel_pytree(params)[0]


@pytest.mark.parametrize("fed_kw", [{}, {"wire": True},
                                    {"wire": True, "two_way": True},
                                    {"compressor": "sign"},
                                    {"local_opt": "sgdm"},
                                    {"local_opt": "prox"},
                                    {"eta_l_decay": 0.9},
                                    {"local_steps_min": 1},
                                    {"local_opt": "sgdm", "eta_l_decay": 0.9,
                                     "local_steps_min": 1,
                                     "client_chunk": 2}])
def test_scan_driver_bit_identical_to_loop(fed_kw):
    """run_rounds == R x round: same final state AND same per-round
    metrics, bit for bit (including wire/transport metrics, the local-rule
    carries, the eta_l_decay round-index threading, and the
    heterogeneous-K draws)."""
    R = 5
    batches, idx, keys = _stage(R)

    sim_l, st_l = _make(**fed_kw)
    mets_l = []
    for r in range(R):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st_l, met = sim_l.round(st_l, b_r, idx[r], keys[r])
        mets_l.append(met)

    sim_s, st_s = _make(**fed_kw)
    st_s, mets_s = sim_s.run_rounds(st_s, batches, idx, keys)

    assert bool(jnp.all(_flat(st_l.params) == _flat(st_s.params)))
    assert bool(jnp.all(st_l.errors == st_s.errors))
    assert bool(jnp.all(st_l.server_error == st_s.server_error))
    assert st_l.bits == st_s.bits and st_l.round == st_s.round == R
    assert len(mets_s) == R
    for m_l, m_s in zip(mets_l, mets_s):
        assert set(m_l) == set(m_s)
        for k in m_l:
            assert float(m_l[k]) == float(m_s[k]), (k, m_l[k], m_s[k])


def test_scan_driver_resumes_mid_stream():
    """3 + 2 scanned rounds == 5 scanned rounds (counters carry across)."""
    R = 5
    batches, idx, keys = _stage(R)
    part = lambda x, lo, hi: jax.tree.map(lambda a: a[lo:hi], x)

    sim_a, st_a = _make()
    st_a, _ = sim_a.run_rounds(st_a, *[part(x, 0, 3) for x in
                                       (batches, idx, keys)])
    st_a, _ = sim_a.run_rounds(st_a, *[part(x, 3, 5) for x in
                                       (batches, idx, keys)])

    sim_b, st_b = _make()
    st_b, _ = sim_b.run_rounds(st_b, batches, idx, keys)
    assert bool(jnp.all(_flat(st_a.params) == _flat(st_b.params)))
    assert st_a.bits == st_b.bits and st_a.round == st_b.round


def test_donated_round_matches_pure_computation():
    """Donation must not alias-corrupt the EF update: the donating round
    produces exactly what the pure (non-donated) round body computes from
    saved copies of the same inputs."""
    R = 3
    batches, idx, keys = _stage(R)
    sim, st = _make()
    pure_fn = jax.jit(sim._round_impl)  # no donation: inputs stay live

    for r in range(R):
        b_r = jax.tree.map(lambda x: x[r], batches)
        # deep host copies taken BEFORE the donating call consumes st
        saved = _CoreState(*jax.tree.map(lambda x: jnp.array(np.asarray(x)),
                                         _CoreState(*st[:5])))
        st, _ = sim.round(st, b_r, idx[r], keys[r])
        ref_core, _ = pure_fn(saved, b_r, idx[r], keys[r], jnp.int32(r))
        assert bool(jnp.all(st.errors == ref_core.errors)), f"round {r}"
        assert bool(jnp.all(_flat(st.params) == _flat(ref_core.params)))
        assert bool(jnp.all(st.x_client == ref_core.x_client))


def test_client_chunk_bounds_match_full_vmap():
    """client_chunk mode computes the same round (up to summation order)."""
    R = 4
    batches, idx, keys = _stage(R)
    sim_f, st_f = _make()
    sim_c, st_c = _make(client_chunk=2)
    for r in range(R):
        b_r = jax.tree.map(lambda x: x[r], batches)
        st_f, met_f = sim_f.round(st_f, b_r, idx[r], keys[r])
        st_c, met_c = sim_c.round(st_c, b_r, idx[r], keys[r])
    np.testing.assert_allclose(np.asarray(_flat(st_c.params)),
                               np.asarray(_flat(st_f.params)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_c.errors),
                               np.asarray(st_f.errors), atol=1e-6)
    assert st_c.bits == st_f.bits
    assert float(met_c["loss"]) == pytest.approx(float(met_f["loss"]),
                                                 abs=1e-6)


def test_bits_counter_is_exact_python_int():
    """The bits counter must be a host-side int: fp32 accumulation is only
    exact below 2^24, which large-d rounds exceed per round."""
    sim, st = _make()
    assert isinstance(st.bits, int) and isinstance(st.round, int)
    # simulate the large-d regime: a round increment far above 2^24 must
    # accumulate exactly (fp32 would drop increments entirely)
    big = st._replace(bits=int(2 ** 53))
    incr = sim._bits_per_round(N)
    assert big.bits + incr == 2 ** 53 + incr  # exact, no rounding
    batches, idx, keys = _stage(1)
    b0 = jax.tree.map(lambda x: x[0], batches)
    st2, met = sim.round(big, b0, idx[0], keys[0])
    assert st2.bits == 2 ** 53 + incr
    assert met["bits"] == st2.bits


def test_trainer_scan_rounds_matches_loop_history():
    """FederatedTrainer.run(scan_rounds=R) reproduces the per-round loop's
    history exactly (simulation backend)."""
    from repro.core.api import FederatedTrainer

    def make():
        tr = FederatedTrainer(
            fed=FedConfig(algorithm="fedcams", num_clients=8, participating=4,
                          local_steps=2, compressor="topk",
                          compress_ratio=1 / 8, eta=0.1, eta_l=0.1),
            train=TrainConfig(rounds=6, log_every=100),
            loss_fn=lambda p, b: mlp_loss(p, b, MC),
            init_params=pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
        tr.data = FederatedClassification(num_clients=8, num_classes=4,
                                          feature_dim=16, seed=0)
        return tr

    h_loop = make().run(log=None)
    h_scan = make().run(scan_rounds=4, log=None)  # 4 + 2 tail chunk
    assert len(h_loop) == len(h_scan) == 6
    for a, b in zip(h_loop, h_scan):
        assert set(a) == set(b)
        for k in a:
            assert a[k] == b[k], (k, a[k], b[k])


def test_client_chunk_must_divide_round_size():
    with pytest.raises(ValueError, match="client_chunk"):
        _make(client_chunk=3)  # n=4 participating


def test_client_chunk_rejects_runtime_mismatch():
    """A round whose actual client count breaks the chunking must raise,
    not silently fall back to the full (n, d) vmap."""
    sim, st = _make(client_chunk=2)  # valid for the configured n=4
    batches, idx, keys = _stage(1)
    b0 = jax.tree.map(lambda x: x[0][:3], batches)  # 3 clients at runtime
    with pytest.raises(ValueError, match="client_chunk"):
        sim.round(st, b0, idx[0][:3], keys[0])


def test_init_params_survive_donation():
    """The caller's init pytree must stay usable after the first donating
    round (FedSim.init copies it into the state)."""
    sim, st = _make()
    p0 = pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0))
    st = sim.init(p0)
    batches, idx, keys = _stage(1)
    b0 = jax.tree.map(lambda x: x[0], batches)
    sim.round(st, b0, idx[0], keys[0])
    # p0 would raise "Array has been deleted" if the state aliased it
    assert np.isfinite(np.asarray(_flat(p0))).all()


def test_trainer_scan_checkpoints_at_chunk_boundaries(tmp_path, monkeypatch):
    """Scan mode snapshots once per chunk that crosses a checkpoint round
    (mid-chunk states don't exist; the boundary state is saved)."""
    from repro.core.api import FederatedTrainer
    monkeypatch.chdir(tmp_path)
    tr = FederatedTrainer(
        fed=FedConfig(algorithm="fedcams", num_clients=8, participating=4,
                      local_steps=2, compressor="topk", compress_ratio=1 / 8,
                      eta=0.1, eta_l=0.1),
        train=TrainConfig(rounds=6, log_every=100, checkpoint_every=5),
        loss_fn=lambda p, b: mlp_loss(p, b, MC),
        init_params=pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    tr.data = FederatedClassification(num_clients=8, num_classes=4,
                                      feature_dim=16, seed=0)
    tr.run(scan_rounds=6, log=None)  # one chunk containing round 5
    assert (tmp_path / "ckpt_round5" / "manifest.json").exists()


@pytest.mark.parametrize("fed_kw", [{}, {"local_opt": "sgdm"},
                                    {"local_opt": "prox"},
                                    {"eta_l_decay": 0.9,
                                     "local_steps_min": 1}])
def test_trainer_scan_rounds_mesh_backend(fed_kw):
    """Mesh backend scan driver: same history as the per-round mesh loop,
    for every local rule and scenario knob (the scan carries the local-rule
    state and the device round counter the eta_l schedule reads)."""
    from repro.core.api import FederatedTrainer
    from repro.data.synthetic import FederatedLMData
    from repro.launch.mesh import make_mesh
    from repro.models.model import Model

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32")

    def make():
        tr = FederatedTrainer(
            fed=FedConfig(algorithm="fedams", num_clients=1, local_steps=2,
                          client_axes=(), eta=0.3, eta_l=0.05, **fed_kw),
            train=TrainConfig(global_batch=4, seq_len=16, rounds=5,
                              remat_policy="none", log_every=100),
            model=Model(cfg, tp=1), mesh=make_mesh((1, 1), ("data", "model")))
        tr.lm_data = FederatedLMData(num_clients=1, vocab_size=64)
        return tr

    h_loop = make().run(log=None)
    h_scan = make().run(scan_rounds=3, log=None)
    assert [h["loss"] for h in h_loop] == [h["loss"] for h in h_scan]
