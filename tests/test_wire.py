"""repro.comm: wire-format round trips, measured-vs-analytic byte counts,
bitpack kernels, transport simulation, and FedSim wire mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # see tests/hypothesis_fallback.py
    from hypothesis_fallback import given, settings, st

from repro.comm import (HEADER_BYTES, CommLog, NetworkConfig,
                        SimulatedNetwork, make_blocktopk_codec,
                        make_dense32_codec, make_sign_codec, make_topk_codec,
                        make_wire_codec, measured_vs_analytic, parse_header)
from repro.comm.wire import pack_uint as wire_pack_uint
from repro.comm.wire import unpack_uint as wire_unpack_uint
from repro.configs.base import FedConfig
from repro.core.rounds import FedSim, mesh_wire_bytes
from repro.kernels import (pack_bits, pack_bits_ref, pack_uint,
                           pack_uint_words, unpack_bits, unpack_bits_ref,
                           unpack_uint, unpack_uint_words)
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss


def _vec(seed, d):
    return jnp.asarray(np.random.default_rng(seed).normal(size=d),
                       jnp.float32)


# -- codec round trips -------------------------------------------------------


CODECS = {
    "dense32": lambda: make_dense32_codec(),
    "topk": lambda: make_topk_codec(1 / 8),
    "blocktopk": lambda: make_blocktopk_codec(1 / 8, block=64),
    "sign": lambda: make_sign_codec(),
    "sign_block": lambda: make_sign_codec(block=32),
}


@pytest.mark.parametrize("name", list(CODECS))
@pytest.mark.parametrize("d", [8, 37, 100, 5000])
def test_roundtrip_bit_exact(name, d):
    """decode(encode(x)) == the dense compressor output, bit-for-bit."""
    codec = CODECS[name]()
    x = _vec(d, d)
    buf = codec.encode(x)
    assert buf.dtype == jnp.uint8
    assert buf.shape[0] == codec.nbytes(d)
    dec = codec.decode(buf, d)
    ref = codec.compressor.compress(x).reshape(-1)
    assert np.array_equal(np.asarray(dec), np.asarray(ref)), name


def test_roundtrip_with_exact_zeros():
    """sign(0) := +1 — the wire and the dense compressor must agree on it."""
    x = jnp.asarray([0.0, -1.0, 2.0, 0.0, -0.5, 0.25, 0.0, 3.0], jnp.float32)
    for name in ("sign", "topk", "blocktopk"):
        codec = CODECS[name]()
        dec = codec.decode(codec.encode(x), x.size)
        ref = codec.compressor.compress(x).reshape(-1)
        assert np.array_equal(np.asarray(dec), np.asarray(ref)), name


def test_roundtrip_under_jit_and_vmap():
    codec = make_topk_codec(1 / 4)
    d = 128
    xs = jnp.stack([_vec(i, d) for i in range(4)])
    f = jax.jit(jax.vmap(lambda x: codec.decode(codec.encode(x), d)))
    ref = jnp.stack([codec.compressor.compress(x) for x in xs])
    assert np.array_equal(np.asarray(f(xs)), np.asarray(ref))


def test_narrow_value_dtypes_roundtrip_through_wire_dtype():
    d = 256
    x = _vec(3, d)
    for vd in ("float16", "bfloat16"):
        codec = make_topk_codec(1 / 8, vd)
        assert not codec.exact
        dec = codec.decode(codec.encode(x), d)
        ref = codec.compressor.compress(x).astype(jnp.dtype(vd))
        assert np.array_equal(np.asarray(dec),
                              np.asarray(ref.astype(jnp.float32)))


def test_blocktopk_int8_quantization_bounded():
    d = 512
    x = _vec(4, d)
    codec = make_blocktopk_codec(1 / 8, block=128, value_dtype="int8")
    dec = codec.decode(codec.encode(x), d)
    ref = codec.compressor.compress(x)
    kept = np.asarray(ref) != 0
    err = np.abs(np.asarray(dec) - np.asarray(ref))[kept]
    # int8 vs per-block fp32 scale: error <= scale/2 = max|v|/254 per block
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 254 + 1e-7


def test_header_parses():
    codec = make_blocktopk_codec(1 / 4, block=64)
    h = parse_header(codec.encode(_vec(0, 200)))
    assert h == {"codec": "blocktopk", "value_dtype": "float32", "d": 200,
                 "k": 16, "block": 64}
    with pytest.raises(ValueError):
        parse_header(jnp.zeros(HEADER_BYTES, jnp.uint8))


def test_make_wire_codec_registry():
    for name in ("dense32", "none", "topk", "blocktopk", "sign", "packedsign"):
        assert make_wire_codec(name, 1 / 8).name
    with pytest.raises(ValueError):
        make_wire_codec("randk")


# -- measured vs analytic bytes (paper Table 1) ------------------------------


@pytest.mark.parametrize("d", [1000, 11_200_000])
def test_measured_bytes_match_analytic_bits(d):
    """Measured wire bits match Table 1's analytic counts within the
    documented per-message header (and beat them where indices are packed
    below 32 bits)."""
    header_bits = 8 * HEADER_BYTES
    for name in ("dense32", "topk", "sign"):
        r = measured_vs_analytic(make_wire_codec(name, 1 / 64), d)
        # allow <=7 padding bits (sign packs d bits to whole bytes)
        assert 0 <= r["overhead_bits"] <= header_bits + 7, r
    r = measured_vs_analytic(make_wire_codec("blocktopk", 1 / 64), d)
    if d > 10_000:  # 11-bit packed indices beat the analytic 32-bit ones
        assert r["measured_bits"] < r["analytic_bits"]
    assert r["measured_bits"] <= r["analytic_bits"] + header_bits + 7


@pytest.mark.parametrize("d", [8, 100, 5000])
def test_sign_codec_pallas_pack_impl_byte_identical(d):
    """The Pallas bitpack path produces byte-identical wire buffers."""
    x = _vec(d + 1, d)
    jnp_codec = make_sign_codec()
    pl_codec = make_sign_codec(pack_impl="pallas")
    b1, b2 = jnp_codec.encode(x), pl_codec.encode(x)
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert np.array_equal(np.asarray(pl_codec.decode(b1, d)),
                          np.asarray(jnp_codec.decode(b1, d)))


# -- bitpack kernels ---------------------------------------------------------


@pytest.mark.parametrize("n,block", [(2048, 2048), (8192, 1024), (4096, 256)])
def test_bitpack_kernel_matches_refs(n, block):
    bits = jnp.asarray(np.random.default_rng(n).integers(0, 2, n), jnp.uint8)
    packed = pack_bits(bits, block=block)
    assert np.array_equal(np.asarray(packed), np.asarray(pack_bits_ref(bits)))
    assert np.array_equal(np.asarray(packed), np.packbits(np.asarray(bits)))
    assert np.array_equal(np.asarray(unpack_bits(packed, block=block)),
                          np.asarray(bits))
    assert np.array_equal(np.asarray(unpack_bits_ref(packed)),
                          np.asarray(bits))


def _naive_pack(vals, nbits):
    """The bit-matrix formulation the word-wise paths must match."""
    shifts = np.arange(nbits - 1, -1, -1, dtype=np.uint64)
    bits = ((np.asarray(vals, np.uint64)[:, None] >> shifts) & 1)
    return np.packbits(bits.astype(np.uint8).reshape(-1))


@given(st.integers(1, 32), st.integers(1, 3000))
def test_pack_uint_roundtrip_all_widths(nbits, count):
    """Property: for every nbits in 1..32, jnp word-wise and Pallas paths
    are byte-identical to the bit-matrix oracle and invert exactly."""
    rng = np.random.default_rng(nbits * 10007 + count)
    hi = min(2 ** nbits, 2 ** 32)
    vals = jnp.asarray(
        rng.integers(0, hi, count, dtype=np.uint64).astype(np.uint32))
    ref = _naive_pack(vals, nbits)
    for packed in (pack_uint_words(vals, nbits), pack_uint(vals, nbits)):
        assert packed.dtype == jnp.uint8
        assert np.array_equal(np.asarray(packed), ref), (nbits, count)
    packed = pack_uint_words(vals, nbits)
    for un in (unpack_uint_words(packed, nbits, count),
               unpack_uint(packed, nbits, count)):
        assert np.array_equal(np.asarray(un), np.asarray(vals)), (nbits,
                                                                  count)


@pytest.mark.parametrize("nbits", [1, 3, 8, 11, 17, 32])
def test_wire_pack_uint_jnp_vs_pallas_parity(nbits):
    """wire.pack_uint/unpack_uint: both impls byte/value identical."""
    rng = np.random.default_rng(nbits)
    count = 1357
    hi = min(2 ** nbits, 2 ** 32)
    vals = jnp.asarray(
        rng.integers(0, hi, count, dtype=np.uint64).astype(np.uint32))
    b_jnp = wire_pack_uint(vals, nbits)
    b_pl = wire_pack_uint(vals, nbits, "pallas")
    assert np.array_equal(np.asarray(b_jnp), np.asarray(b_pl))
    assert b_jnp.size == (count * nbits + 7) // 8
    u_jnp = wire_unpack_uint(b_jnp, nbits, count)
    u_pl = wire_unpack_uint(b_jnp, nbits, count, "pallas")
    assert np.array_equal(np.asarray(u_jnp), np.asarray(vals))
    assert np.array_equal(np.asarray(u_pl), np.asarray(vals))


def test_blocktopk_codec_pallas_pack_impl_byte_identical():
    """blocktopk's 11-bit index stream through the Pallas kernels produces
    byte-identical wire buffers and decodes."""
    d = 5000
    x = _vec(17, d)
    jc = make_blocktopk_codec(1 / 8, block=2048)
    pc = make_blocktopk_codec(1 / 8, block=2048, pack_impl="pallas")
    b1, b2 = jc.encode(x), pc.encode(x)
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert np.array_equal(np.asarray(pc.decode(b1, d)),
                          np.asarray(jc.decode(b1, d)))
    ref = jc.compressor.compress(x).reshape(-1)
    assert np.array_equal(np.asarray(pc.decode(b2, d)), np.asarray(ref))


# -- transport ---------------------------------------------------------------


def test_transport_metrics_smoke():
    net = SimulatedNetwork(NetworkConfig(seed=7), num_clients=20)
    log = CommLog()
    times = []
    for r in range(10):
        t = net.round([1, 5, 9, 13], uplink_bytes_per_client=125_000,
                      downlink_bytes_per_client=500_000, round_idx=r)
        assert t.round_time_s >= t.mean_client_time_s > 0
        assert t.uplink_bytes == 4 * 125_000
        assert t.downlink_bytes == 4 * 500_000
        assert t.slowest_client in (1, 5, 9, 13)
        log.add(t)
        times.append(t.round_time_s)
    assert log.rounds == 10 and log.total_bytes == 10 * 4 * 625_000
    # deterministic given (seed, round)
    again = net.round([1, 5, 9, 13], 125_000, 500_000, round_idx=0)
    assert again.round_time_s == times[0]
    # more bytes on the same links cannot be faster
    slower = net.round([1, 5, 9, 13], 10 * 125_000, 500_000, round_idx=0)
    assert slower.round_time_s > times[0]


def test_transport_empty_round():
    net = SimulatedNetwork(NetworkConfig(), num_clients=4)
    t = net.round([], 1000, 1000, 0)
    assert t.round_time_s == 0.0 and t.slowest_client == -1
    assert t.uplink_bytes == 0 and t.mean_client_time_s == 0.0


def test_network_requires_wire_mode():
    net = SimulatedNetwork(NetworkConfig(), num_clients=12)
    with pytest.raises(ValueError, match="wire"):
        FedSim(lambda p, b: mlp_loss(p, b, MC),
               FedConfig(algorithm="fedcams", num_clients=12), network=net)


def test_links_for_vectorized_matches_per_id_draw():
    """The batched cold-path draw + searchsorted warm path must reproduce
    the original per-client loop's stream bit-for-bit: one Generator
    keyed (seed, id), two normals, independent of participation order."""
    cfg = NetworkConfig(seed=5)
    net = SimulatedNetwork(cfg, 1000)
    idx = np.array([7, 3, 500, 3, 999, 0])
    up, down = net._links_for(idx)
    mu = -0.5 * cfg.bandwidth_sigma ** 2
    for i, c in enumerate(idx):
        raw = np.random.default_rng((cfg.seed, int(c))).normal(
            mu, cfg.bandwidth_sigma, 2)
        assert up[i] == cfg.uplink_mbps * 1e6 / 8.0 * np.exp(raw[0])
        assert down[i] == cfg.downlink_mbps * 1e6 / 8.0 * np.exp(raw[1])
    # warm path (everything cached) returns the same values
    up2, down2 = net._links_for(idx)
    assert np.array_equal(up, up2) and np.array_equal(down, down2)
    # a fresh network sharing the seed agrees, different arrival order
    net2 = SimulatedNetwork(cfg, 1000)
    up3, down3 = net2._links_for(np.array([999, 0]))
    assert up3[0] == up[4] and down3[1] == down[5]


def test_round_timing_quantiles():
    net = SimulatedNetwork(NetworkConfig(seed=2), 64)
    t = net.round(np.arange(64), 10_000, 10_000, 0)
    per = t.client_times_s
    assert t.p50_client_time_s == float(np.percentile(per, 50))
    assert t.p90_client_time_s == float(np.percentile(per, 90))
    assert t.p50_client_time_s <= t.p90_client_time_s <= t.round_time_s


def test_round_timing_empty_cohort_quantiles():
    t = SimulatedNetwork(NetworkConfig(), 4).round([], 1000, 1000, 0)
    assert t.p50_client_time_s == 0.0 and t.p90_client_time_s == 0.0
    assert t.round_time_s == 0.0 and t.mean_client_time_s == 0.0


def test_transport_straggler_stretches_tail():
    base = NetworkConfig(straggler_prob=0.0, latency_jitter_ms=0.0, seed=3)
    strag = NetworkConfig(straggler_prob=1.0, straggler_slowdown=5.0,
                          latency_jitter_ms=0.0, seed=3)
    n0 = SimulatedNetwork(base, 8)
    n1 = SimulatedNetwork(strag, 8)
    t0 = n0.round(list(range(8)), 10_000, 10_000, 0)
    t1 = n1.round(list(range(8)), 10_000, 10_000, 0)
    assert t1.round_time_s == pytest.approx(5.0 * t0.round_time_s, rel=1e-6)


def test_per_round_draws_keyed_by_client_id_not_position():
    """Regression: latency/straggler draws used to come from one
    per-round Generator indexed by cohort POSITION, so permuting or
    resampling the cohort silently changed a client's timing. They are
    keyed by (seed, round, client_id) now: permuting the cohort permutes
    the times exactly, and a client keeps its draw across different
    cohorts in the same round."""
    cfg = NetworkConfig(straggler_prob=0.3, seed=11)
    net = SimulatedNetwork(cfg, 100)
    idx = np.array([7, 3, 50, 42, 99, 0])
    perm = np.array([3, 5, 0, 2, 4, 1])
    t1 = net.round(idx, 10_000, 10_000, round_idx=4)
    t2 = net.round(idx[perm], 10_000, 10_000, round_idx=4)
    assert np.array_equal(t1.client_times_s[perm], t2.client_times_s)
    assert t1.round_time_s == t2.round_time_s
    assert t1.slowest_client == t2.slowest_client
    # same client in a DIFFERENT cohort: identical time this round
    t3 = net.round(np.array([42, 1, 2]), 10_000, 10_000, round_idx=4)
    pos = int(np.where(idx == 42)[0][0])
    assert t3.client_times_s[0] == t1.client_times_s[pos]
    # ...and the stream still varies across rounds and seeds
    t4 = net.round(idx, 10_000, 10_000, round_idx=5)
    assert not np.array_equal(t1.client_times_s, t4.client_times_s)
    other = SimulatedNetwork(NetworkConfig(straggler_prob=0.3, seed=12), 100)
    t5 = other.round(idx, 10_000, 10_000, round_idx=4)
    assert not np.array_equal(t1.client_times_s, t5.client_times_s)


def test_event_clock_orders_and_breaks_ties_deterministically():
    from repro.comm.transport import EventClock
    clk = EventClock()
    clk.push(2.0, "late")
    clk.push(1.0, "early-first")
    clk.push(1.0, "early-second")
    assert len(clk) == 3 and clk.now == 0.0
    t, p = clk.pop()
    assert (t, p) == (1.0, "early-first")       # tie → insertion order
    assert clk.pop() == (1.0, "early-second")
    assert clk.now == 1.0
    assert clk.pop() == (2.0, "late")
    assert clk.now == 2.0 and len(clk) == 0


def test_commlog_bills_delivered_not_attempted():
    """Regression (billing bugfix): uplink_bytes must count only payloads
    the server actually received; the full cohort's sends stay visible as
    the _attempted diagnostic, and an explicit round_time_s override (the
    deadline-truncated effective wall clock) is what sums into
    sim_time_s."""
    net = SimulatedNetwork(NetworkConfig(seed=7), 8)
    log = CommLog()
    t = net.round([0, 1, 2, 3], 1000, 2000, 0)
    rec = log.record(t, round_time_s=0.5, delivered_uplink_bytes=3000)
    assert log.uplink_bytes == 3000
    assert log.uplink_bytes_attempted == 4000
    assert log.sim_time_s == 0.5
    assert rec["wire_up_bytes"] == 3000
    assert rec["wire_up_bytes_attempted"] == 4000
    assert rec["round_time_s"] == 0.5
    # defaults: everything delivered, raw round time
    rec2 = log.record(t)
    assert log.uplink_bytes == 3000 + 4000
    assert log.sim_time_s == 0.5 + t.round_time_s
    assert rec2["wire_up_bytes"] == rec2["wire_up_bytes_attempted"] == 4000


# -- FedSim wire mode --------------------------------------------------------


MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=12, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)


def _run_sim(rounds=4, **fed_kw):
    fed_kw.setdefault("compressor", "topk")
    fed = FedConfig(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=2,
                    num_clients=12, participating=4,
                    compress_ratio=1 / 8, **fed_kw)
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    rng = jax.random.PRNGKey(1)
    mets = []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        from repro.core.sampling import sample_clients
        idx = np.asarray(sample_clients(k1, 12, 4))
        b = DATA.round_batches(idx, r, 2, 16)
        st, met = sim.round(st, jax.tree.map(jnp.asarray, b),
                            jnp.asarray(idx), k2)
        mets.append(met)
    return st, mets, sim


def test_fedsim_wire_mode_matches_dense_path_bitwise():
    """encode->decode in the round changes nothing numerically (fp32)."""
    st0, _, _ = _run_sim(wire=False)
    st1, _, _ = _run_sim(wire=True)
    f0 = jax.flatten_util.ravel_pytree(st0.params)[0]
    f1 = jax.flatten_util.ravel_pytree(st1.params)[0]
    assert bool(jnp.all(f0 == f1))
    assert bool(jnp.all(st0.errors == st1.errors))


@pytest.mark.parametrize("comp", ["topk", "blocktopk", "sign"])
def test_fedsim_wire_metrics(comp):
    _, mets, sim = _run_sim(compressor=comp, wire=True)
    up = sim.codec.nbytes(sim._d)
    for i, m in enumerate(mets):
        assert m["wire_up_bytes"] == 4 * up
        assert m["round_time_s"] > 0
    # cumulative measured bytes and simulated wall-clock grow monotonically
    assert mets[-1]["wire_bytes"] == sum(m["wire_up_bytes"]
                                         + m["wire_down_bytes"] for m in mets)
    assert mets[-1]["sim_time_s"] == pytest.approx(
        sum(m["round_time_s"] for m in mets))
    # measured uplink agrees with the analytic accounting within the header
    analytic_bits = sim.comp.bits_per_message(sim._d)
    assert 8 * up <= analytic_bits + 8 * HEADER_BYTES + 7


def test_fedsim_wire_two_way_compresses_downlink():
    _, m_one, _ = _run_sim(wire=True)
    _, m_two, _ = _run_sim(wire=True, two_way=True)
    assert m_two[-1]["wire_down_bytes"] < m_one[-1]["wire_down_bytes"] / 3
    assert np.isfinite([float(m["loss"]) for m in m_two]).all()


def test_trainer_history_carries_wire_metrics():
    from repro.core.api import FederatedTrainer
    from repro.configs.base import TrainConfig
    tr = FederatedTrainer(
        fed=FedConfig(algorithm="fedcams", num_clients=8, participating=4,
                      local_steps=2, compressor="sign", eta=0.05, eta_l=0.1,
                      wire=True),
        train=TrainConfig(rounds=3, log_every=100),
        loss_fn=lambda p, b: mlp_loss(p, b, MC),
        init_params=pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)),
        network=SimulatedNetwork(NetworkConfig(seed=11), 8))
    tr.data = FederatedClassification(num_clients=8, num_classes=4,
                                      feature_dim=16, seed=0)
    hist = tr.run(log=None)
    for rec in hist:
        assert rec["wire_bytes"] > 0 and rec["round_time_s"] > 0
    assert hist[-1]["wire_bytes"] > hist[0]["wire_bytes"]


# -- mesh-path accounting ----------------------------------------------------


def test_mesh_wire_bytes_sparse_below_dense():
    tree = {"a": jnp.zeros((64, 64)), "b": jnp.zeros((300,))}
    dense = mesh_wire_bytes(FedConfig(algorithm="fedcams"), tree)
    assert dense == (64 * 64 + 300) * 4
    sparse = mesh_wire_bytes(
        FedConfig(algorithm="fedcams", aggregation="sparse",
                  compressor="blocktopk", compress_ratio=1 / 64), tree)
    packed = mesh_wire_bytes(
        FedConfig(algorithm="fedcams", aggregation="sparse",
                  compressor="packedsign"), tree)
    assert sparse < dense / 8
    assert packed < dense / 16
    # a tp-sharded client pushes every one of its tp device payloads
    assert mesh_wire_bytes(FedConfig(algorithm="fedcams"), tree,
                           tp=4) == 4 * dense
