"""Deterministic stand-in for the subset of ``hypothesis`` the test-suite
uses, so tier-1 collects and runs on images without the dependency.

Only what the tests need is implemented: ``given`` over ``st.integers`` /
``st.sampled_from`` strategies (each test runs against a fixed number of
seeded draws), and a ``settings`` object whose ``register_profile`` /
``load_profile`` control ``max_examples``. Install the real package
(``pip install -r requirements-dev.txt``) for full shrinking/coverage.
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))


st = strategies


class settings:
    """Profile registry mirroring ``hypothesis.settings``'s tiny surface."""

    _profiles = {"default": {"max_examples": 20}}
    _active = "default"

    def __init__(self, **kw):  # used as @settings(...) decorator passthrough
        self._kw = kw

    def __call__(self, fn):
        return fn

    @classmethod
    def register_profile(cls, name, **kw):
        cls._profiles[name] = kw

    @classmethod
    def load_profile(cls, name):
        cls._active = name

    @classmethod
    def max_examples(cls):
        return int(cls._profiles.get(cls._active, {}).get("max_examples", 20))


def given(*strats):
    """Run the test against ``max_examples`` deterministic seeded draws."""

    def deco(fn):
        # NB: the wrapper must expose a ZERO-arg signature — pytest would
        # otherwise read the test's drawn parameters as fixture requests.
        def wrapper():
            for i in range(settings.max_examples()):
                rng = np.random.default_rng(0xC0FFEE + 7919 * i)
                drawn = tuple(s.example(rng) for s in strats)
                fn(*drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
