"""Subprocess side of the sim-vs-mesh differential parity harness.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` via
``tests/conftest.py::run_forced_devices`` (imported by
``tests/test_mesh_parity.py``; never collected by pytest). One process
executes EVERY grid case so both backends of every pair share one jax
init and one XLA codegen (bit-parity claims are same-process claims —
see CHANGES.md PR 3 note).

The differential fixture is a single-leaf model whose local phase has no
floating-point reassociation freedom at all:

    loss(w, batch) = 0.5 · Σ_b Σ_j (w_j − t_{b,j})²   with B = 2 rows

so the gradient is the two-term sum ``(w − t_0) + (w − t_1)`` — float
addition is commutative (only associativity is order-sensitive), so the
vmapped FedSim local phase and the per-device mesh local phase produce
bit-identical deltas (a host-side replay would NOT: XLA fuses
``w − η·g`` into an FMA inside the jitted rounds, so only same-program /
same-fusion pairs are bitwise comparable). Everything downstream — EF
totals, the selection, packed-sign hats, the compacted-Selection
collective, the server update — is then compared at the bit level.
``errors`` equality IS the per-round selection equality: the EF residual
is ``tot`` with exactly the selected coordinates zeroed, so two backends
with bit-equal state that selected differently would disagree on the EF
rows wherever ``tot ≠ 0``. That each backend's selection equals the
*reference* compressor is the already-established other half of the
chain: tests/test_sparse_uplink.py (sim select-once ≡ dense reference),
tests/test_kernels.py (Pallas kernel ≡ ``Compressor.select``), and the
single-device stage properties in tests/test_mesh_parity.py.

Targets are quantized to a 0.25 grid so |tot| ties actually occur and the
``lax.top_k`` lowest-index tie-breaking is exercised end-to-end (both
backends must break them identically for the bitwise comparison to hold).
"""
from __future__ import annotations

import json

import numpy as np

D = 2176        # block_layout → 2 blocks of 2048, 1920-element padded tail
M = 8           # clients == forced host devices
BC = 2          # per-client batch rows per local step (2-term reduce only)
K = 2           # local steps
R = 3           # rounds per case
ETA, ETA_L = 0.25, 0.0625
RATIO = 1.0 / 8.0

# name -> mesh-side FedConfig kwargs. The sim side mirrors the mesh's
# documented topk -> blocktopk remap (core/mesh.py: per-leaf global top-k
# is ill-defined on sharded leaves) and ignores aggregation /
# mesh_sparse_impl; `wire` applies to the sim side only (the mesh's wire
# IS the collective) — at float32 wire value dtype the sim wire path is
# bit-exact, so the same mesh run must match both.
CASES = {
    "dense": dict(algorithm="fedams", compressor="none",
                  aggregation="dense"),
    "topk": dict(algorithm="fedcams", compressor="topk",
                 aggregation="sparse"),
    "blocktopk": dict(algorithm="fedcams", compressor="blocktopk",
                      aggregation="sparse"),
    "packedsign": dict(algorithm="fedcams", compressor="packedsign",
                       aggregation="sparse"),
    # the kernel-routed tentpole path: same grid point as "blocktopk" but
    # the Selection comes out of the fused Pallas topk_ef_sparse kernel
    # (interpret mode on CPU — bit-identical to the compiled TPU kernel)
    "blocktopk_kernel": dict(algorithm="fedcams", compressor="blocktopk",
                             aggregation="sparse",
                             mesh_sparse_impl="kernel"),
    # one-pass fused server ingest (DESIGN.md §3): the gathered (vals, idx)
    # go straight into the m/v/v̂/x update with no dense mean delta. Both
    # sides run the SAME provider (jnp blocked scatter / Pallas
    # fedams_ingest), so the pair stays bitwise comparable; track_gamma off
    # because the γ diagnostic consumes the dense aggregate the fused path
    # never builds.
    "blocktopk_fused": dict(algorithm="fedcams", compressor="blocktopk",
                            aggregation="sparse", fused_ingest="jnp",
                            track_gamma=False),
    "blocktopk_fused_kernel": dict(algorithm="fedcams",
                                   compressor="blocktopk",
                                   aggregation="sparse",
                                   fused_ingest="kernel",
                                   track_gamma=False),
    # two-level hierarchical aggregation (DESIGN.md §scale-out): the 8
    # clients split into 4 edge groups of 2 on a (cgroup=4, data=2) mesh;
    # tier 1 merges each group's selections into a dense partial, tier 2
    # gathers the 4 partials at the root. The sim side runs the same
    # grouping through server_aggregate_sparse_grouped — both sides reduce
    # the group partials with an identical jnp.sum over a stacked (g, d)
    # array, so the pair stays bitwise comparable.
    "blocktopk_hier": dict(algorithm="fedcams", compressor="blocktopk",
                           aggregation="sparse", agg_groups=4),
    "blocktopk_hier_kernel": dict(algorithm="fedcams",
                                  compressor="blocktopk",
                                  aggregation="sparse", agg_groups=4,
                                  mesh_sparse_impl="kernel"),
}


def _round_targets(r: int):
    """(K, GB, D) quantized targets for round ``r`` — the mesh batch.
    Client i owns rows [i·BC, (i+1)·BC) of the GB axis (the "data"-axis
    shard order), every local step."""
    rng = np.random.default_rng(1000 + r)
    t = rng.normal(size=(K, M * BC, D)).astype(np.float32)
    return np.round(t * 4.0) / 4.0


def _sim_batches(t):
    """Mesh (K, GB, D) -> sim (M, K, BC, D), client-major."""
    return t.reshape(K, M, BC, D).transpose(1, 0, 2, 3)


class ParityModel:
    """Single-leaf deterministic model (see module docstring)."""

    def __init__(self, d: int = D):
        self.d = d

    def defs(self):
        from jax.sharding import PartitionSpec as P

        from repro.models import params as pdefs
        return {"w": pdefs.ParamDef((self.d,), P(), dtype="float32")}

    def loss(self, p, b, ctx, remat_policy="none", chunk=0):
        import jax.numpy as jnp
        diff = p["w"][None, :] - b["t"]
        return 0.5 * jnp.sum(diff * diff), ()

    def train_batch_defs(self, global_batch, seq_len):
        from jax.sharding import PartitionSpec as P

        from repro.models import params as pdefs
        return {"t": pdefs.ParamDef((global_batch, self.d), P(None, None),
                                    dtype="float32")}


def _run_mesh(fed, rounds_targets, kernel_impl):
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.configs.base import TrainConfig
    from repro.core.mesh import (build_fed_round, fed_batch_defs,
                                 fed_state_defs, init_fed_state)
    from repro.launch.mesh import make_mesh
    from repro.models import params as pdefs
    from repro.sharding.rules import ParallelContext
    from jax.sharding import PartitionSpec as P

    model = ParityModel()
    train = TrainConfig(global_batch=M * BC, seq_len=1, remat_policy="none")
    if fed.agg_groups > 1:
        # hierarchical layout: first client axis is the group axis; device
        # linear order stays client-major, so target slicing is unchanged
        mesh = make_mesh((fed.agg_groups, M // fed.agg_groups),
                         ("cgroup", "data"))
    else:
        mesh = make_mesh((M,), ("data",))
    ctx = ParallelContext(client_axes=fed.client_axes, num_clients=M)
    sdefs = fed_state_defs(model, fed)
    ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
    bsp = jax.tree.map(lambda d: d.spec, fed_batch_defs(model, fed, train),
                       is_leaf=pdefs.is_def)
    rnd = jax.jit(compat.shard_map(
        build_fed_round(model, fed, train, ctx, kernel_impl=kernel_impl),
        mesh=mesh, in_specs=(ssp, bsp, P()),
        out_specs=(ssp, {"loss": P(), "wire_up_bytes": P()})))
    state = init_fed_state(model, fed, jax.random.PRNGKey(0))
    out = []
    for r, t in enumerate(rounds_targets):
        state, met = rnd(state, {"t": jnp.asarray(t)}, jnp.int32(r))
        out.append(dict(
            params=np.asarray(state.params["w"]),
            errors=np.asarray(state.errors["w"]),
            loss=float(met["loss"]),
            wire_up_bytes=float(met["wire_up_bytes"])))
    return out


def _run_sim(fed, rounds_targets):
    import jax
    import jax.numpy as jnp

    from repro.core.sim import FedSim
    from repro.models import params as pdefs

    model = ParityModel()
    sim = FedSim(lambda p, b: model.loss(p, b, None), fed)
    st = sim.init(pdefs.init_params(model.defs(), jax.random.PRNGKey(0)))
    out = []
    for r, t in enumerate(rounds_targets):
        st, met = sim.round(st, {"t": jnp.asarray(_sim_batches(t))},
                            jnp.arange(M, dtype=jnp.int32),
                            jax.random.PRNGKey(100 + r))
        out.append(dict(
            params=np.asarray(st.params["w"]),
            errors=np.asarray(st.errors),
            loss=float(met["loss"])))
    return out


def _select_only_kernel_impl():
    """A KernelImpl that serves ONLY the sparse-uplink selection: the
    server update stays on the shared jnp ``server_update`` (passing a
    full KernelImpl also swaps in the Pallas FedAMS server kernel — same
    update math, but XLA may compile its x division with a different
    FMA/rsqrt contraction than the sim's differently-shaped program, a
    few ulp that tests/test_server_opt.py owns, not this uplink
    harness)."""
    from repro.core.server_opt import server_update
    from repro.kernels.ops import KernelImpl

    class SelectOnlyKernelImpl(KernelImpl):
        def fedams_update_tree(self, fed, st, params, agg):
            return server_update(fed, st, params, agg)

    return SelectOnlyKernelImpl()


def run_case(name: str, wire: bool) -> list:
    """One paired run -> per-round tree-compare summary dicts."""
    from repro.configs.base import FedConfig

    kw = dict(CASES[name])
    mesh_impl = kw.pop("mesh_sparse_impl", "auto")
    groups = kw.get("agg_groups", 1)
    common = dict(compress_ratio=RATIO, local_steps=K, num_clients=M,
                  eta=ETA, eta_l=ETA_L)
    mesh_axes = ("cgroup", "data") if groups > 1 else ("data",)
    fed_mesh = FedConfig(client_axes=mesh_axes, mesh_sparse_impl=mesh_impl,
                         **kw, **common)
    sim_kw = dict(kw)
    if sim_kw["compressor"] == "topk":     # mirror the mesh's documented remap
        sim_kw["compressor"] = "blocktopk"
    fed_sim = FedConfig(client_axes=(), wire=wire, **sim_kw, **common)

    targets = [_round_targets(r) for r in range(R)]
    # fused_ingest="kernel" needs the FULL KernelImpl on the mesh side (the
    # ingest kernel replaces the server update entirely, so the
    # select-only shim's jnp fallback never runs); the select-only shim
    # serves the mesh_sparse_impl="kernel" case, where only the selection
    # should come from Pallas.
    if kw.get("fused_ingest") == "kernel":
        from repro.kernels.ops import KernelImpl
        ki = KernelImpl()
    else:
        ki = _select_only_kernel_impl() if mesh_impl == "kernel" else None
    mesh_rounds = _run_mesh(fed_mesh, targets, ki)
    sim_rounds = _run_sim(fed_sim, targets)

    rows = []
    for r, (mr, sr) in enumerate(zip(mesh_rounds, sim_rounds)):
        scale = float(max(np.abs(sr["params"]).max(), 1e-30))
        rows.append({
            "round": r,
            "errors_bitwise": bool((mr["errors"] == sr["errors"]).all()),
            "errors_maxdiff": float(
                np.abs(mr["errors"] - sr["errors"]).max()),
            "params_bitwise": bool((mr["params"] == sr["params"]).all()),
            # params inherit exactly the aggregate's difference through the
            # elementwise server update -> report it in ulp-like units
            "params_maxdiff_rel": float(
                np.abs(mr["params"] - sr["params"]).max() / scale),
            "loss_mesh": mr["loss"], "loss_sim": sr["loss"],
            "wire_up_bytes": mr["wire_up_bytes"],
        })
    return rows


def jaxpr_payload(compressor: str) -> dict:
    """Trace (never execute) the sparse mesh round for a TWO-leaf model and
    measure what the client-axis all_gathers actually carry, plus how many
    selections run. Returns per-trace totals and the `mesh_wire_bytes`
    metric for the same config, so the test can assert metric == measured.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.configs.base import FedConfig, TrainConfig
    from repro.core.mesh import (build_fed_round, fed_batch_defs,
                                 fed_state_defs, init_fed_state,
                                 mesh_wire_bytes)
    from repro.launch.mesh import make_mesh
    from repro.models import params as pdefs
    from repro.sharding.rules import ParallelContext

    class TwoLeafModel(ParityModel):
        # second leaf (300 elements: one 384-wide padded block) rides along
        # so per-leaf counts are distinguishable from per-tree counts
        def defs(self):
            base = super().defs()
            base["b"] = pdefs.ParamDef((300,), P(), dtype="float32")
            return base

        def loss(self, p, b, ctx, remat_policy="none", chunk=0):
            diff = p["w"][None, :] - b["t"]
            return (0.5 * jnp.sum(diff * diff)
                    + 0.5 * jnp.sum(p["b"] * p["b"]), ())

    fed = FedConfig(algorithm="fedcams", compressor=compressor,
                    aggregation="sparse", compress_ratio=RATIO,
                    local_steps=K, num_clients=M, eta=ETA, eta_l=ETA_L,
                    client_axes=("data",))
    model = TwoLeafModel()
    train = TrainConfig(global_batch=M * BC, seq_len=1, remat_policy="none")
    mesh = make_mesh((M,), ("data",))
    ctx = ParallelContext(client_axes=("data",), num_clients=M)
    sdefs = fed_state_defs(model, fed)
    ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
    bsp = jax.tree.map(lambda d: d.spec, fed_batch_defs(model, fed, train),
                       is_leaf=pdefs.is_def)
    fn = compat.shard_map(build_fed_round(model, fed, train, ctx),
                          mesh=mesh, in_specs=(ssp, bsp, P()),
                          out_specs=(ssp, {"loss": P(),
                                           "wire_up_bytes": P()}))
    state = init_fed_state(model, fed, jax.random.PRNGKey(0))
    jaxpr = jax.make_jaxpr(fn)(
        state, {"t": jnp.zeros((K, M * BC, D), jnp.float32)}, jnp.int32(0))

    gathered = []      # (bytes, shape) per all_gather operand
    counts = {"top_k": 0, "argmax": 0}

    try:  # jax >= 0.6 moved the jaxpr types; 0.4.x has them on jax.core
        from jax.extend.core import ClosedJaxpr, Jaxpr
    except ImportError:  # pragma: no cover
        ClosedJaxpr, Jaxpr = jax.core.ClosedJaxpr, jax.core.Jaxpr

    def subjaxprs(params):
        for v in params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for s in vs:
                if isinstance(s, ClosedJaxpr):
                    yield s.jaxpr
                elif isinstance(s, Jaxpr):
                    yield s

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in ("all_gather", "all_gather_invariant"):
                v = eqn.invars[0].aval
                gathered.append([int(np.prod(v.shape)) * v.dtype.itemsize,
                                 list(v.shape)])
            if eqn.primitive.name in counts:
                counts[eqn.primitive.name] += 1
            for s in subjaxprs(eqn.params):
                walk(s)

    walk(jaxpr.jaxpr)

    delta_tree = {"w": np.zeros(D, np.float32), "b": np.zeros(300, np.float32)}
    return {
        "gathered": gathered,
        "gathered_bytes": int(sum(g[0] for g in gathered)),
        "top_k": counts["top_k"], "argmax": counts["argmax"],
        "metric_bytes": int(mesh_wire_bytes(fed, delta_tree, tp=1)),
        "dense_bytes": 4 * (D + 300),
        "num_leaves": 2,
    }


def jaxpr_payload_hier() -> dict:
    """Trace the HIERARCHICAL sparse round (g = 2 groups of 4 on the forced
    8-device mesh) at ratio 1/2 and split the client-axis all_gathers by
    tier: "data"-axis gathers carry the member selections (tier 1),
    "cgroup"-axis gathers carry the dense group partials the root consumes
    (tier 2). At this ratio the root payload win is provable in-process:
    the root sees g dense fp32 partials (g·d·4 bytes) instead of n
    selections (n·k·8 = n·d/2·8 = 4·n·d bytes) — the O(g·d) vs O(n·k)
    crossover the metric bills (``mesh_wire_bytes_tiers``)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.configs.base import FedConfig, TrainConfig
    from repro.core.mesh import (build_fed_round, fed_batch_defs,
                                 fed_state_defs, init_fed_state,
                                 mesh_wire_bytes_tiers)
    from repro.launch.mesh import make_mesh
    from repro.models import params as pdefs
    from repro.sharding.rules import ParallelContext

    class TwoLeafModel(ParityModel):
        def defs(self):
            base = super().defs()
            base["b"] = pdefs.ParamDef((300,), P(), dtype="float32")
            return base

        def loss(self, p, b, ctx, remat_policy="none", chunk=0):
            diff = p["w"][None, :] - b["t"]
            return (0.5 * jnp.sum(diff * diff)
                    + 0.5 * jnp.sum(p["b"] * p["b"]), ())

    g = 2
    ratio = 0.5    # dense-partial tier wins only for k large: s > 1/ratio
    fed = FedConfig(algorithm="fedcams", compressor="blocktopk",
                    aggregation="sparse", compress_ratio=ratio,
                    agg_groups=g, local_steps=K, num_clients=M,
                    eta=ETA, eta_l=ETA_L, client_axes=("cgroup", "data"))
    model = TwoLeafModel()
    train = TrainConfig(global_batch=M * BC, seq_len=1, remat_policy="none")
    mesh = make_mesh((g, M // g), ("cgroup", "data"))
    ctx = ParallelContext(client_axes=("cgroup", "data"), num_clients=M)
    sdefs = fed_state_defs(model, fed)
    ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
    bsp = jax.tree.map(lambda d: d.spec, fed_batch_defs(model, fed, train),
                       is_leaf=pdefs.is_def)
    fn = compat.shard_map(build_fed_round(model, fed, train, ctx),
                          mesh=mesh, in_specs=(ssp, bsp, P()),
                          out_specs=(ssp, {"loss": P(),
                                           "wire_up_bytes": P()}))
    state = init_fed_state(model, fed, jax.random.PRNGKey(0))
    jaxpr = jax.make_jaxpr(fn)(
        state, {"t": jnp.zeros((K, M * BC, D), jnp.float32)}, jnp.int32(0))

    tiers = {"tier1": [], "tier2": []}   # (operand bytes, shape) per gather

    try:
        from jax.extend.core import ClosedJaxpr, Jaxpr
    except ImportError:  # pragma: no cover
        ClosedJaxpr, Jaxpr = jax.core.ClosedJaxpr, jax.core.Jaxpr

    def subjaxprs(params):
        for v in params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for s in vs:
                if isinstance(s, ClosedJaxpr):
                    yield s.jaxpr
                elif isinstance(s, Jaxpr):
                    yield s

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in ("all_gather", "all_gather_invariant"):
                ax = eqn.params.get("axis_name", ())
                axes = ax if isinstance(ax, tuple) else (ax,)
                v = eqn.invars[0].aval
                rec = [int(np.prod(v.shape)) * v.dtype.itemsize,
                       list(v.shape)]
                # one gather per axis (rules._gather_axes loops), so each
                # eqn belongs to exactly one tier
                tiers["tier2" if "cgroup" in axes else "tier1"].append(rec)
            for s in subjaxprs(eqn.params):
                walk(s)

    walk(jaxpr.jaxpr)

    delta_tree = {"w": np.zeros(D, np.float32),
                  "b": np.zeros(300, np.float32)}
    metric = mesh_wire_bytes_tiers(fed, delta_tree, tp=1)
    # what the FLAT root would carry at the same ratio: every client's
    # compacted selection (vals f32 + idx i32), summed over leaves
    fed_flat = FedConfig(algorithm="fedcams", compressor="blocktopk",
                         aggregation="sparse", compress_ratio=ratio,
                         local_steps=K, num_clients=M, eta=ETA, eta_l=ETA_L,
                         client_axes=("data",))
    flat_tiers = mesh_wire_bytes_tiers(fed_flat, delta_tree, tp=1)
    return {
        "tier1_gathers": tiers["tier1"],
        "tier2_gathers": tiers["tier2"],
        "tier1_operand_bytes": int(sum(t[0] for t in tiers["tier1"])),
        "tier2_operand_bytes": int(sum(t[0] for t in tiers["tier2"])),
        "metric_tier1_bytes": int(metric["tier1"]),
        "metric_tier2_bytes": int(metric["tier2"]),
        "agg_groups": g,
        "num_clients": M,
        "root_bytes_hier": g * int(metric["tier2"]),
        "root_bytes_flat": M * int(flat_tiers["tier1"]),
        "num_leaves": 2,
    }


def main() -> None:
    out = {"cases": {}, "jaxpr": {}}
    for name in CASES:
        for wire in (False, True):
            out["cases"][f"{name}_wire{int(wire)}"] = run_case(name, wire)
    for compressor in ("blocktopk", "packedsign"):
        out["jaxpr"][compressor] = jaxpr_payload(compressor)
    out["jaxpr_hier"] = jaxpr_payload_hier()
    print(json.dumps(out))


if __name__ == "__main__":
    main()
