"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # see tests/hypothesis_fallback.py
    from hypothesis_fallback import given, settings, st

from repro.core.compressors import make_compressor, selection_to_dense
from repro.kernels import ref
from repro.kernels.fedams_update import fedams_update
from repro.kernels.ops import KernelImpl
from repro.kernels.sign_ef import sign_ef
from repro.kernels.topk_ef import topk_ef, topk_ef_sparse

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _pair(seed, n):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.normal(size=n), jnp.float32),
            jnp.asarray(r.normal(size=n) * 0.3, jnp.float32))


@pytest.mark.parametrize("n,block,k", [(256, 64, 4), (1024, 128, 16),
                                       (4096, 2048, 32), (8192, 1024, 1)])
def test_topk_ef_matches_ref(n, block, k):
    x, e = _pair(0, n)
    h1, e1 = topk_ef(x, e, k=k, block=block)
    h2, e2 = ref.topk_ef_ref(x, e, k, block)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-6)


@given(st.integers(0, 10**6), st.sampled_from([64, 128, 256]),
       st.integers(1, 16))
def test_topk_ef_property(seed, block, k):
    x, e = _pair(seed, 4 * block)
    h1, e1 = topk_ef(x, e, k=k, block=block)
    h2, e2 = ref.topk_ef_ref(x, e, k, block)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-6)
    # EF identity holds inside the kernel
    np.testing.assert_allclose(np.asarray(h1 + e1), np.asarray(x + e),
                               atol=1e-5)


@pytest.mark.parametrize("n,block", [(256, 64), (2048, 2048), (8192, 1024)])
def test_sign_ef_matches_ref(n, block):
    x, e = _pair(1, n)
    h1, e1 = sign_ef(x, e, block=block)
    h2, e2 = ref.sign_ef_ref(x, e)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("option", [1, 2])
@pytest.mark.parametrize("n,block", [(512, 128), (4096, 4096)])
def test_fedams_update_matches_ref(option, n, block):
    r = np.random.default_rng(2)
    arrs = [jnp.asarray(np.abs(r.normal(size=n)) if i in (2, 3)
                        else r.normal(size=n), jnp.float32)
            for i in range(5)]
    kw = dict(eta=0.7, beta1=0.9, beta2=0.99, eps=1e-3, option=option)
    got = fedams_update(*arrs, block=block, **kw)
    want = ref.fedams_update_ref(*arrs, **kw)
    for g, w, nm in zip(got, want, "x m v vhat".split()):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5,
                                   atol=1e-6, err_msg=nm)


@given(st.integers(0, 10**6), st.sampled_from([128, 256]),
       st.integers(1, 127))
def test_fedams_update_ragged_tail_property(seed, block, tail):
    """d % block != 0 goes through the pad-and-slice path: outputs keep the
    unpadded length and match the jitted jnp reference — m/v/v̂ bitwise
    (the zero pad lanes can't leak into real lanes); x gets a tiny
    tolerance: the multi-block interpret grid may compile the x division
    with a contracted FMA/rsqrt form (a few ulp of the increment), and
    tests/test_server_opt.py owns the single-block bitwise gate on x."""
    n = 2 * block + tail
    r = np.random.default_rng(seed)
    arrs = [jnp.asarray(np.abs(r.normal(size=n)) if i in (2, 3)
                        else r.normal(size=n), jnp.float32)
            for i in range(5)]
    for option in (1, 2):
        kw = dict(eta=0.7, beta1=0.9, beta2=0.99, eps=1e-3, option=option)
        got = fedams_update(*arrs, block=block, **kw)
        want = jax.jit(lambda *a: ref.fedams_update_ref(*a, **kw))(*arrs)
        for g, w, nm in zip(got, want, "x m v vhat".split()):
            assert g.shape == (n,), (nm, g.shape)
            if nm == "x":
                np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                           rtol=1e-6, atol=1e-6)
            else:
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                              err_msg=f"{nm} option={option}")


@given(st.integers(0, 10**6))
def test_fedams_kernel_vhat_monotone(seed):
    r = np.random.default_rng(seed)
    n = 256
    x = jnp.zeros(n)
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    vh = jnp.zeros(n)
    for _ in range(4):
        d = jnp.asarray(r.normal(size=n) * 0.1, jnp.float32)
        x, m, v, vh2 = fedams_update(x, m, v, vh, d, eta=1.0, beta1=0.9,
                                     beta2=0.99, eps=1e-3, option=1, block=128)
        assert (np.asarray(vh2) >= np.asarray(vh) - 1e-12).all()
        assert (np.asarray(vh2) >= 1e-3 - 1e-12).all()
        vh = vh2


def test_topk_ef_keeps_exactly_k_on_ties():
    """Ties regression: a tile of equal |values| must keep EXACTLY k
    entries (lowest indices first, lax.top_k order) — the old threshold
    formulation (|x| >= kth) kept every tied entry, breaking the wire
    format's fixed (vals, idx) sizes and the bits_per_message accounting."""
    n, block, k = 4096, 2048, 7
    x = jnp.ones(n, jnp.float32)
    e = jnp.zeros(n, jnp.float32)
    hat, ne = topk_ef(x, e, k=k, block=block)
    hat = np.asarray(hat).reshape(-1, block)
    for b in range(hat.shape[0]):
        kept = np.flatnonzero(hat[b])
        assert kept.tolist() == list(range(k)), (b, kept)
    # mixed signs and partial ties
    x2 = jnp.asarray(np.tile([2.0, -2.0, 1.0, -1.0], block // 4), jnp.float32)
    h2, _ = topk_ef(x2, jnp.zeros(block, jnp.float32), k=3, block=block)
    assert int((np.asarray(h2) != 0).sum()) == 3
    assert np.flatnonzero(np.asarray(h2)).tolist() == [0, 1, 4]
    # matches the dense blocktopk compressor bit for bit on ties
    comp = make_compressor("blocktopk", k / block, block)
    assert np.array_equal(np.asarray(comp.compress(x)), np.asarray(hat.reshape(-1)))


@pytest.mark.parametrize("n,block,k", [(256, 64, 4), (4096, 2048, 32),
                                       (8192, 1024, 1)])
def test_topk_ef_sparse_matches_dense_kernel(n, block, k):
    """The compacted (vals, idx) output scatters back to exactly the dense
    kernel's hat, the fused new_err is identical, and idx are global."""
    x, e = _pair(7, n)
    hat, ne = topk_ef(x, e, k=k, block=block)
    vals, idx, ne2 = topk_ef_sparse(x, e, k=k, block=block)
    assert vals.shape == idx.shape == (n // block, k)
    rec = jnp.zeros(n, jnp.float32).at[idx.reshape(-1)].set(vals.reshape(-1))
    assert np.array_equal(np.asarray(rec), np.asarray(hat))
    assert np.array_equal(np.asarray(ne2), np.asarray(ne))
    # per-block indices live in that block's global range
    lo = np.arange(n // block)[:, None] * block
    assert ((np.asarray(idx) >= lo) & (np.asarray(idx) < lo + block)).all()


def test_kernel_impl_topk_select_leaf_matches_compressor():
    """KernelImpl's fused selection agrees with the jnp compressor.select
    (vals/idx bit-identical incl. padded tails) and its new_err with the
    dense EF identity."""
    ki = KernelImpl(block=64)
    r = np.random.default_rng(9)
    for n in (64, 100, 300):
        x = jnp.asarray(r.normal(size=n), jnp.float32)
        e = jnp.asarray(r.normal(size=n) * 0.2, jnp.float32)
        sel, ne = ki.topk_select_leaf(1 / 4, x, e)
        comp = make_compressor("blocktopk", 1 / 4, 64)
        ref_sel = comp.select(x + e)
        assert np.array_equal(np.asarray(sel.idx), np.asarray(ref_sel.idx)), n
        assert np.array_equal(np.asarray(sel.vals), np.asarray(ref_sel.vals))
        hat = selection_to_dense(sel, n)
        np.testing.assert_array_equal(np.asarray(ne),
                                      np.asarray((x + e) - hat))


def test_kernel_impl_interpret_resolves_by_backend():
    """interpret=None resolves like kernels.bitpack: interpreter off-TPU,
    compiled on TPU; an explicit bool is honored unchanged."""
    ki = KernelImpl()
    assert ki.interpret is None
    expected = jax.default_backend() != "tpu"
    assert ki._interp is expected
    assert KernelImpl(interpret=True)._interp is True
    assert KernelImpl(interpret=False)._interp is False


def test_kernel_impl_padding_paths():
    """Non-multiple leaf sizes go through the zero-padding path exactly."""
    ki = KernelImpl(block=128)
    r = np.random.default_rng(3)
    for n in (100, 128, 300):
        x = jnp.asarray(r.normal(size=n), jnp.float32)
        e = jnp.asarray(r.normal(size=n) * 0.2, jnp.float32)
        h, ne = ki.ef_compress_leaf("sign", 1.0, x, e)
        h2, e2 = ref.sign_ef_ref(x, e)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h2), rtol=1e-4,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(ne), np.asarray(e2), rtol=1e-4,
                                   atol=1e-5)


def test_kernel_impl_tree_mask():
    ki = KernelImpl(block=64)
    comp = make_compressor("topk", 1 / 4)
    tree = {"a": jnp.ones((8, 8)), "b": jnp.arange(10.0)}
    err = jax.tree.map(jnp.zeros_like, tree)
    hat, ne = ki.ef_compress_tree(comp, tree, err, jnp.float32(0.0))
    assert all(float(jnp.abs(l).max()) == 0 for l in jax.tree.leaves(hat))
