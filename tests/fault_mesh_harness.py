"""Subprocess side of the fault-tolerant mesh tests (tests/test_faults.py).

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` via
``tests/conftest.py::run_forced_devices``; never collected by pytest.
Reuses the deterministic single-leaf fixture from
``mesh_parity_harness`` (quantized targets, reassociation-free local
phase) so every comparison below holds at the bit level:

* **all-ones parity** — a ``FaultConfig()`` with nothing enabled must be
  loss/params/errors-bitwise against the fault-free build of the same
  round (the masked aggregation path costs nothing when nobody fails);
* **stale-then-repay** — a ``crash_trace`` outage leaves the dead
  client's EF row bitwise untouched, and on rejoin the uplink total is
  ``stale + delta``: verified against a zero-residual twin run (same
  jitted program, so the round-3 delta is bit-identical between the two
  runs) on the complement of both selection supports, where the EF rows
  expose the totals directly;
* **corruption NACK** — NaN-poisoned payloads are rejected before
  ingest: server state stays finite, and exactly the rejected clients'
  EF rows roll back to their pre-round values.
"""
from __future__ import annotations

import json

import numpy as np

from mesh_parity_harness import BC, K, M, ParityModel, _round_targets

ETA, ETA_L = 0.25, 0.0625
RATIO = 1.0 / 8.0


def _fed(fault=None, **kw):
    from repro.configs.base import FedConfig
    return FedConfig(algorithm="fedcams", compressor="blocktopk",
                     aggregation="sparse", compress_ratio=RATIO,
                     local_steps=K, num_clients=M, eta=ETA, eta_l=ETA_L,
                     client_axes=("data",), track_gamma=False,
                     fault=fault, **kw)


def _run(fed, rounds, edit_errors_row0_at=None):
    """Run ``rounds`` mesh fed_rounds; returns per-round numpy snapshots.
    ``edit_errors_row0_at=r`` zeroes client 0's EF row right before round
    ``r`` (the zero-residual twin of the repayment check)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.configs.base import TrainConfig
    from repro.core.mesh import (build_fed_round, fed_batch_defs,
                                 fed_state_defs, init_fed_state,
                                 mesh_metric_specs)
    from repro.launch.mesh import make_mesh
    from repro.models import params as pdefs
    from repro.sharding.rules import ParallelContext

    model = ParityModel()
    train = TrainConfig(global_batch=M * BC, seq_len=1, remat_policy="none")
    mesh = make_mesh((M,), ("data",))
    ctx = ParallelContext(client_axes=fed.client_axes, num_clients=M)
    sdefs = fed_state_defs(model, fed)
    ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
    bsp = jax.tree.map(lambda d: d.spec, fed_batch_defs(model, fed, train),
                       is_leaf=pdefs.is_def)
    rnd = jax.jit(compat.shard_map(
        build_fed_round(model, fed, train, ctx), mesh=mesh,
        in_specs=(ssp, bsp, P()), out_specs=(ssp, mesh_metric_specs(fed))))
    state = init_fed_state(model, fed, jax.random.PRNGKey(0))
    out = []
    for r in range(rounds):
        if edit_errors_row0_at == r:
            err = np.array(state.errors["w"])
            err[0] = 0.0
            state = state._replace(errors={"w": jnp.asarray(err)})
        state, met = rnd(state, {"t": jnp.asarray(_round_targets(r))},
                         jnp.int32(r))
        out.append(dict(
            params=np.asarray(state.params["w"]),
            errors=np.asarray(state.errors["w"]),
            met={k: float(v) for k, v in met.items()}))
    return out


def run_all() -> dict:
    from repro.comm.faults import FaultConfig

    results = {}

    # 1. all-ones fault plan == fault-free build, bitwise
    base = _run(_fed(), 3)
    par = _run(_fed(fault=FaultConfig()), 3)
    results["parity"] = {
        "loss_bitwise": all(b["met"]["loss"] == p["met"]["loss"]
                            for b, p in zip(base, par)),
        "params_bitwise": all((b["params"] == p["params"]).all()
                              for b, p in zip(base, par)),
        "errors_bitwise": all((b["errors"] == p["errors"]).all()
                              for b, p in zip(base, par)),
        "survivors": [p["met"]["survivors"] for p in par],
    }

    # 2. scheduled outage: stale residual, then bitwise repayment on rejoin
    fault = FaultConfig(crash_trace=((0, 1, 3),))
    runa = _run(_fed(fault=fault), 4)
    runz = _run(_fed(fault=fault), 4, edit_errors_row0_at=3)
    stale = runa[0]["errors"][0]
    ea, ez = runa[3]["errors"][0], runz[3]["errors"][0]
    # EF rows are the uplink totals with exactly the selected coordinates
    # zeroed; off both supports row A must be (stale + delta) and row Z
    # must be delta — the same IEEE add the round computed in-trace
    off = (ea != 0.0) & (ez != 0.0)
    results["rejoin"] = {
        "stale_r1_bitwise": bool((runa[1]["errors"][0] == stale).all()),
        "stale_r2_bitwise": bool((runa[2]["errors"][0] == stale).all()),
        "others_moved_r1": bool(
            (runa[1]["errors"][1:] != runa[0]["errors"][1:]).any()),
        "off_support_count": int(off.sum()),
        "repay_bitwise": bool((ea[off] == (stale[off] + ez[off])).all()),
        "selection_shifted_by_residual": bool(
            ((ea == 0.0) != (ez == 0.0)).any()),
        "survivors": [r["met"]["survivors"] for r in runa],
    }

    # 3. NaN corruption: reject-before-ingest + EF NACK rollback
    runc = _run(_fed(fault=FaultConfig(corrupt_prob=0.6, corrupt_mode="nan",
                                       seed=3)), 3)
    rejected = [r["met"]["rejected"] for r in runc]
    nack_matches = []
    prev_err = np.zeros_like(runc[0]["errors"])
    for r, row in enumerate(runc):
        stale_rows = int((row["errors"] == prev_err).all(axis=1).sum())
        nack_matches.append(stale_rows == int(rejected[r]))
        prev_err = row["errors"]
    results["corruption"] = {
        "rejected": rejected,
        "any_rejected": any(x > 0 for x in rejected),
        "state_finite": bool(np.isfinite(runc[-1]["params"]).all()
                             and np.isfinite(runc[-1]["errors"]).all()),
        "loss_finite": all(np.isfinite(r["met"]["loss"]) for r in runc),
        "nack_rows_match_rejected": all(nack_matches),
    }
    return results


if __name__ == "__main__":
    print(json.dumps(run_all()))
