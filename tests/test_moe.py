"""MoE dispatch correctness: capacity-gather vs dense reference, padding,
aux loss, and determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import params as pdefs
from repro.models.moe import moe_defs, moe_ffn, moe_ffn_dense_ref, router_probs
from repro.sharding.rules import ParallelContext

CTX = ParallelContext()


def _setup(E=4, k=2, d=32, dff=64, shared=1, cf=8.0, seed=0):
    mo = MoEConfig(num_experts=E, top_k=k, num_shared_experts=shared,
                   d_ff_expert=dff, d_ff_shared=dff, capacity_factor=cf)
    params = pdefs.init_params(moe_defs(d, mo, tp=1), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, d))
    return mo, params, x


def test_gather_dispatch_matches_dense_at_high_capacity():
    """With capacity >= tokens, no drops: gather dispatch == dense ref."""
    mo, params, x = _setup(cf=16.0)
    out, aux = moe_ffn(params, x, mo, CTX, dtype="float32")
    ref = moe_ffn_dense_ref(params, x, mo, CTX, dtype="float32")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_capacity_drops_reduce_output_not_nan():
    mo, params, x = _setup(cf=0.25)
    out, aux = moe_ffn(params, x, mo, CTX, dtype="float32")
    assert bool(jnp.isfinite(out).all())


def test_padded_experts_never_selected():
    """qwen2-moe style: experts padded to tp multiple get -inf logits."""
    mo = MoEConfig(num_experts=3, top_k=2, d_ff_expert=16)
    params = pdefs.init_params(moe_defs(16, mo, tp=4),
                               jax.random.PRNGKey(0))
    assert params["w_up"].shape[0] == 4  # padded
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 16))
    probs = router_probs(params, x, mo, "float32")
    assert float(probs[:, 3:].max()) == 0.0


def test_aux_loss_prefers_balance():
    """Uniform router => aux ≈ weight; collapsed router => larger."""
    mo, params, x = _setup(E=4, k=1, shared=0)
    _, aux_u = moe_ffn(params, x, mo, CTX, dtype="float32")
    # collapse: bias router to expert 0
    p2 = dict(params)
    p2["router"] = params["router"] * 0.0 + jnp.eye(32, 4) * 100.0
    _, aux_c = moe_ffn(p2, x, mo, CTX, dtype="float32")
    assert float(aux_c) > float(aux_u)


def test_gates_renormalized():
    mo, params, x = _setup()
    xf = x.reshape(-1, x.shape[-1])
    probs = router_probs(params, xf, mo, "float32")
    gates, _ = jax.lax.top_k(probs, mo.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
