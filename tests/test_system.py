"""End-to-end system behaviour: the full framework trains a small LM with
FedCAMS and serves it — the paper's technique wired through the whole stack
(model substrate + FL core + Pallas kernels path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig, TrainConfig
from repro.core.rounds import build_fed_round, init_fed_state
from repro.data.synthetic import FederatedLMData
from repro.kernels.ops import KernelImpl
from repro.models.model import Model, greedy_sample
from repro.sharding.rules import ParallelContext

CFG = ModelConfig(name="sys", family="dense", num_layers=2, d_model=48,
                  num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=64,
                  dtype="float32")
CTX = ParallelContext(client_axes=(), num_clients=1)


def _train(algo="fedcams", rounds=8, use_kernels=False, compressor="topk"):
    fed = FedConfig(algorithm=algo, num_clients=1, local_steps=2,
                    compressor=compressor, compress_ratio=1 / 8,
                    client_axes=(), eta=0.3, eta_l=0.1,
                    use_kernels=use_kernels)
    train = TrainConfig(global_batch=4, seq_len=24, remat_policy="none")
    model = Model(CFG, tp=1)
    ki = KernelImpl(block=2048) if use_kernels else None
    rnd = jax.jit(build_fed_round(model, fed, train, CTX, kernel_impl=ki))
    state = init_fed_state(model, fed, jax.random.PRNGKey(0))
    data = FederatedLMData(num_clients=1, vocab_size=CFG.vocab_size, seed=0)
    losses = []
    for r in range(rounds):
        raw = data.mesh_batch(r, fed.local_steps, 4, 24)
        state, met = rnd(state, {k: jnp.asarray(v) for k, v in raw.items()},
                         jnp.int32(r))
        losses.append(float(met["loss"]))
    return losses, state, model


def test_fedcams_trains_lm_end_to_end():
    losses, state, model = _train()
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_kernel_path_matches_jnp_path():
    """The Pallas kernel implementation is a drop-in for the jnp math.

    Both paths use blockwise top-k with block=2048, so the selections are
    identical (ties aside) and the losses must track to float tolerance."""
    l_jnp, _, _ = _train(use_kernels=False, rounds=4)
    l_krn, _, _ = _train(use_kernels=True, rounds=4)
    np.testing.assert_allclose(l_jnp, l_krn, rtol=2e-3, atol=1e-4)


def test_kernel_path_sign_matches():
    l_jnp, _, _ = _train(use_kernels=False, rounds=3, compressor="sign")
    l_krn, _, _ = _train(use_kernels=True, rounds=3, compressor="sign")
    np.testing.assert_allclose(l_jnp, l_krn, rtol=2e-3, atol=1e-4)


def test_trained_model_serves():
    _, state, model = _train(rounds=5)
    params = state.params
    prompts = jnp.asarray(np.random.default_rng(0).integers(
        0, CFG.vocab_size, size=(2, 8)).astype(np.int32))
    logits, caches = model.prefill(params, prompts, CTX, max_len=16)
    tok = greedy_sample(logits, CTX)
    assert tok.shape == (2,)
    lg, caches = model.decode_step(params, tok[:, None].astype(jnp.int32),
                                   caches, jnp.int32(8), CTX, max_len=16)
    assert bool(jnp.isfinite(lg).all())
