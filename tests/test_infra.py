"""Substrate tests: layers, checkpoint, data pipeline, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.data.synthetic import (FederatedClassification, FederatedLMData,
                                  dirichlet_label_partition)
from repro.launch.hlo_analysis import analyze
from repro.models import params as pdefs
from repro.models.layers import (embed_defs, embed_lookup, rms_norm, rope,
                                 sharded_xent, softcap)
from repro.sharding.rules import ParallelContext, attn_dims, pad_to

CTX = ParallelContext()


# -- layers ------------------------------------------------------------------


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 7.0
    y = rms_norm(jnp.ones(32), x)
    rms = jnp.sqrt(jnp.mean(y * y, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # shifting positions rotates q and k identically => q·k invariant
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 1, 16))
    def dots(off):
        qr = rope(q, jnp.asarray([[0 + off, 1 + off]]))
        kr = rope(k, jnp.asarray([[0 + off, 1 + off]]))
        return jnp.einsum("bshd,bshd->bs", qr, kr)
    np.testing.assert_allclose(np.asarray(dots(0)), np.asarray(dots(5)),
                               atol=1e-4)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    assert softcap(x, None) is x


def test_sharded_xent_matches_dense_tp1():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 33))
    labels = jax.random.randint(jax.random.PRNGKey(1), (4, 7), 0, 30)
    got = sharded_xent(logits, labels, CTX, true_vocab=30)
    lg = jnp.where(jnp.arange(33) < 30, logits, -jnp.inf)
    want = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(lg),
                                         labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_embed_lookup_tp1():
    p = pdefs.init_params(embed_defs(16, 8), jax.random.PRNGKey(0))
    ids = jnp.asarray([[0, 5, 15]])
    out = embed_lookup(p, ids, CTX, "float32")
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(p["table"][jnp.asarray([0, 5, 15])]),
                               rtol=1e-6)


def test_attn_dims_padding():
    d = attn_dims(14, 2, 64, 16)     # internvl2 on tp16
    assert d.q_heads == 16 and d.q_local == 1 and not d.kv_sharded
    d = attn_dims(40, 40, 128, 16)   # qwen1.5-32b
    assert d.q_heads == 48 and d.kv_heads == 48
    d = attn_dims(32, 16, 128, 16)   # gemma2-27b
    assert d.q_heads == 32 and d.kv_sharded and d.kv_local == 1
    d = attn_dims(56, 8, 128, 16)    # deepseek-coder
    assert d.q_heads == 64 and not d.kv_sharded and d.group == 8
    assert pad_to(151655, 16) % 16 == 0


# -- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    save_pytree(str(tmp_path / "ck"), tree, {"round": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = load_pytree(str(tmp_path / "ck"), like)
    assert meta["round"] == 7
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]))


def test_checkpoint_structure_mismatch(tmp_path):
    save_pytree(str(tmp_path / "ck"), {"a": jnp.ones(3)})
    with pytest.raises(ValueError, match="mismatch"):
        load_pytree(str(tmp_path / "ck"), {"zz": jnp.ones(3)})


# -- data ----------------------------------------------------------------------


def test_dirichlet_partition_rows_sum_to_one():
    r = np.random.default_rng(0)
    p = dirichlet_label_partition(r, 10, 20, 0.3)
    np.testing.assert_allclose(p.sum(1), 1.0, rtol=1e-6)
    iid = dirichlet_label_partition(r, 10, 5, np.inf)
    np.testing.assert_allclose(iid, 0.1)


def test_classification_noniid_skew():
    d_noniid = FederatedClassification(num_clients=8, alpha=0.05, seed=1)
    d_iid = FederatedClassification(num_clients=8, alpha=np.inf, seed=1)
    ent = lambda p: -(p * np.log(p + 1e-12)).sum(1).mean()
    assert ent(d_noniid.label_dist) < ent(d_iid.label_dist) - 0.5


def test_classification_batches_deterministic():
    d = FederatedClassification(num_clients=4, seed=3)
    b1 = d.client_batch(1, 5, 8)
    b2 = d.client_batch(1, 5, 8)
    np.testing.assert_allclose(b1["x"], b2["x"])
    assert (b1["y"] == b2["y"]).all()
    b3 = d.client_batch(1, 6, 8)
    assert not np.allclose(b1["x"], b3["x"])


def test_lm_data_shapes_and_planted_structure():
    d = FederatedLMData(num_clients=4, vocab_size=64, seed=0)
    b = d.client_batch(0, 0, 16, 32)
    assert b["tokens"].shape == (16, 32)
    follow = (b["tokens"] * d.mult + d.add) % 64
    frac = (b["labels"] == follow).mean()
    assert 0.3 < frac < 0.8  # coin prob 0.5 + accidental matches


def test_lm_mesh_batch_layout():
    d = FederatedLMData(num_clients=4, vocab_size=64, seed=0)
    mb = d.mesh_batch(0, 3, 8, 16)
    assert mb["tokens"].shape == (3, 8, 16)


# -- HLO analyzer ---------------------------------------------------------------


def test_hlo_analyzer_scan_trip_count():
    from jax import lax

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return lax.scan(body, x, None, length=7)[0]

    x = jnp.zeros((64, 64))
    c = jax.jit(f).lower(x, x).compile()
    hc = analyze(c.as_text())
    assert hc.flops == 2 * 64 ** 3 * 7


def test_hlo_analyzer_nested_scans():
    from jax import lax

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            return lax.scan(inner, c, None, length=3)[0], None
        return lax.scan(outer, x, None, length=5)[0]

    x = jnp.zeros((32, 32))
    c = jax.jit(f).lower(x, x).compile()
    hc = analyze(c.as_text())
    assert hc.flops == 2 * 32 ** 3 * 15
