"""Event-driven async buffered rounds (DESIGN.md §11): config
validation, staleness-weight math, the buffer==cohort bitwise parity
anchor against the sync round (scan AND loop), flush determinism, EF
residual repayment across in-flight dispatches, fault composition, and
delivered-vs-attempted billing under the event clock."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.comm import FaultConfig, NetworkConfig, SimulatedNetwork
from repro.comm.async_engine import (STALENESS_WEIGHTS, AsyncRoundEngine,
                                     resolve_staleness_weight)
from repro.configs.base import FedConfig
from repro.core.rounds import FedSim
from repro.core.sampling import sample_clients
from repro.core.stages import (server_aggregate_sparse,
                               server_aggregate_sparse_weighted)
from repro.data.synthetic import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

pytestmark = pytest.mark.async_rounds

MC = MLPConfig(in_dim=16, hidden=32, depth=2, num_classes=4)
DATA = FederatedClassification(num_clients=8, num_classes=4, feature_dim=16,
                               alpha=0.5, seed=0)
M, N, K, BS = 8, 4, 2, 8


def _net(straggler=0.0, slowdown=8.0, seed=3):
    return SimulatedNetwork(
        NetworkConfig(straggler_prob=straggler, straggler_slowdown=slowdown,
                      seed=seed), M)


def _fed(async_buffer=0, **kw):
    base = dict(algorithm="fedcams", eta=0.05, eta_l=0.1, local_steps=K,
                num_clients=M, participating=N, compressor="blocktopk",
                compress_ratio=1 / 8, track_gamma=False, wire=True,
                async_buffer=async_buffer)
    base.update(kw)
    return FedConfig(**base)


def _stage(rounds, seed=0):
    """The exact staging FederatedTrainer/run_rounds consume."""
    rng = jax.random.PRNGKey(seed + 1)
    idxs, keys, batches = [], [], []
    for r in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        idx = np.asarray(sample_clients(k1, M, N))
        batches.append(DATA.round_batches(idx, r, K, BS))
        idxs.append(idx)
        keys.append(k2)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    return stacked, jnp.asarray(np.stack(idxs)), jnp.stack(keys)


def _run(fed, rounds=6, network=None, seed=0, loop=False):
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), fed,
                 network=network or _net())
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(seed)))
    batches, idxs, keys = _stage(rounds, seed)
    if loop:  # sync per-round loop path (async always takes run_rounds)
        mets = []
        for r in range(rounds):
            st, met = sim.round(st, jax.tree.map(lambda x: x[r], batches),
                                idxs[r], keys[r])
            mets.append(met)
        return sim, st, mets
    st, mets = sim.run_rounds(st, batches, idxs, keys)
    return sim, st, mets


def _flat(st):
    return np.concatenate(
        [np.asarray(ravel_pytree(st.params)[0])]
        + [np.asarray(leaf).ravel() for leaf in jax.tree.leaves(st.opt)]
        + [np.asarray(st.errors).ravel()])


# -- config validation -------------------------------------------------------


def test_async_config_validation():
    _fed(async_buffer=4)                                    # fine
    _fed(async_buffer=2, fault=FaultConfig(crash_prob=0.1))  # composes
    with pytest.raises(ValueError, match="exceeds"):
        _fed(async_buffer=5)
    with pytest.raises(ValueError, match=">= 0"):
        _fed(async_buffer=-1)
    with pytest.raises(ValueError, match="wire"):
        _fed(async_buffer=4, wire=False)
    with pytest.raises(ValueError, match="sparse"):
        _fed(async_buffer=4, compressor="sign")
    with pytest.raises(ValueError, match="sparse"):
        _fed(async_buffer=4, sparse_uplink=False)
    with pytest.raises(ValueError, match="track_gamma"):
        _fed(async_buffer=4, track_gamma=True)
    with pytest.raises(ValueError, match="two_way"):
        _fed(async_buffer=4, two_way=True)
    with pytest.raises(ValueError, match="agg_groups"):
        _fed(async_buffer=4, agg_groups=2)
    with pytest.raises(ValueError, match="competing straggler"):
        _fed(async_buffer=4, deadline_s=1.0)
    with pytest.raises(ValueError, match="competing straggler"):
        _fed(async_buffer=4, fault=FaultConfig(deadline_s=1.0))
    with pytest.raises(ValueError, match="staleness_weight"):
        _fed(staleness_weight="cubic")


def test_async_round_method_refuses():
    sim, *_ = _run(_fed(), rounds=2)  # warm nothing; build async sim fresh
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), _fed(async_buffer=4),
                 network=_net())
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    batches, idxs, keys = _stage(1)
    with pytest.raises(ValueError, match="run_rounds"):
        sim.round(st, jax.tree.map(lambda x: x[0], batches), idxs[0],
                  keys[0])


# -- staleness weights -------------------------------------------------------


def test_staleness_weight_rules():
    tau = np.array([0.0, 1.0, 3.0, 8.0])
    assert np.array_equal(STALENESS_WEIGHTS["uniform"](tau), np.ones(4))
    assert np.allclose(STALENESS_WEIGHTS["inv_sqrt"](tau),
                       1.0 / np.sqrt(1.0 + tau))
    assert np.allclose(STALENESS_WEIGHTS["inv_linear"](tau), 1.0 / (1 + tau))
    assert np.allclose(STALENESS_WEIGHTS["exp"](tau), np.exp(-tau / 2))
    # w(0) must be exactly 1.0 for every rule, including after the f32
    # cast — the buffer==cohort parity anchor leans on this
    for name, fn in STALENESS_WEIGHTS.items():
        assert np.float32(fn(np.zeros(1))[0]) == np.float32(1.0), name
    with pytest.raises(ValueError, match="cubic"):
        resolve_staleness_weight("cubic")
    assert resolve_staleness_weight("inv_sqrt") is \
        STALENESS_WEIGHTS["inv_sqrt"]


def test_weighted_aggregate_stage():
    r = np.random.default_rng(0)
    vals = jnp.asarray(r.normal(size=(4, 8)), jnp.float32)
    idx = jnp.asarray(r.integers(0, 32, size=(4, 8)), jnp.int32)
    # unit weights: bitwise the plain sparse mean (the parity anchor)
    unit = server_aggregate_sparse_weighted(vals, idx, 32, jnp.ones(4))
    plain = server_aggregate_sparse(vals, idx, 32, 4)
    assert np.array_equal(np.asarray(unit), np.asarray(plain))
    # weighted: matches the dense manual computation
    w = jnp.asarray([1.0, 0.5, 0.0, 0.25])
    got = np.asarray(server_aggregate_sparse_weighted(vals, idx, 32, w))
    dense = np.zeros((4, 32), np.float32)
    for c in range(4):
        for j in range(8):
            dense[c, int(idx[c, j])] += float(vals[c, j])
    want = (np.asarray(w)[:, None] * dense).sum(0) / float(np.sum(w))
    assert np.allclose(got, want, rtol=1e-6)
    # zero-weight NaN payload is where()-excluded, never multiplied
    poisoned = vals.at[2].set(jnp.nan)
    out = server_aggregate_sparse_weighted(poisoned, idx, 32, w)
    assert np.isfinite(np.asarray(out)).all()


# -- the parity anchor -------------------------------------------------------


@pytest.mark.parametrize("staleness", ["uniform", "inv_sqrt"])
@pytest.mark.parametrize("fused", ["auto", "off"])
def test_buffer_equals_cohort_is_bitwise_sync(staleness, fused):
    """With async_buffer == cohort size and unit staleness weights every
    flush must be bit-identical to the sync round — fused and unfused
    server paths, for every weight rule (w(0) = 1 exactly)."""
    sync_sim, st_sync, h_sync = _run(_fed(fused_ingest=fused), rounds=6)
    async_sim, st_async, h_async = _run(
        _fed(async_buffer=N, staleness_weight=staleness,
             fused_ingest=fused), rounds=6)
    assert isinstance(async_sim._async, AsyncRoundEngine)
    if fused == "auto":  # both sides actually exercised the fused ingest
        assert sync_sim._fused != "off" and async_sim._fused != "off"
    assert np.array_equal(_flat(st_sync), _flat(st_async))
    assert np.array_equal(np.asarray(st_sync.x_client),
                          np.asarray(st_async.x_client))
    assert st_sync.bits == st_async.bits
    assert st_sync.round == st_async.round
    assert len(h_async) == len(h_sync) == 6          # one flush per cohort
    for ms, ma in zip(h_sync, h_async):
        # loss: the async flush reduces host-roundtripped f32 copies, 1
        # ulp from the in-jit cohort mean; state above is exact
        assert float(ma["loss"]) == pytest.approx(float(ms["loss"]),
                                                  rel=1e-6)
        assert ma["wire_up_bytes"] == ms["wire_up_bytes"]
        assert ma["wire_down_bytes"] == ms["wire_down_bytes"]
        assert ma["bits"] == ms["bits"]
        # event-clock delta vs straggler max: (t0 + ct) - t0 in float
        assert ma["round_time_s"] == pytest.approx(ms["round_time_s"],
                                                   rel=1e-12)
        assert ma["staleness_max"] == 0.0
        assert ma["buffer_fill"] == float(N)


def test_parity_anchor_vs_loop_path():
    _, st_loop, h_loop = _run(_fed(), rounds=4, loop=True)
    _, st_async, h_async = _run(_fed(async_buffer=N), rounds=4)
    assert np.array_equal(_flat(st_loop), _flat(st_async))
    for ms, ma in zip(h_loop, h_async):
        assert float(ma["loss"]) == pytest.approx(float(ms["loss"]),
                                                  rel=1e-6)


# -- buffered behavior (B < n) ----------------------------------------------


def test_buffered_flushes_deterministic_and_accounted():
    net = lambda: _net(straggler=0.3)
    _, st1, h1 = _run(_fed(async_buffer=2), rounds=6, network=net())
    _, st2, h2 = _run(_fed(async_buffer=2), rounds=6, network=net())
    assert np.array_equal(_flat(st1), _flat(st2))
    assert len(h1) == len(h2)
    for a, b in zip(h1, h2):
        assert a == b                    # flush-for-flush identical dicts
    # 6 cohorts x 4 deliveries / buffer 2 = 12 flushes
    assert len(h1) == 12
    assert st1.round == 12
    assert all(m["buffer_fill"] == 2.0 for m in h1)
    # billing: sim clock is the sum of flush deltas; bytes split
    # delivered vs attempted and agree with the flush fills
    assert h1[-1]["sim_time_s"] == pytest.approx(
        sum(m["round_time_s"] for m in h1), abs=1e-12)
    up_pc = h1[0]["wire_up_bytes"] // 2
    assert all(m["wire_up_bytes"] == 2 * up_pc for m in h1)
    attempted = sum(m["wire_up_bytes_attempted"] for m in h1)
    assert attempted == 6 * N * up_pc
    # monotone nondecreasing event clock
    assert all(m["round_time_s"] >= 0.0 for m in h1)


def test_stragglers_create_staleness_and_downweighting():
    """With B < n under stragglers, cohorts overlap: some payloads ingest
    τ >= 1 flushes after dispatch, and inv_sqrt down-weights them
    (weight_sum < buffer_fill on exactly the stale flushes)."""
    _, st, h = _run(_fed(async_buffer=2, staleness_weight="inv_sqrt"),
                    rounds=8, network=_net(straggler=0.4))
    assert max(m["staleness_max"] for m in h) >= 1.0
    assert np.isfinite(_flat(st)).all()
    for m in h:
        if m["staleness_max"] > 0:
            assert m["weight_sum"] < m["buffer_fill"]
        else:
            assert m["weight_sum"] == pytest.approx(m["buffer_fill"])
    # uniform weighting keeps weight_sum == fill even when stale
    _, _, hu = _run(_fed(async_buffer=2, staleness_weight="uniform"),
                    rounds=8, network=_net(straggler=0.4))
    assert all(m["weight_sum"] == m["buffer_fill"] for m in hu)
    assert max(m["staleness_max"] for m in hu) >= 1.0


def test_custom_weight_fn_override():
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), _fed(async_buffer=2),
                 network=_net(straggler=0.4))
    calls = []

    def wf(tau):
        calls.append(tau.copy())
        return np.where(tau > 0, 0.0, 1.0)   # hard-drop stale work

    sim._async = AsyncRoundEngine(sim, weight_fn=wf)
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    batches, idxs, keys = _stage(6)
    st, mets = sim.run_rounds(st, batches, idxs, keys)
    assert calls and np.isfinite(_flat(st)).all()
    stale = [m for m in mets if m["staleness_max"] > 0]
    assert stale and all(m["weight_sum"] < m["buffer_fill"] for m in stale)


# -- EF residual repayment across in-flight dispatches -----------------------


def test_ef_residual_repays_on_next_dispatch():
    """A client's EF residual booked at dispatch r must shift its
    selection at dispatch r+1 even while the first payload is still in
    flight — bitwise vs a zeroed-residual twin: the twin's second
    dispatch equals a fresh-EF dispatch, the real one differs."""
    sim = FedSim(lambda p, b: mlp_loss(p, b, MC), _fed(async_buffer=4),
                 network=_net())
    st = sim.init(pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)))
    sim._ensure_async_fns()
    batches, idxs, keys = _stage(2)
    b0 = jax.tree.map(lambda x: x[0], batches)
    b1 = jax.tree.map(lambda x: x[1], batches)
    # use round 0's cohort for BOTH dispatches so client rows line up
    idx0 = idxs[0]
    e1, v1, i1, _ = sim._async_dispatch_fn(
        jnp.zeros_like(st.errors), st.x_client, b0, idx0, keys[0],
        jnp.int32(0), None)
    e1 = np.asarray(e1)
    assert np.abs(e1[np.asarray(idx0)]).sum() > 0   # residual was booked
    # twin: zero client 0's residual before the second dispatch
    e1z = e1.copy()
    e1z[int(idx0[0])] = 0.0
    _, v2, i2, _ = sim._async_dispatch_fn(
        jnp.asarray(e1), st.x_client, b1, idx0, keys[1], jnp.int32(1), None)
    _, v2z, i2z, _ = sim._async_dispatch_fn(
        jnp.asarray(e1z), st.x_client, b1, idx0, keys[1], jnp.int32(1),
        None)
    # client 0 repays its residual: payload differs from the zeroed twin
    assert not (np.array_equal(np.asarray(v2[0]), np.asarray(v2z[0]))
                and np.array_equal(np.asarray(i2[0]), np.asarray(i2z[0])))
    # everyone else kept their residual: bitwise identical payloads
    assert np.array_equal(np.asarray(v2[1:]), np.asarray(v2z[1:]))
    assert np.array_equal(np.asarray(i2[1:]), np.asarray(i2z[1:]))


# -- fault composition -------------------------------------------------------


def test_async_with_crash_faults():
    """Crashed clients never deliver: fewer flushes, a partial final
    flush is fill-masked, billing splits delivered vs attempted, and the
    model stays finite."""
    fed = _fed(async_buffer=4, fault=FaultConfig(crash_prob=0.25, seed=2))
    sim, st, h = _run(fed, rounds=8, network=_net(straggler=0.2))
    delivered = sum(m["buffer_fill"] for m in h)
    crashed = sum(m["crashed"] for m in h)
    assert crashed > 0
    assert delivered == 8 * N - crashed
    assert len(h) == int(np.ceil(delivered / 4))
    assert h[-1]["buffer_fill"] <= 4.0
    assert np.isfinite(_flat(st)).all()
    log = sim.comm_log
    assert log.uplink_bytes < log.uplink_bytes_attempted
    up_pc = sim.codec.nbytes(sim._d)
    assert log.uplink_bytes == int(delivered) * up_pc
    assert log.uplink_bytes_attempted == 8 * N * up_pc


def test_async_with_corruption_validates_before_ingest():
    fed = _fed(async_buffer=4,
               fault=FaultConfig(corrupt_prob=0.3, corrupt_mode="nan",
                                 seed=5))
    _, st, h = _run(fed, rounds=8)
    rejected = sum(m["rejected"] for m in h)
    assert rejected > 0                       # corruption actually fired
    assert np.isfinite(_flat(st)).all()       # ...and never reached state
    for m in h:
        assert np.isfinite(m["loss"])
        assert m["survivors"] == m["buffer_fill"] - m["rejected"]


def test_async_allones_fault_plan_matches_faultless():
    """FaultConfig() arms the fault machinery with nobody failing — the
    async run must produce the same model as the fault-free async run
    (dispatch fault path + flush re-validation are transparent)."""
    _, st0, h0 = _run(_fed(async_buffer=2), rounds=5,
                      network=_net(straggler=0.3))
    _, st1, h1 = _run(_fed(async_buffer=2, fault=FaultConfig()), rounds=5,
                      network=_net(straggler=0.3))
    assert np.allclose(_flat(st0), _flat(st1), rtol=1e-6, atol=1e-7)
    assert len(h0) == len(h1)
    assert all(m["rejected"] == 0.0 for m in h1)


# -- trainer routing ---------------------------------------------------------


def test_trainer_routes_async_and_records_flushes():
    from repro.core.api import FederatedTrainer
    from repro.configs.base import TrainConfig

    class Data:
        def round_batches(self, idx, r, k, bs):
            return DATA.round_batches(idx, r, k, bs)

    t = FederatedTrainer(
        fed=_fed(async_buffer=2), train=TrainConfig(rounds=6, log_every=100),
        loss_fn=lambda p, b: mlp_loss(p, b, MC),
        init_params=pdefs.init_params(mlp_defs(MC), jax.random.PRNGKey(0)),
        data=Data(), network=_net(straggler=0.3))
    hist = t.run(6, log=None)
    assert len(hist) == 12                   # flushes, not cohorts
    assert all("staleness_max" in h and "wire_up_bytes" in h for h in hist)
    assert hist[-1]["sim_time_s"] == pytest.approx(
        sum(h["round_time_s"] for h in hist), abs=1e-9)
