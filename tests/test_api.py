"""FederatedTrainer facade: both backends train end-to-end."""
import jax
import numpy as np

from repro.configs.base import FedConfig, ModelConfig, TrainConfig
from repro.core.api import FederatedTrainer
from repro.data.synthetic import FederatedClassification, FederatedLMData
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss
from repro.models.model import Model


def test_trainer_simulation_backend(tmp_path):
    mc = MLPConfig(in_dim=16, hidden=32, depth=1, num_classes=4)
    tr = FederatedTrainer(
        fed=FedConfig(algorithm="fedcams", num_clients=8, participating=4,
                      local_steps=2, compressor="topk", compress_ratio=1 / 8,
                      eta=0.1, eta_l=0.1),
        train=TrainConfig(rounds=10, log_every=100),
        loss_fn=lambda p, b: mlp_loss(p, b, mc),
        init_params=pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
    tr.data = FederatedClassification(num_clients=8, num_classes=4,
                                      feature_dim=16, seed=0)
    hist = tr.run(log=None)
    assert len(hist) == 10
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.2
    tr.save(str(tmp_path / "ck"))
    assert (tmp_path / "ck" / "manifest.json").exists()


def test_trainer_mesh_backend():
    from repro.launch.mesh import make_mesh
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32")
    mesh = make_mesh((1, 1), ("data", "model"))
    tr = FederatedTrainer(
        fed=FedConfig(algorithm="fedams", num_clients=1, local_steps=2,
                      client_axes=(), eta=0.3, eta_l=0.05),
        train=TrainConfig(global_batch=4, seq_len=16, rounds=5,
                          remat_policy="none", log_every=100),
        model=Model(cfg, tp=1), mesh=mesh)
    tr.lm_data = FederatedLMData(num_clients=1, vocab_size=64)
    hist = tr.run(log=None)
    assert np.isfinite([h["loss"] for h in hist]).all()
    assert hist[-1]["loss"] < hist[0]["loss"]
