"""Distribution correctness, run in subprocesses with fake host devices (so
the rest of the suite keeps seeing one device).

The key check: the shard_map mesh execution of the federated round is
numerically equivalent to the pure-simulation path (same clients, same
batches, same server math) — the SPMD mapping introduces no drift."""
import json

import jax
import pytest

from conftest import run_forced_devices

# Exact TP gradients through shard_map need the vma machinery
# (jax.shard_map with check_vma); on jax 0.4.x the compat path runs the
# experimental shard_map with check_rep=False, whose psum transpose is off
# by tp factors — see repro/compat.py and ParallelContext.tp_copy.
requires_vma = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="exact TP gradients need vma-era jax.shard_map (jax >= 0.7)")


def run_sub(code: str, devices: int = 8) -> str:
    return run_forced_devices(code, devices, timeout=600)


@pytest.mark.slow
@requires_vma
def test_mesh_round_matches_simulation():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.configs.base import ModelConfig, FedConfig, TrainConfig
        from repro.core.rounds import (FedSim, build_fed_round,
                                       init_fed_state, fed_state_defs,
                                       fed_batch_defs)
        from repro.models.model import Model
        from repro.models import params as pdefs
        from repro.sharding.rules import ParallelContext
        from repro.launch.mesh import make_mesh

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        m, K, GB, S = 4, 2, 8, 16
        fed = FedConfig(algorithm="fedams", num_clients=m, local_steps=K,
                        compressor="none", client_axes=("data",),
                        eta=0.3, eta_l=0.05)
        train = TrainConfig(global_batch=GB, seq_len=S, remat_policy="none")
        mesh = make_mesh((4, 2), ("data", "model"))
        model = Model(cfg, tp=2)
        ctx = ParallelContext(model_axis="model", tp=2,
                              client_axes=("data",), num_clients=m)
        sdefs = fed_state_defs(model, fed)
        ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
        bsp = jax.tree.map(lambda d: d.spec, fed_batch_defs(model, fed, train),
                           is_leaf=pdefs.is_def)
        rnd = jax.jit(compat.shard_map(build_fed_round(model, fed, train, ctx),
                      mesh=mesh, in_specs=(ssp, bsp, P()),
                      out_specs=(ssp, {"loss": P(), "wire_up_bytes": P()})))
        state = init_fed_state(model, fed, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 64, size=(K, GB, S)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks),
                 "labels": jnp.asarray(np.roll(toks, -1, -1))}
        mesh_losses = []
        for r in range(3):
            state, met = rnd(state, batch, jnp.int32(r))
            mesh_losses.append(float(met["loss"]))

        # --- pure simulation on the same data/clients -------------------
        model1 = Model(cfg, tp=1)
        ctx1 = ParallelContext()
        sim = FedSim(lambda p, b: model1.loss(p, b, ctx1,
                                              remat_policy="none"), fed)
        params = init_fed_state(model1, fed, jax.random.PRNGKey(0)).params
        st = sim.init(params)
        per = GB // m
        cb = {"tokens": jnp.asarray(toks.reshape(K, m, per, S)
                                    .transpose(1, 0, 2, 3)),
              "labels": jnp.asarray(np.roll(toks, -1, -1)
                                    .reshape(K, m, per, S)
                                    .transpose(1, 0, 2, 3))}
        sim_losses = []
        for r in range(3):
            st, met = sim.round(st, cb, jnp.arange(m), jax.random.PRNGKey(r))
            sim_losses.append(float(met["loss"]))
        print(json.dumps({"mesh": mesh_losses, "sim": sim_losses}))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    for a, b in zip(data["mesh"], data["sim"]):
        assert abs(a - b) < 5e-3, data


@pytest.mark.slow
def test_sparse_aggregation_equals_dense_topk():
    """Beyond-paper sparse all_gather aggregation == dense psum of the same
    per-leaf top-k compression (bitwise semantics, modulo float order)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.configs.base import ModelConfig, FedConfig, TrainConfig
        from repro.core.rounds import (build_fed_round, init_fed_state,
                                       fed_state_defs, fed_batch_defs)
        from repro.models.model import Model
        from repro.models import params as pdefs
        from repro.sharding.rules import ParallelContext
        from repro.launch.mesh import make_mesh

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        mesh = make_mesh((4, 2), ("data", "model"))
        model = Model(cfg, tp=2)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 64, size=(2, 8, 16)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks),
                 "labels": jnp.asarray(np.roll(toks, -1, -1))}
        res = {}
        for agg in ("dense", "sparse"):
            fed = FedConfig(algorithm="fedcams", num_clients=4, local_steps=2,
                            compressor="topk", compress_ratio=1/4,
                            aggregation=agg, client_axes=("data",),
                            eta=0.3, eta_l=0.05)
            train = TrainConfig(global_batch=8, seq_len=16,
                                remat_policy="none")
            ctx = ParallelContext(model_axis="model", tp=2,
                                  client_axes=("data",), num_clients=4)
            sdefs = fed_state_defs(model, fed)
            ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
            bsp = jax.tree.map(lambda d: d.spec,
                               fed_batch_defs(model, fed, train),
                               is_leaf=pdefs.is_def)
            rnd = jax.jit(compat.shard_map(
                build_fed_round(model, fed, train, ctx), mesh=mesh,
                in_specs=(ssp, bsp, P()), out_specs=(ssp, {"loss": P(), "wire_up_bytes": P()}),
                check_vma=True))
            state = init_fed_state(model, fed, jax.random.PRNGKey(0))
            losses = []
            for r in range(3):
                state, met = rnd(state, batch, jnp.int32(r))
                losses.append(float(met["loss"]))
            res[agg] = losses
        print(json.dumps(res))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    for a, b in zip(data["dense"], data["sparse"]):
        assert abs(a - b) < 5e-3, data


@pytest.mark.slow
def test_multipod_mesh_and_hierarchical_client():
    """3-axis (pod, data, model) mesh lowers and runs: per_data clients over
    (pod,data) and hierarchical per_pod clients with within-client DP."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.configs.base import ModelConfig, FedConfig, TrainConfig
        from repro.core.rounds import (build_fed_round, init_fed_state,
                                       fed_state_defs, fed_batch_defs)
        from repro.models.model import Model
        from repro.models import params as pdefs
        from repro.sharding.rules import ParallelContext
        from repro.launch.mesh import make_mesh

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        model = Model(cfg, tp=2)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 64, size=(1, 8, 16)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks),
                 "labels": jnp.asarray(np.roll(toks, -1, -1))}
        out = {}
        for mode, axes, m in (("per_data", ("pod", "data"), 4),
                              ("per_pod", ("pod",), 2)):
            fed = FedConfig(algorithm="fedcams", num_clients=m, local_steps=1,
                            compressor="topk", compress_ratio=1/4,
                            client_axes=axes, eta=0.3, eta_l=0.05)
            train = TrainConfig(global_batch=8, seq_len=16,
                                remat_policy="none")
            hier = "data" not in axes
            ctx = ParallelContext(model_axis="model", tp=2,
                                  data_axis="data" if hier else None,
                                  dp=2 if hier else 1,
                                  client_axes=axes, num_clients=m)
            sdefs = fed_state_defs(model, fed)
            ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
            bsp = jax.tree.map(lambda d: d.spec,
                               fed_batch_defs(model, fed, train),
                               is_leaf=pdefs.is_def)
            rnd = jax.jit(compat.shard_map(
                build_fed_round(model, fed, train, ctx), mesh=mesh,
                in_specs=(ssp, bsp, P()), out_specs=(ssp, {"loss": P(), "wire_up_bytes": P()}),
                check_vma=True))
            state = init_fed_state(model, fed, jax.random.PRNGKey(0))
            state, met = rnd(state, batch, jnp.int32(0))
            out[mode] = float(met["loss"])
        print(json.dumps(out))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    assert all(v == v for v in data.values())  # finite


@pytest.mark.slow
def test_seq_sharded_decode_matches_unsharded():
    """long_500k mechanism: LSE-combined attention over a sequence-sharded
    cache equals the single-device decode."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.configs.base import ModelConfig
        from repro.models.model import Model
        from repro.models import params as pdefs
        from repro.sharding.rules import ParallelContext
        from repro.launch.mesh import make_mesh

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        model = Model(cfg, tp=1)
        params = model.init(jax.random.PRNGKey(0))
        B, max_len, S = 1, 16, 10
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 64)

        # reference: unsharded decode
        ctx0 = ParallelContext()
        caches = model.init_cache(B, max_len)
        ref = []
        for i in range(S):
            lg, caches = model.decode_step(params, toks[:, i:i+1], caches,
                                           jnp.int32(i), ctx0,
                                           max_len=max_len)
            ref.append(np.asarray(lg))

        # seq-sharded over 4 "data" devices
        mesh = make_mesh((4,), ("data",))
        ctx = ParallelContext(seq_axis="data", seq_shards=4)
        from repro.launch.steps import remap_defs
        cdefs = remap_defs(model.cache_defs(B, max_len, seq_sharded=True),
                           {"model": None})
        csp = jax.tree.map(lambda d: d.spec, cdefs, is_leaf=pdefs.is_def)
        psp = jax.tree.map(lambda d: P(*[None]*len(d.shape)), model.defs(),
                           is_leaf=pdefs.is_def)
        step = jax.jit(compat.shard_map(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, ctx,
                                                   max_len=max_len),
            mesh=mesh, in_specs=(psp, P(), csp, P()),
            out_specs=(P(), csp)))
        from repro.models.stack import init_cache_value
        caches = init_cache_value(cdefs)
        errs = []
        for i in range(S):
            lg, caches = step(params, toks[:, i:i+1], caches, jnp.int32(i))
            errs.append(float(np.abs(np.asarray(lg) - ref[i]).max()))
        print(json.dumps({"max_err": max(errs)}))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    assert data["max_err"] < 1e-3, data


@pytest.mark.slow
def test_tp_serving_prefill_decode():
    """Tensor-parallel serving: prefill+decode under shard_map TP2 matches
    the single-device path (incl. vocab-sharded greedy sampling)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.configs.base import ModelConfig
        from repro.models.model import Model, greedy_sample
        from repro.models import params as pdefs
        from repro.sharding.rules import ParallelContext
        from repro.launch.mesh import make_mesh

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        max_len = 16
        model = Model(cfg, tp=2)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)

        # reference single-device generation
        ctx0 = ParallelContext()
        lg, caches = model.prefill(params, prompts, ctx0, max_len=max_len)
        tok = greedy_sample(lg, ctx0)[:, None].astype(jnp.int32)
        ref = [np.asarray(tok[:, 0])]
        for i in range(5):
            lg, caches = model.decode_step(params, tok, caches,
                                           jnp.int32(8 + i), ctx0,
                                           max_len=max_len)
            tok = greedy_sample(lg, ctx0)[:, None].astype(jnp.int32)
            ref.append(np.asarray(tok[:, 0]))

        mesh = make_mesh((2,), ("model",))
        ctx = ParallelContext(model_axis="model", tp=2)
        psp = jax.tree.map(lambda d: d.spec, model.defs(),
                           is_leaf=pdefs.is_def)
        cdefs = model.cache_defs(2, max_len, seq_sharded=False)
        cdefs = jax.tree.map(
            lambda d: d, cdefs, is_leaf=pdefs.is_def)
        from repro.launch.steps import remap_defs
        cdefs = remap_defs(cdefs, {"data": None})
        csp = jax.tree.map(lambda d: d.spec, cdefs, is_leaf=pdefs.is_def)
        prefill = jax.jit(compat.shard_map(
            lambda p, t: model.prefill(p, t, ctx, max_len=max_len),
            mesh=mesh, in_specs=(psp, P()),
            out_specs=(P(None, "model"), csp)))
        def dstep(p, t, c, pos):
            lg, c2 = model.decode_step(p, t, c, pos, ctx, max_len=max_len)
            return greedy_sample(lg, ctx), c2
        decode = jax.jit(compat.shard_map(
            dstep, mesh=mesh, in_specs=(psp, P(), csp, P()),
            out_specs=(P(), csp)))

        lg, caches = prefill(params, prompts)
        tok = greedy_sample(lg, ParallelContext())[:, None].astype(jnp.int32)
        got = [np.asarray(tok[:, 0])]
        for i in range(5):
            t2, caches = decode(params, tok, caches, jnp.int32(8 + i))
            tok = t2[:, None].astype(jnp.int32)
            got.append(np.asarray(tok[:, 0]))
        print(json.dumps({"ref": np.stack(ref, 1).tolist(),
                          "got": np.stack(got, 1).tolist()}))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    assert data["ref"] == data["got"], data
