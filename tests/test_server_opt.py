"""Server optimizer semantics: FedAMS options, baselines, v̂ monotonicity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.server_opt import init_server_state, server_update


def _mk(algo, **kw):
    return FedConfig(algorithm=algo, eta=kw.pop("eta", 1.0),
                     beta1=kw.pop("beta1", 0.9), beta2=kw.pop("beta2", 0.99),
                     eps=kw.pop("eps", 1e-3), **kw)


def _steps(fed, T=10, seed=0, d=32):
    r = np.random.default_rng(seed)
    x = jnp.zeros(d)
    st = init_server_state(x)
    traj = []
    for _ in range(T):
        delta = jnp.asarray(r.normal(size=d) * 0.1, jnp.float32)
        x, st = server_update(fed, st, x, delta)
        traj.append((np.asarray(x), st))
    return traj


def test_fedavg_is_plain_average_step():
    fed = _mk("fedavg", eta=1.0)
    x = jnp.zeros(4)
    st = init_server_state(x)
    x2, _ = server_update(fed, st, x, jnp.asarray([1.0, -2.0, 0.5, 0.0]))
    assert np.allclose(np.asarray(x2), [1.0, -2.0, 0.5, 0.0])


def test_vhat_monotone_both_options():
    for opt in (1, 2):
        fed = dataclasses.replace(_mk("fedams"), option=opt)
        prev = None
        for _, st in _steps(fed):
            vh = np.asarray(st.vhat)
            if prev is not None:
                assert (vh >= prev - 1e-12).all()
            prev = vh


def test_option1_vhat_floor_is_eps():
    fed = _mk("fedams")
    _, st = _steps(fed, T=1)[0]
    assert (np.asarray(st.vhat) >= fed.eps - 1e-12).all()


def test_option2_matches_amsgrad_reference():
    """Option 2 ≡ AMSGrad on pseudo-gradients (numpy reference)."""
    fed = dataclasses.replace(_mk("fedamsgrad"), option=2)
    r = np.random.default_rng(1)
    d = 16
    x = np.zeros(d)
    m = np.zeros(d)
    v = np.zeros(d)
    vh = np.zeros(d)
    xj = jnp.zeros(d)
    st = init_server_state(xj)
    for _ in range(5):
        delta = r.normal(size=d).astype(np.float32) * 0.1
        m = 0.9 * m + 0.1 * delta
        v = 0.99 * v + 0.01 * delta * delta
        vh = np.maximum(vh, v)
        x = x + 1.0 * m / (np.sqrt(vh) + 1e-3)
        xj, st = server_update(fed, st, xj, jnp.asarray(delta))
        assert np.allclose(np.asarray(xj), x, atol=1e-5)


def test_kernel_update_bitwise_vs_server_update_flat():
    """Regression gate for the kernel server step's MATH:
    ``kernels.fedams_update`` is BIT-IDENTICAL to the jnp ``server_update``
    across {fedams, fedamsgrad, fedcams} x {option 1, 2} on all four state
    arrays over multiple steps — incl. the eps-max ordering split (option 1
    folds eps into the v̂ max and divides by √v̂; option 2 adds eps after
    the √), the fedamsgrad-IS-option-2 mapping, the true-division x update
    (a rsqrt multiply deviates here immediately), and the
    ``(1-β₂)·(δ·δ)`` association of the v update (``jnp.square``, matching
    the jnp branches — the left-associated ``(1-β₂)·δ·δ`` is 1 ulp off).
    Both sides run jitted at the same (single-block) shape so XLA makes
    identical contraction choices."""
    from repro.kernels.fedams_update import fedams_update

    r = np.random.default_rng(11)
    n = 4096
    for algo in ("fedams", "fedamsgrad", "fedcams"):
        for option in (1, 2):
            fed = dataclasses.replace(_mk(algo, eta=0.7), option=option)
            opt_k = 2 if algo == "fedamsgrad" else option
            x = jnp.asarray(r.normal(size=n), jnp.float32)
            st = init_server_state(x)
            upd = jax.jit(lambda s, xx, d, fed=fed: server_update(fed, s,
                                                                  xx, d))
            xj, (xk, m, v, vh) = x, (x, st.m, st.v, st.vhat)
            for step in range(4):
                delta = jnp.asarray(r.normal(size=n) * 0.1, jnp.float32)
                xj, st = upd(st, xj, delta)
                xk, m, v, vh = fedams_update(
                    xk, m, v, vh, delta, eta=0.7, beta1=fed.beta1,
                    beta2=fed.beta2, eps=fed.eps, option=opt_k, block=n)
                for name, a, b in (("x", xj, xk), ("m", st.m, m),
                                   ("v", st.v, v), ("vhat", st.vhat, vh)):
                    np.testing.assert_array_equal(
                        np.asarray(a), np.asarray(b),
                        err_msg=f"{algo} opt{option} step{step} {name}")


def test_kernel_update_tree_parity_vs_server_update():
    """``KernelImpl.fedams_update_tree`` vs jnp ``server_update`` on a
    multi-leaf tree (padding + t counter exercised), both jitted:
    m/v/v̂ stay bitwise; x gets a tiny tolerance — across the two
    differently-shaped programs (padded flat blocks vs raw leaf shapes)
    XLA CPU may compile the x division with a contracted FMA/rsqrt form,
    a few ulp of each increment that accumulates in the carried x.
    The update-math bitwise gate is the flat test above."""
    from repro.kernels.ops import KernelImpl

    ki = KernelImpl(block=128)
    r = np.random.default_rng(11)
    params = {"w": jnp.asarray(r.normal(size=(10, 30)), jnp.float32),
              "b": jnp.asarray(r.normal(size=5), jnp.float32)}
    for algo in ("fedams", "fedamsgrad"):
        for option in (1, 2):
            fed = dataclasses.replace(_mk(algo, eta=0.7), option=option)
            st_j = init_server_state(params)
            st_k = init_server_state(params)
            xj = xk = params
            upd_j = jax.jit(lambda s, x, d, fed=fed: server_update(
                fed, s, x, d))
            upd_k = jax.jit(lambda s, x, d, fed=fed: ki.fedams_update_tree(
                fed, s, x, d))
            for step in range(6):
                delta = jax.tree.map(
                    lambda p: jnp.asarray(
                        r.normal(size=p.shape) * 0.1, jnp.float32), params)
                xj, st_j = upd_j(st_j, xj, delta)
                xk, st_k = upd_k(st_k, xk, delta)
                for name, a, b in (("m", st_j.m, st_k.m),
                                   ("v", st_j.v, st_k.v),
                                   ("vhat", st_j.vhat, st_k.vhat)):
                    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                        np.testing.assert_array_equal(
                            np.asarray(la), np.asarray(lb),
                            err_msg=f"{algo} opt{option} step{step} {name}")
                for la, lb in zip(jax.tree.leaves(xj), jax.tree.leaves(xk)):
                    np.testing.assert_allclose(
                        np.asarray(la), np.asarray(lb),
                        rtol=1e-6, atol=1e-6)
            assert int(st_k.t) == int(st_j.t)


def test_fedyogi_differs_from_fedadam():
    a = _steps(_mk("fedadam"), T=5, seed=3)[-1][0]
    y = _steps(_mk("fedyogi"), T=5, seed=3)[-1][0]
    assert not np.allclose(a, y)


def test_bounded_update_magnitude_option1():
    """|Δx| <= η·|m|/sqrt(eps): max stabilization bounds the step."""
    fed = _mk("fedams", eps=1e-2)
    prev = np.zeros(32)
    for x, st in _steps(fed, T=8, seed=5):
        step = np.abs(x - prev)
        bound = 1.0 * np.abs(np.asarray(st.m)) / np.sqrt(1e-2) + 1e-6
        assert (step <= bound).all()
        prev = x
