"""Server optimizer semantics: FedAMS options, baselines, v̂ monotonicity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.server_opt import init_server_state, server_update


def _mk(algo, **kw):
    return FedConfig(algorithm=algo, eta=kw.pop("eta", 1.0),
                     beta1=kw.pop("beta1", 0.9), beta2=kw.pop("beta2", 0.99),
                     eps=kw.pop("eps", 1e-3), **kw)


def _steps(fed, T=10, seed=0, d=32):
    r = np.random.default_rng(seed)
    x = jnp.zeros(d)
    st = init_server_state(x)
    traj = []
    for _ in range(T):
        delta = jnp.asarray(r.normal(size=d) * 0.1, jnp.float32)
        x, st = server_update(fed, st, x, delta)
        traj.append((np.asarray(x), st))
    return traj


def test_fedavg_is_plain_average_step():
    fed = _mk("fedavg", eta=1.0)
    x = jnp.zeros(4)
    st = init_server_state(x)
    x2, _ = server_update(fed, st, x, jnp.asarray([1.0, -2.0, 0.5, 0.0]))
    assert np.allclose(np.asarray(x2), [1.0, -2.0, 0.5, 0.0])


def test_vhat_monotone_both_options():
    for opt in (1, 2):
        fed = dataclasses.replace(_mk("fedams"), option=opt)
        prev = None
        for _, st in _steps(fed):
            vh = np.asarray(st.vhat)
            if prev is not None:
                assert (vh >= prev - 1e-12).all()
            prev = vh


def test_option1_vhat_floor_is_eps():
    fed = _mk("fedams")
    _, st = _steps(fed, T=1)[0]
    assert (np.asarray(st.vhat) >= fed.eps - 1e-12).all()


def test_option2_matches_amsgrad_reference():
    """Option 2 ≡ AMSGrad on pseudo-gradients (numpy reference)."""
    fed = dataclasses.replace(_mk("fedamsgrad"), option=2)
    r = np.random.default_rng(1)
    d = 16
    x = np.zeros(d)
    m = np.zeros(d)
    v = np.zeros(d)
    vh = np.zeros(d)
    xj = jnp.zeros(d)
    st = init_server_state(xj)
    for _ in range(5):
        delta = r.normal(size=d).astype(np.float32) * 0.1
        m = 0.9 * m + 0.1 * delta
        v = 0.99 * v + 0.01 * delta * delta
        vh = np.maximum(vh, v)
        x = x + 1.0 * m / (np.sqrt(vh) + 1e-3)
        xj, st = server_update(fed, st, xj, jnp.asarray(delta))
        assert np.allclose(np.asarray(xj), x, atol=1e-5)


def test_fedyogi_differs_from_fedadam():
    a = _steps(_mk("fedadam"), T=5, seed=3)[-1][0]
    y = _steps(_mk("fedyogi"), T=5, seed=3)[-1][0]
    assert not np.allclose(a, y)


def test_bounded_update_magnitude_option1():
    """|Δx| <= η·|m|/sqrt(eps): max stabilization bounds the step."""
    fed = _mk("fedams", eps=1e-2)
    prev = np.zeros(32)
    for x, st in _steps(fed, T=8, seed=5):
        step = np.abs(x - prev)
        bound = 1.0 * np.abs(np.asarray(st.m)) / np.sqrt(1e-2) + 1e-6
        assert (step <= bound).all()
        prev = x
