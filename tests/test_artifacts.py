"""Integrity of the shipped dry-run artifact: the full 80-case matrix
(10 archs x 4 shapes x 2 meshes) must be present with ok/justified-skip
statuses and well-formed roofline terms."""
import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


@pytest.fixture(scope="module")
def results():
    if not os.path.exists(RESULTS):
        pytest.skip("results/dryrun.json not generated in this checkout")
    with open(RESULTS) as f:
        return json.load(f)


def test_full_matrix_present(results):
    from repro.configs import ARCH_IDS, INPUT_SHAPES
    missing = []
    for mesh in ("pod16x16", "pod2x16x16"):
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                if f"baseline/{mesh}/{arch}/{shape}" not in results:
                    missing.append((mesh, arch, shape))
    assert not missing, missing


def test_statuses_ok_or_justified_skip(results):
    for k, v in results.items():
        if not k.startswith("baseline/"):
            continue
        assert v["status"] in ("ok", "skipped"), (k, v.get("error", ""))
        if v["status"] == "skipped":
            assert "hubert" in k and ("decode" in k or "long" in k), k
            assert "encoder-only" in v["reason"]


def test_roofline_terms_well_formed(results):
    for k, v in results.items():
        if not k.startswith("baseline/") or v["status"] != "ok":
            continue
        rl = v["roofline"]
        assert rl["compute_s"] > 0, k
        assert rl["memory_s"] > 0, k
        assert rl["dominant"] in ("compute", "memory", "collective"), k
        assert 0 < rl["useful_ratio"] < 10, (k, rl["useful_ratio"])
        # train steps must show client/TP collectives
        if k.endswith("train_4k"):
            assert rl["collective_s"] > 0, k
