"""Integrity of shipped/durable artifacts: the dry-run matrix (the full
80-case 10 archs x 4 shapes x 2 meshes grid with ok/justified-skip statuses
and well-formed roofline terms) and the checkpoint store's restore-time
validation."""
import json
import os

import numpy as np
import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


@pytest.fixture(scope="module")
def results():
    if not os.path.exists(RESULTS):
        pytest.skip("results/dryrun.json not generated in this checkout")
    with open(RESULTS) as f:
        return json.load(f)


def test_full_matrix_present(results):
    from repro.configs import ARCH_IDS, INPUT_SHAPES
    missing = []
    for mesh in ("pod16x16", "pod2x16x16"):
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                if f"baseline/{mesh}/{arch}/{shape}" not in results:
                    missing.append((mesh, arch, shape))
    assert not missing, missing


def test_statuses_ok_or_justified_skip(results):
    for k, v in results.items():
        if not k.startswith("baseline/"):
            continue
        assert v["status"] in ("ok", "skipped"), (k, v.get("error", ""))
        if v["status"] == "skipped":
            assert "hubert" in k and ("decode" in k or "long" in k), k
            assert "encoder-only" in v["reason"]


def test_roofline_terms_well_formed(results):
    for k, v in results.items():
        if not k.startswith("baseline/") or v["status"] != "ok":
            continue
        rl = v["roofline"]
        assert rl["compute_s"] > 0, k
        assert rl["memory_s"] > 0, k
        assert rl["dominant"] in ("compute", "memory", "collective"), k
        assert 0 < rl["useful_ratio"] < 10, (k, rl["useful_ratio"])
        # train steps must show client/TP collectives
        if k.endswith("train_4k"):
            assert rl["collective_s"] > 0, k


# -- checkpoint store restore-time validation --------------------------------


def test_load_pytree_rejects_dtype_mismatch(tmp_path):
    """Regression: restoring into a differently-dtyped target must raise,
    never silently cast — int8 blockscale payloads read back as float
    counts (or fp32 moments truncated to bf16) would corrupt optimizer
    state without a single error."""
    from repro.checkpoint.store import load_pytree, save_pytree
    tree = {"v": np.arange(8, dtype=np.float32),
            "q": np.arange(8, dtype=np.int8)}
    save_pytree(str(tmp_path / "ck"), tree)
    # same dtypes round-trip fine
    got, _ = load_pytree(str(tmp_path / "ck"), tree)
    np.testing.assert_array_equal(got["v"], tree["v"])
    assert got["q"].dtype == np.int8
    # restore target disagrees -> hard error naming the leaf
    bad = {"v": np.arange(8, dtype=np.float32),
           "q": np.arange(8, dtype=np.float32)}
    with pytest.raises(ValueError, match="dtype mismatch for .*q"):
        load_pytree(str(tmp_path / "ck"), bad)


def test_load_pytree_rejects_manifest_file_disagreement(tmp_path):
    """The manifest is the source of truth for what was saved; if the
    .npz holds a different dtype the files are inconsistent (corrupt or
    mixed save) and the restore must refuse."""
    from repro.checkpoint.store import load_pytree, save_pytree
    tree = {"v": np.arange(4, dtype=np.float32)}
    save_pytree(str(tmp_path / "ck"), tree)
    man = tmp_path / "ck" / "manifest.json"
    m = json.loads(man.read_text())
    m["dtypes"][0] = "int32"
    man.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="manifest recorded"):
        load_pytree(str(tmp_path / "ck"),
                    {"v": np.arange(4, dtype=np.float32)})
