"""Compressor properties (paper Assumption 4.14 and Remarks 4.15/4.16)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # see tests/hypothesis_fallback.py
    from hypothesis_fallback import given, settings, st

from repro.core.compressors import (make_blocktopk, make_compressor,
                                    make_identity, make_int8, make_randk,
                                    make_sign, make_topk)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _vec(seed, d):
    return jnp.asarray(np.random.default_rng(seed).normal(size=d),
                       jnp.float32)


@given(st.integers(0, 10**6), st.integers(8, 400),
       st.sampled_from([1 / 2, 1 / 4, 1 / 8, 1 / 64]))
def test_topk_contraction(seed, d, ratio):
    """‖C(x)−x‖ <= sqrt(1−k/d)·‖x‖ (Remark 4.15, with k the realized count)."""
    x = _vec(seed, d)
    comp = make_topk(ratio)
    k = max(1, int(round(ratio * d)))
    hat = comp.compress(x)
    resid = float(jnp.linalg.norm(hat - x))
    bound = float(np.sqrt(max(1 - k / d, 0.0)) * jnp.linalg.norm(x))
    assert resid <= bound + 1e-5
    assert int(jnp.sum(hat != 0)) <= k


@given(st.integers(0, 10**6), st.integers(8, 300))
def test_sign_contraction(seed, d):
    """q = sqrt(1 − ‖x‖₁²/(d‖x‖²)) exactly (Remark 4.16)."""
    x = _vec(seed, d)
    comp = make_sign()
    hat = comp.compress(x)
    resid = float(jnp.linalg.norm(hat - x))
    q = comp.q_bound(x)
    assert resid <= q * float(jnp.linalg.norm(x)) + 1e-4


@given(st.integers(0, 10**6), st.integers(10, 500),
       st.sampled_from([1 / 4, 1 / 16]), st.sampled_from([16, 64]))
def test_blocktopk_contraction(seed, d, ratio, block):
    """Blockwise top-k preserves the global q = sqrt(1-r) bound."""
    x = _vec(seed, d)
    comp = make_blocktopk(ratio, block)
    hat = comp.compress(x)
    resid = float(jnp.linalg.norm(hat - x))
    bound = float(np.sqrt(1 - ratio) * jnp.linalg.norm(x)) * (1 + 1e-5)
    # per-block exact-k keeps >= global k elements; bound still holds per block
    assert resid <= bound + 1e-5


def test_topk_selects_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -1.5])
    hat = make_topk(3 / 8).compress(x)
    nz = set(np.nonzero(np.asarray(hat))[0].tolist())
    assert nz == {1, 3, 7}
    assert np.allclose(np.asarray(hat)[[1, 3, 7]], [-5.0, 3.0, -1.5])


def test_sign_formula():
    x = jnp.asarray([1.0, -2.0, 3.0, -4.0])
    hat = make_sign().compress(x)
    assert np.allclose(np.asarray(hat), 2.5 * np.sign(np.asarray(x)))


def test_identity_and_int8():
    x = _vec(0, 100)
    assert np.allclose(make_identity().compress(x), x)
    h = make_int8().compress(x)
    assert float(jnp.max(jnp.abs(h - x))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_randk_needs_rng_and_keeps_k():
    x = _vec(0, 64)
    comp = make_randk(1 / 4)
    with pytest.raises(AssertionError):
        comp.compress(x)
    h = comp.compress(x, jax.random.PRNGKey(0))
    assert int(jnp.sum(h != 0)) <= 16


def test_bits_accounting_table1():
    """Paper Table 1 one-way costs."""
    d = 6400
    assert make_sign().bits_per_message(d) == 32 + d
    assert make_topk(1 / 64).bits_per_message(d) == 64 * 100
    assert make_identity().bits_per_message(d) == 32 * d


def test_make_compressor_registry():
    for name in ["topk", "blocktopk", "sign", "packedsign", "randk", "int8",
                 "none"]:
        assert make_compressor(name, 1 / 8).name
    with pytest.raises(ValueError):
        make_compressor("bogus")
