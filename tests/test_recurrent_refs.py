"""Recurrent mixers against naive step-by-step references, plus the
chunkwise-recurrent mLSTM equivalence (the §Perf optimization must be a
pure re-bracketing of the same math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RGLRUConfig, XLSTMConfig
from repro.models import params as pdefs
from repro.models.rglru import RGLRUState, rglru_decode, rglru_defs, rglru_train
from repro.models.xlstm import (MLSTMState, mlstm_decode, mlstm_defs,
                                mlstm_train, mlstm_train_chunkwise)
from repro.sharding.rules import ParallelContext

CTX = ParallelContext()


def test_rglru_train_matches_stepwise_decode():
    r = RGLRUConfig(lru_width=32, conv_width=4)
    p = pdefs.init_params(rglru_defs(32, r), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    full = rglru_train(p, x, r, CTX, "float32")
    st = RGLRUState(h=jnp.zeros((2, 32)),
                    conv=jnp.zeros((2, 3, 32)))
    outs = []
    for t in range(10):
        o, st = rglru_decode(p, x[:, t:t + 1], st, r, CTX, "float32")
        outs.append(o[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=1e-4)


def test_rglru_decay_in_unit_interval():
    """a_t = exp(-c softplus(Λ) σ(gate)) must be in (0, 1]."""
    r = RGLRUConfig(lru_width=16)
    p = pdefs.init_params(rglru_defs(16, r), jax.random.PRNGKey(0))
    from repro.models.rglru import _gates
    xc = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 16)) * 3
    a, b = _gates(p, xc, r)
    assert float(a.min()) > 0.0 and float(a.max()) <= 1.0


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunkwise_equals_quadratic(chunk):
    p = pdefs.init_params(mlstm_defs(64, 4, XLSTMConfig()),
                          jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    a = mlstm_train(p, x, 4, CTX, "float32")
    b = mlstm_train_chunkwise(p, x, 4, CTX, "float32", chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    _, sa = mlstm_train(p, x, 4, CTX, "float32", return_state=True)
    _, sb = mlstm_train_chunkwise(p, x, 4, CTX, "float32", chunk=chunk,
                                  return_state=True)
    np.testing.assert_allclose(np.asarray(sa.C), np.asarray(sb.C), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.m), np.asarray(sb.m), atol=1e-4)


def test_mlstm_train_matches_stepwise_decode():
    p = pdefs.init_params(mlstm_defs(32, 4, XLSTMConfig()),
                          jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 32))
    full = mlstm_train(p, x, 4, CTX, "float32")
    di = p["w_q"].shape[1]
    dh = di // 4
    st = MLSTMState(C=jnp.zeros((1, 4, dh, dh)), n=jnp.zeros((1, 4, dh)),
                    m=jnp.full((1, 4), -1e30))
    outs = []
    for t in range(8):
        o, st = mlstm_decode(p, x[:, t:t + 1], st, 4, CTX, "float32")
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.stack(outs, 1)), atol=1e-4)


def test_weighted_sampling_respects_weights():
    from repro.core.sampling import weighted_participation_mask
    w = jnp.asarray([10.0, 10.0, 0.001, 0.001])
    hits = np.zeros(4)
    for s in range(200):
        m = weighted_participation_mask(jax.random.PRNGKey(s), w, 2)
        hits += np.asarray(m)
    assert hits[0] > 150 and hits[1] > 150
    assert hits[2] < 50 and hits[3] < 50


def test_fedadagrad_accumulates_variance():
    from repro.configs.base import FedConfig
    from repro.core.server_opt import init_server_state, server_update
    fed = FedConfig(algorithm="fedadagrad", eta=0.1)
    x = jnp.zeros(4)
    st = init_server_state(x)
    d = jnp.ones(4)
    prev = np.zeros(4)
    for _ in range(3):
        x, st = server_update(fed, st, x, d)
        v = np.asarray(st.v)
        assert (v > prev).all()   # strictly accumulating
        prev = v
