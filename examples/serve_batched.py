"""Serve a small model with batched requests: prefill a batch of prompts,
then greedy-decode continuations through the KV-cache path (the same code
the decode_32k / long_500k dry-runs lower for the production mesh).

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-2b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import Model, greedy_sample
from repro.sharding.rules import ParallelContext

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma2-2b",
                help="architecture id (smoke variant is served)")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=32)
args = ap.parse_args()

spec = get_arch(args.arch)
cfg = spec.smoke
if cfg.is_encoder:
    raise SystemExit(f"{args.arch} is encoder-only (no decode)")
max_len = args.prompt_len + args.gen
model = Model(cfg, tp=1)
ctx = ParallelContext()
params = model.init(jax.random.PRNGKey(0))

prompts = np.random.default_rng(0).integers(
    0, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)

prefill = jax.jit(lambda p, t: model.prefill(p, t, ctx, max_len=max_len))
decode = jax.jit(lambda p, t, c, pos: model.decode_step(
    p, t, c, pos, ctx, max_len=max_len))

t0 = time.time()
logits, caches = prefill(params, jnp.asarray(prompts))
tok = greedy_sample(logits, ctx)[:, None].astype(jnp.int32)
print(f"prefill {args.batch}x{args.prompt_len}: {(time.time()-t0)*1e3:.0f} ms")

out = [np.asarray(tok[:, 0])]
t0 = time.time()
for i in range(args.gen - 1):
    lg, caches = decode(params, tok, caches, jnp.int32(args.prompt_len + i))
    tok = greedy_sample(lg, ctx)[:, None].astype(jnp.int32)
    out.append(np.asarray(tok[:, 0]))
dt = time.time() - t0
gen = np.stack(out, 1)
print(f"decode: {dt/(args.gen-1)*1e3:.1f} ms/step, "
      f"{args.batch*(args.gen-1)/dt:.0f} tok/s")
for b in range(min(args.batch, 3)):
    print(f"  request[{b}]: ...{prompts[b,-4:].tolist()} -> "
          f"{gen[b,:12].tolist()}...")
