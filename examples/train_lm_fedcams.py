"""End-to-end driver: federated training of a transformer LM with FedCAMS.

Runs the full production code path (Model substrate + mesh fed_round via
shard_map) on host devices. The default preset is a ~10M-param gemma-2-style
model federated over 4 clients with 2-way tensor parallelism — a few
hundred rounds are CPU-feasible; --preset 100m scales the same config up.

    PYTHONPATH=src python examples/train_lm_fedcams.py --rounds 200
    PYTHONPATH=src python examples/train_lm_fedcams.py --preset 100m \
        --rounds 300   # the assignment's ~100M-model target
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ap = argparse.ArgumentParser()
ap.add_argument("--preset", default="10m", choices=["2m", "10m", "100m"])
ap.add_argument("--rounds", type=int, default=100)
ap.add_argument("--clients", type=int, default=4)
ap.add_argument("--tp", type=int, default=2)
ap.add_argument("--seq-len", type=int, default=128)
ap.add_argument("--global-batch", type=int, default=8)
ap.add_argument("--compressor", default="topk")
ap.add_argument("--ratio", type=float, default=1 / 64)
ap.add_argument("--checkpoint", default="")
args = ap.parse_args()

os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={args.clients * args.tp}")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import FedConfig, ModelConfig, TrainConfig
from repro.core import (build_fed_round, fed_batch_defs, fed_state_defs,
                        init_fed_state)
from repro.data import FederatedLMData
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.models import params as pdefs
from repro.sharding.rules import ParallelContext

SIZES = {  # layers, d_model, heads, kv, d_ff, vocab
    "2m": (2, 128, 4, 2, 384, 512),
    "10m": (4, 256, 8, 4, 768, 2048),
    "100m": (12, 768, 12, 4, 2048, 8192),
}
L, D, H, KV, FF, V = SIZES[args.preset]
cfg = ModelConfig(name=f"lm-{args.preset}", family="dense", num_layers=L,
                  d_model=D, num_heads=H, num_kv_heads=KV, d_ff=FF,
                  vocab_size=V, attn_pattern=(64, 0), logit_softcap=30.0,
                  dtype="float32")
fed = FedConfig(algorithm="fedcams", compressor=args.compressor,
                compress_ratio=args.ratio, num_clients=args.clients,
                local_steps=2, eta=0.3, eta_l=0.05, client_axes=("data",))
train = TrainConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                    remat_policy="none")

mesh = make_mesh((args.clients, args.tp), ("data", "model"))
model = Model(cfg, tp=args.tp)
ctx = ParallelContext(model_axis="model" if args.tp > 1 else None,
                      tp=args.tp, client_axes=("data",),
                      num_clients=args.clients)
sdefs = fed_state_defs(model, fed)
ssp = jax.tree.map(lambda d: d.spec, sdefs, is_leaf=pdefs.is_def)
bsp = jax.tree.map(lambda d: d.spec, fed_batch_defs(model, fed, train),
                   is_leaf=pdefs.is_def)
step = jax.jit(compat.shard_map(build_fed_round(model, fed, train, ctx),
                                mesh=mesh, in_specs=(ssp, bsp, P()),
                                out_specs=(ssp, {"loss": P(),
                                                 "wire_up_bytes": P()})))
state = init_fed_state(model, fed, jax.random.PRNGKey(0))
nparams = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state.params))
print(f"model={cfg.name} params={nparams/1e6:.1f}M clients={args.clients} "
      f"tp={args.tp} compressor={fed.compressor} r={fed.compress_ratio:g}")

data = FederatedLMData(num_clients=args.clients, vocab_size=V)
t0 = time.time()
for r in range(args.rounds):
    raw = data.mesh_batch(r, fed.local_steps, args.global_batch, args.seq_len)
    state, met = step(state, {k: jnp.asarray(v) for k, v in raw.items()},
                      jnp.int32(r))
    if r % 10 == 0 or r == args.rounds - 1:
        print(f"round {r:4d}  loss {float(met['loss']):7.4f}  "
              f"wire {float(met['wire_up_bytes'])/1e6:6.2f} MB/round  "
              f"({time.time()-t0:6.1f}s)")
if args.checkpoint:
    from repro.checkpoint import save_pytree
    save_pytree(args.checkpoint, jax.device_get(state.params),
                {"preset": args.preset, "rounds": args.rounds})
    print("saved", args.checkpoint)
