"""Wire-mode quickstart: the same FedCAMS run as quickstart.py, but every
client delta is *actually serialized* to packed bytes (repro.comm.wire),
pushed through a simulated heterogeneous network (repro.comm.transport) and
decoded server-side — so alongside the paper's analytic bit accounting you
get measured wire bytes and simulated round wall-clock, including a
two-way-compressed downlink.

    PYTHONPATH=src python examples/quickstart_wire.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.comm import NetworkConfig, SimulatedNetwork
from repro.configs import FedConfig, TrainConfig
from repro.core import FederatedTrainer
from repro.data import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

mc = MLPConfig(in_dim=32, hidden=64, depth=2, num_classes=10)
fed = FedConfig(algorithm="fedcams", compressor="sign",
                num_clients=100, participating=10, local_steps=3,
                eta=0.1, eta_l=0.05, eps=1e-4,   # benchmarks/common.py TUNED
                wire=True, two_way=True)      # <- measured bytes, both ways
net = SimulatedNetwork(                        # uplink-constrained WAN with
    NetworkConfig(uplink_mbps=10, downlink_mbps=50,  # 5% stragglers
                  straggler_prob=0.05, seed=0), fed.num_clients)

trainer = FederatedTrainer(
    fed=fed, train=TrainConfig(rounds=50, log_every=10),
    loss_fn=lambda p, b: mlp_loss(p, b, mc),
    init_params=pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)),
    network=net)
trainer.data = FederatedClassification(num_clients=100, feature_dim=32,
                                       alpha=0.3)

hist = trainer.run(log=None)
for rec in hist:
    if rec["round"] % 10 == 0 or rec["round"] == len(hist) - 1:
        print(f"round {rec['round']:3d}  loss {rec['loss']:.4f}  "
              f"wire {rec['wire_bytes']/1e6:6.2f} MB  "
              f"round {rec['round_time_s']*1e3:6.1f} ms  "
              f"simulated total {rec['sim_time_s']:6.2f} s")
d = trainer._sim._d
print(f"\nuncompressed fp32 would be {len(hist)*10*2*4*d/1e6:.1f} MB; "
      f"the wire carried {hist[-1]['wire_bytes']/1e6:.2f} MB")
