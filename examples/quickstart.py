"""Quickstart: FedCAMS (the paper's algorithm) training a tiny model in ~30
lines — compressed client->server communication with error feedback, the
FedAMS max-stabilized server optimizer, and partial participation.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig
from repro.core import FedSim, sample_clients
from repro.data import FederatedClassification
from repro.models import params as pdefs
from repro.models.convmixer import MLPConfig, mlp_defs, mlp_loss

mc = MLPConfig(in_dim=32, hidden=64, depth=2, num_classes=10)
data = FederatedClassification(num_clients=100, feature_dim=32, alpha=0.3)
fed = FedConfig(algorithm="fedcams", compressor="topk", compress_ratio=1 / 64,
                num_clients=100, participating=10, local_steps=3,
                eta=0.03, eta_l=0.05)

sim = FedSim(lambda p, b: mlp_loss(p, b, mc), fed)
state = sim.init(pdefs.init_params(mlp_defs(mc), jax.random.PRNGKey(0)))
rng = jax.random.PRNGKey(1)

for r in range(50):
    rng, k1, k2 = jax.random.split(rng, 3)
    clients = np.asarray(sample_clients(k1, 100, 10))
    batches = data.round_batches(clients, r, fed.local_steps, 20)
    state, met = sim.round(state, jax.tree.map(jnp.asarray, batches),
                           jnp.asarray(clients), k2)
    if r % 10 == 0 or r == 49:
        print(f"round {r:3d}  loss {float(met['loss']):.4f}  "
              f"communicated {float(met['bits'])/8e6:.2f} MB "
              f"(uncompressed would be "
              f"{float(state.round)*10*4*state.errors.shape[1]/1e6:.1f} MB)")
